//! Fig. 6 — speedup of the fused im2col+data-packing pass (Algorithm 2)
//! over performing im2col and packing as separate passes, across
//! LMUL ∈ {1, 2, 4, 8}, for the ResNet-50 stem (7×7) and the 3×3 conv2
//! of each stage — the layers where im2col overhead dominates (§4.3).
//!
//! Paper claims: fusion wins at every LMUL; the optimal LMUL varies per
//! layer because feature-map widths are not multiples of the vector
//! length (boundary handling grows with LMUL).

use nmprune::benchlib::{bench, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::im2col::{fused_im2col_pack_cnhw, im2col_cnhw, pack_data_matrix};
use nmprune::models::resnet50_fig6_layers;
use nmprune::rvv::kernels::{sim_fused_im2col_pack, sim_separate_im2col_pack};
use nmprune::rvv::RvvMachine;
use nmprune::tensor::Tensor;
use nmprune::tuner::LMULS;
use nmprune::util::XorShiftRng;

fn main() {
    let mut layers = resnet50_fig6_layers(1);
    if is_quick() {
        // Stem + the two largest 3×3 layers exercise every boundary case.
        layers.truncate(3);
    }
    let cfg = BenchConfig::quick();
    let mut rep = Reporter::from_env("fig6_fusion_speedup");

    let mut sim_t = Table::new(
        "Fig. 6 (sim) — fused/separate speedup, RVV cycles",
        &["layer", "LMUL=1", "LMUL=2", "LMUL=4", "LMUL=8", "best LMUL"],
    );
    let mut nat_t = Table::new(
        "Fig. 6 (native) — fused/separate speedup, wall-clock",
        &["layer", "LMUL=1", "LMUL=2", "LMUL=4", "LMUL=8", "best LMUL"],
    );

    for l in &layers {
        let s = l.shape;
        let mut rng = XorShiftRng::new(0xF16 ^ s.c_in as u64);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);

        let mut sim_cells = vec![l.name.to_string()];
        let mut nat_cells = vec![l.name.to_string()];
        let (mut best_sim, mut best_sim_cyc) = (0usize, f64::INFINITY);
        let (mut best_nat, mut best_nat_ns) = (0usize, f64::INFINITY);

        for &lmul in &LMULS {
            // --- simulator: cycle ratio separate/fused ---
            let mut m = RvvMachine::k1();
            let x_addr = m.alloc(&x.data);
            let (_, fused) = sim_fused_im2col_pack(&mut m, x_addr, &s, lmul);
            let mut m = RvvMachine::k1();
            let x_addr = m.alloc(&x.data);
            let (_, sep) = sim_separate_im2col_pack(&mut m, x_addr, &s, lmul);
            let ratio = sep.cycles as f64 / fused.cycles as f64;
            let lcfg = RecordConfig::new(lmul, 0, 1);
            let case = format!("sim fused cycles {}", l.name);
            rep.record_value(&case, lcfg, fused.cycles as f64, "cycles", true);
            let case = format!("sim fusion speedup {}", l.name);
            rep.record_value(&case, lcfg, ratio, "ratio", true);
            sim_cells.push(format!("{ratio:.2}x"));
            if (fused.cycles as f64) < best_sim_cyc {
                best_sim_cyc = fused.cycles as f64;
                best_sim = lmul;
            }

            // --- native wall-clock ---
            let v = 8 * lmul;
            let bf = bench("fused", cfg, || fused_im2col_pack_cnhw(&x, &s, v));
            let bs = bench("separate", cfg, || {
                let a = im2col_cnhw(&x, &s);
                pack_data_matrix(&a, s.k(), s.gemm_cols(), v)
            });
            let case = format!("native fused pack {}", l.name);
            rep.record(&case, RecordConfig::new(lmul, 0, 1), &bf.summary, None);
            nat_cells.push(format!("{:.2}x", bs.mean_ns() / bf.mean_ns()));
            if bf.mean_ns() < best_nat_ns {
                best_nat_ns = bf.mean_ns();
                best_nat = lmul;
            }
        }
        sim_cells.push(format!("{best_sim}"));
        nat_cells.push(format!("{best_nat}"));
        sim_t.row(&sim_cells);
        nat_t.row(&nat_cells);
    }

    sim_t.print();
    nat_t.print();
    println!("paper: fusion consistently >1x at every LMUL; optimal LMUL varies per layer");
    rep.finish();
}
