//! Fig. 8 — execution-time breakdown of a dense (unpruned) convolution,
//! isolating the preprocessing strategies (§4.3):
//!
//!   8a — with vs without data packing: dropping the packing pass makes
//!        the GEMM read the strided row-major A matrix, collapsing cache
//!        locality; total time *increases* despite skipping a pass.
//!   8b — fused vs separate: fusion costs only slightly more than the
//!        im2col pass alone while replacing im2col+pack entirely; for
//!        the stride-2 stem the fused pass even beats im2col alone
//!        (padding regions are skipped, not copied).
//!
//! Layers: ResNet-50 stem (7×7 s2) + the 3×3 conv2 of each stage.
//! Metric: deterministic RVV-simulator cycles, split per phase.

use nmprune::benchlib::{is_quick, RecordConfig, Reporter, Table};
use nmprune::models::resnet50_fig6_layers;
use nmprune::rvv::kernels::{
    sim_fused_im2col_pack, sim_gemm_dense, sim_gemm_dense_unpacked, sim_im2col, sim_pack,
};
use nmprune::rvv::RvvMachine;
use nmprune::tensor::layout::oihw_to_filter_matrix;
use nmprune::tensor::Tensor;
use nmprune::util::XorShiftRng;

const LMUL: usize = 2;
const TILE: usize = 8;

fn main() {
    let quick = is_quick();
    let mut layers = resnet50_fig6_layers(1);
    if quick {
        layers.truncate(3);
    }
    let mut rep = Reporter::from_env("fig8_breakdown");

    let mut t8a = Table::new(
        "Fig. 8a (sim cycles) — with vs without data packing",
        &[
            "layer",
            "im2col",
            "pack",
            "gemm(packed)",
            "total packed",
            "gemm(unpacked)",
            "total unpacked",
            "packed wins",
        ],
    );
    let mut t8b = Table::new(
        "Fig. 8b (sim cycles) — fused vs separate im2col+pack",
        &[
            "layer",
            "im2col alone",
            "separate (im2col+pack)",
            "fused",
            "fused/separate",
            "fused<=im2col?",
        ],
    );

    for l in &layers {
        let s = l.shape;
        let mut rng = XorShiftRng::new(0xF18 ^ s.c_out as u64);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
        let f = oihw_to_filter_matrix(&w);
        let k = s.k();
        let cols = s.gemm_cols();
        // GEMM output rows bounded in quick mode; preprocessing always
        // runs in full (it is the subject of the figure).
        let rows = if quick { s.c_out.min(16) } else { s.c_out };
        let fdata = &f.data[..rows * k];

        // Phase cycles, each from a fresh machine so cache state is
        // comparable across configurations.
        let mut m = RvvMachine::k1();
        let xa = m.alloc(&x.data);
        let (a_addr, r_im2col) = sim_im2col(&mut m, xa, &s, LMUL);
        let (_p_addr, r_pack) = sim_pack(&mut m, a_addr, k, cols, LMUL);
        // GEMM over packed strips (same machine: A already warm as it
        // would be in a real pipeline).
        let v = m.vlmax(LMUL);
        let packed = {
            let a_host = m.read(a_addr, k * cols).to_vec();
            nmprune::im2col::pack_data_matrix(&a_host, k, cols, v)
        };
        let mut mg = RvvMachine::k1();
        let (_, r_gemm_p) = sim_gemm_dense(&mut mg, fdata, rows, &packed, TILE, LMUL);

        // No-packing: GEMM straight off the row-major A.
        let mut mu = RvvMachine::k1();
        let a_host = m.read(a_addr, k * cols).to_vec();
        let au = mu.alloc(&a_host);
        let (_, r_gemm_u) = sim_gemm_dense_unpacked(&mut mu, fdata, rows, au, k, cols, TILE, LMUL);

        // Fused pass.
        let mut mf = RvvMachine::k1();
        let xa = mf.alloc(&x.data);
        let (_, r_fused) = sim_fused_im2col_pack(&mut mf, xa, &s, LMUL);

        let total_packed = r_im2col.cycles + r_pack.cycles + r_gemm_p.cycles;
        let total_unpacked = r_im2col.cycles + r_gemm_u.cycles;
        let scfg = RecordConfig::new(LMUL, TILE, 1);
        let case = format!("sim total packed {}", l.name);
        rep.record_value(&case, scfg, total_packed as f64, "cycles", true);
        let case = format!("sim total unpacked {}", l.name);
        rep.record_value(&case, scfg, total_unpacked as f64, "cycles", true);
        t8a.row(&[
            l.name.into(),
            format!("{}", r_im2col.cycles),
            format!("{}", r_pack.cycles),
            format!("{}", r_gemm_p.cycles),
            format!("{}", total_packed),
            format!("{}", r_gemm_u.cycles),
            format!("{}", total_unpacked),
            format!("{}", total_packed < total_unpacked),
        ]);

        let sep = r_im2col.cycles + r_pack.cycles;
        let case = format!("sim separate im2col+pack {}", l.name);
        rep.record_value(&case, scfg, sep as f64, "cycles", true);
        let case = format!("sim fused {}", l.name);
        rep.record_value(&case, scfg, r_fused.cycles as f64, "cycles", true);
        t8b.row(&[
            l.name.into(),
            format!("{}", r_im2col.cycles),
            format!("{}", sep),
            format!("{}", r_fused.cycles),
            format!("{:.2}x", sep as f64 / r_fused.cycles as f64),
            format!("{}", r_fused.cycles <= r_im2col.cycles + r_im2col.cycles / 10),
        ]);
    }

    t8a.print();
    t8b.print();
    println!(
        "paper: 8a — omitting packing balloons GEMM time (poor locality); \
         8b — fused ~= im2col alone, far below separate; stem stride-2 fused beats im2col alone"
    );
    rep.finish();
}
