//! Table 2 — end-to-end inference time for the full model zoo, dense vs
//! column-wise sparse at r ∈ {0.25, 0.50, 0.75}, batch 1 (§4.5).
//!
//! Paper claims: shallow ResNets up to 4.0× over dense NHWC, deep
//! ResNets up to 3.2×, MobileNet-V2 up to 1.4×, DenseNet-121 modest.
//! The paper's accuracy column comes from ImageNet retraining; our
//! substitution trains the synthetic-task CNN (`make accuracy` →
//! `artifacts/accuracy_table.md`) and this bench reprints those numbers
//! when present.

use nmprune::benchlib::{bench, bench_pool, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::engine::{ExecConfig, Executor};
use nmprune::models::{build_model, model_names, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::util::XorShiftRng;

const THREADS: usize = 4;

fn main() {
    let quick = is_quick();
    // NMPRUNE_THREAD_CAP=N caps every layer's GEMM at N pool workers
    // (0 / unset = pool-wide), exposing the per-layer parallelism knob
    // end-to-end without re-tuning: batch-1 late-stage layers are small
    // enough that modest caps can match pool-wide dispatch.
    let thread_cap = std::env::var("NMPRUNE_THREAD_CAP")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(0);
    let res = if quick { 112 } else { 224 };
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(0),
        measure: std::time::Duration::from_millis(if quick { 1 } else { 1500 }),
        min_samples: if quick { 1 } else { 2 },
        max_samples: if quick { 2 } else { 4 },
    };

    let mut t = Table::new(
        &format!(
            "Table 2 — end-to-end time (ms) @{res}, batch 1, 4 threads{}",
            if thread_cap > 0 {
                format!(", per-layer cap {thread_cap}")
            } else {
                String::new()
            }
        ),
        &[
            "model",
            "dense NHWC",
            "r=0.25",
            "r=0.50",
            "r=0.75",
            "best speedup",
        ],
    );

    let mut rep = Reporter::from_env("table2_e2e");
    let mut rng = XorShiftRng::new(0x7B2);
    let pool = bench_pool(THREADS);
    for &name in model_names() {
        if quick && matches!(name, "resnet101" | "resnet152" | "densenet121") {
            continue;
        }
        let arch = ModelArch::parse(name).unwrap();
        let x = Tensor::random(&[1, res, res, 3], &mut rng, 0.0, 1.0);

        let eff_threads = if thread_cap > 0 { thread_cap } else { THREADS };
        let ecfg = RecordConfig::new(0, 0, eff_threads);
        let mut run = |label: &str, mut cfg_exec: ExecConfig| -> f64 {
            cfg_exec.default_choice.threads = thread_cap;
            let exec = Executor::new(build_model(arch, 1, res), cfg_exec);
            let r = bench(name, cfg, || exec.run(&x));
            rep.record(&format!("{name}@{res} {label}"), ecfg, &r.summary, None);
            r.mean_ms()
        };
        let dense = run("dense nhwc", ExecConfig::dense_nhwc(pool.clone()));
        let r25 = run("sparse r25", ExecConfig::sparse_cnhw(pool.clone(), 0.25));
        let r50 = run("sparse r50", ExecConfig::sparse_cnhw(pool.clone(), 0.5));
        let r75 = run("sparse r75", ExecConfig::sparse_cnhw(pool.clone(), 0.75));

        t.row(&[
            name.into(),
            format!("{dense:.1}"),
            format!("{r25:.1}"),
            format!("{r50:.1}"),
            format!("{r75:.1}"),
            format!("{:.2}x", dense / r25.min(r50).min(r75)),
        ]);
    }

    t.print();

    // Accuracy column (Table 1 + Table 2 Acc): reprint the training
    // harness output if it has been generated.
    match std::fs::read_to_string("artifacts/accuracy_table.md") {
        Ok(s) => println!("\n## Accuracy (synthetic-task substitution — see DESIGN.md §2)\n\n{s}"),
        Err(_) => println!(
            "\n(accuracy table not found — run `make accuracy` to train/prune/fine-tune \
             the substitution CNN and emit artifacts/accuracy_table.md)"
        ),
    }
    println!(
        "paper: shallow ResNets up to 4.0x, deep up to 3.2x, MobileNet-V2 1.4x, DenseNet-121 modest"
    );
    rep.finish();
}
