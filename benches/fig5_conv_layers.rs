//! Fig. 5 — inference time of the 12 representative ResNet-50
//! convolution layers (conv1/conv2/conv3 of each stage's first block,
//! excluding downsampling), batch 1, single thread, 50% sparsity.
//!
//! Paper configurations (§4.2), all three using the fused im2col+pack
//! preprocessing and CNHW layout:
//!   (1) dense
//!   (2) conventional N:M pruning, outer-product order (2:4)
//!   (3) column-wise N:M pruning (ours, adaptive M = K)
//!
//! Paper claims to preserve: conventional N:M is *slower* than dense (up
//! to 5.4×); column-wise is consistently *faster* (up to 1.86×, avg
//! ~1.5×). We report both deterministic RVV-simulator cycles (the
//! paper-metric twin of the SpacemiT K1) and native wall-clock.

use nmprune::benchlib::{bench, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::gemm::{gemm_dense, spmm_colwise, spmm_outer_rownm};
use nmprune::im2col::pack_data_matrix;
use nmprune::models::resnet50_fig5_layers;
use nmprune::pruning::{prune_colwise_adaptive, prune_rownm, retained_for_sparsity};
use nmprune::rvv::kernels::{sim_gemm_dense, sim_spmm_colwise, sim_spmm_outer_rownm};
use nmprune::rvv::RvvMachine;
use nmprune::tensor::layout::oihw_to_filter_matrix;
use nmprune::tensor::Tensor;
use nmprune::util::XorShiftRng;

const SPARSITY: f64 = 0.5;
const TILE: usize = 8;
const LMUL: usize = 2; // (T+1)·LMUL ≤ 32 with T = 8

fn main() {
    let quick = is_quick();
    let mut layers = resnet50_fig5_layers(1);
    if quick {
        // One conv2/conv3 pair per early stage keeps every code path hot
        // while the CI smoke stays under a minute.
        layers.truncate(4);
    }
    let cfg = BenchConfig::quick();
    let mut rep = Reporter::from_env("fig5_conv_layers");

    let mut sim_t = Table::new(
        "Fig. 5 (sim) — RVV cycles per conv GEMM, 50% sparsity, LMUL=2, T=8",
        &[
            "layer",
            "dense cyc",
            "conv N:M cyc",
            "colwise cyc",
            "convNM vs dense",
            "ours vs dense",
        ],
    );
    let mut nat_t = Table::new(
        "Fig. 5 (native) — wall-clock per conv GEMM, single thread",
        &[
            "layer",
            "dense ms",
            "conv N:M ms",
            "colwise ms",
            "convNM vs dense",
            "ours vs dense",
        ],
    );

    let mut worst_conv = f64::INFINITY; // conventional speedup (min = worst slowdown)
    let mut best_ours: f64 = 0.0;
    let mut sum_ours = 0.0;

    for l in &layers {
        let s = l.shape;
        let mut rng = XorShiftRng::new(0xF15 ^ s.c_out as u64);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
        let f = oihw_to_filter_matrix(&w);
        let k = s.k();
        let machine = RvvMachine::k1();
        let v = machine.vlmax(LMUL);
        // Sim on a bounded strip count (deterministic per-strip cost ×
        // strip count is exact); native on the full data matrix.
        let full_cols = s.gemm_cols();
        let sim_cols = if quick {
            full_cols.min(4 * v)
        } else {
            full_cols.min(16 * v)
        };
        let scale = full_cols as f64 / sim_cols as f64;
        let a = rng.normal_vec(k * full_cols, 1.0);
        let packed_sim = pack_data_matrix(&a[..k * sim_cols], k, sim_cols, v);
        let packed_full = pack_data_matrix(&a, k, full_cols, v);

        // Pruned operands: conventional row-based 2:4, ours adaptive M=K.
        let n4 = retained_for_sparsity(4, SPARSITY);
        let rowp = prune_rownm(&f.data, s.c_out, k, n4, 4);
        let colp = prune_colwise_adaptive(&f.data, s.c_out, k, TILE, SPARSITY);

        // --- simulator cycles ---
        let mut m = RvvMachine::k1();
        let (_, rd) = sim_gemm_dense(&mut m, &f.data, s.c_out, &packed_sim, TILE, LMUL);
        let mut m = RvvMachine::k1();
        let (_, ro) = sim_spmm_outer_rownm(&mut m, &rowp, &packed_sim, LMUL);
        let mut m = RvvMachine::k1();
        let (_, rc) = sim_spmm_colwise(&mut m, &colp, &packed_sim, LMUL);
        let (dc, oc, cc) = (
            rd.cycles as f64 * scale,
            ro.cycles as f64 * scale,
            rc.cycles as f64 * scale,
        );
        // Simulator cycles are deterministic: the strongest regression
        // gates in the whole trajectory.
        let scfg = RecordConfig::new(LMUL, TILE, 1);
        let case = format!("sim dense {}", l.name);
        rep.record_value(&case, scfg, dc, "cycles", true);
        let case = format!("sim outer_rownm {}", l.name);
        rep.record_value(&case, scfg, oc, "cycles", true);
        let case = format!("sim colwise {}", l.name);
        rep.record_value(&case, scfg, cc, "cycles", true);
        sim_t.row(&[
            l.name.into(),
            format!("{:.0}", dc),
            format!("{:.0}", oc),
            format!("{:.0}", cc),
            format!("{:.2}x", dc / oc),
            format!("{:.2}x", dc / cc),
        ]);
        worst_conv = worst_conv.min(dc / oc);
        best_ours = best_ours.max(dc / cc);
        sum_ours += dc / cc;

        // --- native wall-clock ---
        let bd = bench("dense", cfg, || gemm_dense(&f.data, s.c_out, &packed_full, TILE));
        let bo = bench("outer", cfg, || spmm_outer_rownm(&rowp, &packed_full));
        let bc = bench("colwise", cfg, || spmm_colwise(&colp, &packed_full));
        let flops = 2.0 * s.c_out as f64 * k as f64 * full_cols as f64;
        let ncfg = RecordConfig::new(0, TILE, 1);
        let case = format!("native dense {}", l.name);
        rep.record(&case, ncfg, &bd.summary, Some(flops));
        let case = format!("native outer_rownm {}", l.name);
        rep.record(&case, ncfg, &bo.summary, Some(0.5 * flops));
        let case = format!("native colwise {}", l.name);
        rep.record(&case, ncfg, &bc.summary, Some(0.5 * flops));
        nat_t.row(&[
            l.name.into(),
            format!("{:.3}", bd.mean_ms()),
            format!("{:.3}", bo.mean_ms()),
            format!("{:.3}", bc.mean_ms()),
            format!("{:.2}x", bd.mean_ns() / bo.mean_ns()),
            format!("{:.2}x", bd.mean_ns() / bc.mean_ns()),
        ]);
    }

    sim_t.print();
    nat_t.print();
    println!(
        "paper: conventional N:M up to 5.4x SLOWER than dense; ours up to 1.86x faster (avg 1.5x)"
    );
    println!(
        "sim:   conventional N:M worst {:.2}x vs dense; ours best {:.2}x, avg {:.2}x",
        worst_conv,
        best_ours,
        sum_ours / layers.len() as f64
    );
    rep.finish();
}
