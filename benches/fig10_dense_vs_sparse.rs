//! Fig. 10 — per-layer inference time: dense NHWC (SiFive-style
//! XNNPACK indirection baseline, LMUL=4) vs dense CNHW (fused pack,
//! LMUL=4) vs our auto-tuned sparse CNHW (50% sparsity), multi-threaded
//! (§4.4). Layers: the Fig. 5 set plus the four stage downsampling
//! projections.
//!
//! Paper claims: ours beats dense CNHW by up to 2.1×; dense NHWC wins in
//! Stage 1 but collapses in deep stages (up to 21× slower than ours at
//! Stage4-down / Stage4-conv1) because its per-run weight packing data
//! movement grows with C_in×C_out.

use nmprune::benchlib::{bench, bench_pool, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::conv::{Conv2dDenseCnhw, Conv2dDenseNhwc, Conv2dSparseCnhw};
use nmprune::models::resnet50_fig10_layers;
use nmprune::tensor::Tensor;
use nmprune::tuner;
use nmprune::util::XorShiftRng;

const SPARSITY: f64 = 0.5;
const THREADS: usize = 4;
const V_LMUL4: usize = 32; // VLMAX at LMUL=4 on the 256-bit machine

fn main() {
    let quick = is_quick();
    let mut layers = resnet50_fig10_layers(1);
    if quick {
        // Early layers plus the deepest pair: the NHWC collapse the
        // figure demonstrates needs a stage-4 shape.
        let n = layers.len();
        layers.drain(3..n - 2);
    }
    let cfg = if quick {
        BenchConfig {
            warmup: std::time::Duration::from_millis(5),
            measure: std::time::Duration::from_millis(60),
            min_samples: 2,
            max_samples: 10,
        }
    } else {
        BenchConfig::quick()
    };

    let mut rep = Reporter::from_env("fig10_dense_vs_sparse");
    let mut t = Table::new(
        "Fig. 10 — dense NHWC vs dense CNHW vs tuned sparse CNHW (ms, 4 threads)",
        &[
            "layer",
            "dense NHWC",
            "dense CNHW",
            "sparse (tuned)",
            "ours vs CNHW",
            "ours vs NHWC",
            "tuned (LMUL,T)",
        ],
    );

    let mut worst_nhwc: f64 = 0.0;
    let mut best_vs_cnhw: f64 = 0.0;
    for l in &layers {
        let s = l.shape;
        let mut rng = XorShiftRng::new(0xF10 ^ s.c_out as u64);
        let x_nhwc = Tensor::random(&[s.n, s.h_in, s.w_in, s.c_in], &mut rng, -1.0, 1.0);
        let x_cnhw = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);

        // Auto-tune (T, LMUL) for the sparse path — §3.3 mechanism —
        // profiling on the same persistent pool the measurement uses.
        let pool = bench_pool(THREADS);
        let tr = tuner::tune_native(&s, Some(SPARSITY), &pool, if quick { 4 } else { 8 });
        let (vt, tt) = (tr.best.v, tr.best.tile);

        let nhwc = Conv2dDenseNhwc::new(s, &w);
        let cnhw = Conv2dDenseCnhw::new(s, &w, V_LMUL4, 7); // (7+1)·4 = 32 regs
        let sparse = Conv2dSparseCnhw::new_adaptive(s, &w, vt, tt, SPARSITY)
            .with_thread_cap(tr.best.threads); // replay the full tuned choice

        let bn = bench("nhwc", cfg, || nhwc.run(&x_nhwc, &pool));
        let bc = bench("cnhw", cfg, || cnhw.run(&x_cnhw, &pool));
        let bs = bench("sparse", cfg, || sparse.run(&x_cnhw, &pool));

        let case = format!("dense nhwc {}", l.name);
        rep.record(&case, RecordConfig::new(4, 0, THREADS), &bn.summary, None);
        let case = format!("dense cnhw {}", l.name);
        rep.record(&case, RecordConfig::new(4, 7, THREADS), &bc.summary, None);
        // The tuned choice is part of the record's identity: a tuner
        // that starts picking a different (LMUL, T, P) shows up as a
        // removed + added record, not a bogus time regression.
        let case = format!("sparse tuned {}", l.name);
        let tcfg = RecordConfig::new(tr.best.lmul, tt, tr.best.threads);
        rep.record(&case, tcfg, &bs.summary, None);

        let vs_cnhw = bc.mean_ns() / bs.mean_ns();
        let vs_nhwc = bn.mean_ns() / bs.mean_ns();
        best_vs_cnhw = best_vs_cnhw.max(vs_cnhw);
        worst_nhwc = worst_nhwc.max(vs_nhwc);
        t.row(&[
            l.name.into(),
            format!("{:.3}", bn.mean_ms()),
            format!("{:.3}", bc.mean_ms()),
            format!("{:.3}", bs.mean_ms()),
            format!("{vs_cnhw:.2}x"),
            format!("{vs_nhwc:.2}x"),
            format!("({},{})", tr.best.lmul, tt),
        ]);
    }

    t.print();
    println!(
        "paper: ours up to 2.1x over dense CNHW; NHWC up to 21x slower than ours in stage 4.\n\
         measured: ours up to {best_vs_cnhw:.2}x over dense CNHW; NHWC worst {worst_nhwc:.2}x vs ours"
    );
    rep.finish();
}
