//! Ablations for the design choices DESIGN.md calls out — not a paper
//! figure, but the evidence behind three claims the paper asserts
//! without isolating:
//!
//!   A. Tile size T is the data-reuse lever (§3.1): each loaded data
//!      vector is reused T times, so cycles/FLOP fall with T until
//!      register pressure caps it — the reason Algorithm 1 pins
//!      accumulators in registers.
//!   B. Data packing matters *because of* streaming locality: with the
//!      cache model's next-line prefetcher disabled, packed and
//!      unpacked GEMM converge — packing's win is prefetch-friendly
//!      contiguity, not fewer accesses.
//!   C. CNHW beats NCHW on batch-level packing (§5.2): CNHW rows span
//!      batches, so strips stay full when W_out is small; NCHW confines
//!      rows to one image and wastes tail lanes per image.

//!   D. Structured beats unstructured *at execution time* (§2.1): a CSR
//!      kernel at the same sparsity does the same MACs but loses the
//!      shared-index data reuse and the register-resident accumulators,
//!      so column-wise wins wall-clock at equal FLOPs.

use nmprune::benchlib::{bench, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::im2col::pack_data_matrix;
use nmprune::models::resnet50_fig5_layers;
use nmprune::pruning::{prune_colwise_adaptive, prune_unstructured, Csr};
use nmprune::rvv::kernels::{sim_gemm_dense, sim_gemm_dense_unpacked, sim_spmm_colwise};
use nmprune::rvv::{CacheConfig, CostModel, RvvConfig, RvvMachine};
use nmprune::util::XorShiftRng;

const LMUL: usize = 2;

fn machine(prefetch: bool) -> RvvMachine {
    RvvMachine::new(RvvConfig {
        vlen_bits: 256,
        num_regs: 32,
        cache: CacheConfig {
            prefetch,
            ..CacheConfig::default()
        },
        cost: CostModel::default(),
    })
}

fn main() {
    let quick = is_quick();
    let mut reporter = Reporter::from_env("ablation_design");
    let mut rng = XorShiftRng::new(0xAB1);

    // ---- A: tile-size sweep on the column-wise kernel ----
    let mut ta = Table::new(
        "Ablation A — tile size T vs cycles (colwise SpMM, 50% sparsity, LMUL=2)",
        &["T", "cycles", "cycles/row", "data loads", "loads/row"],
    );
    let (rows, k, cols) = (64usize, 576usize, 512usize);
    let w = rng.normal_vec(rows * k, 1.0);
    let a = rng.normal_vec(k * cols, 1.0);
    let tiles: &[usize] = if quick { &[1, 8, 15] } else { &[1, 2, 4, 8, 12, 15] };
    for &tile in tiles {
        let mut m = machine(true);
        let v = m.vlmax(LMUL);
        let p = pack_data_matrix(&a, k, cols, v);
        let cp = prune_colwise_adaptive(&w, rows, k, tile, 0.5);
        let (_, rep) = sim_spmm_colwise(&mut m, &cp, &p, LMUL);
        let case = format!("A colwise cycles T={tile}");
        let acfg = RecordConfig::new(LMUL, tile, 1);
        reporter.record_value(&case, acfg, rep.cycles as f64, "cycles", true);
        ta.row(&[
            format!("{tile}"),
            format!("{}", rep.cycles),
            format!("{:.0}", rep.cycles as f64 / rows as f64),
            format!("{}", rep.l1_loads),
            format!("{:.0}", rep.l1_loads as f64 / rows as f64),
        ]);
    }
    ta.print();
    println!("claim A: cycles/row falls with T (shared data vector reused T times)\n");

    // ---- B: prefetch on/off × packed/unpacked dense GEMM ----
    let mut tb = Table::new(
        "Ablation B — packing win is streaming locality (dense GEMM cycles)",
        &["config", "packed", "unpacked", "unpacked/packed"],
    );
    let (rows, k, cols) = (64usize, 576usize, 1024usize);
    let w = rng.normal_vec(rows * k, 1.0);
    let a = rng.normal_vec(k * cols, 1.0);
    for prefetch in [true, false] {
        let mut m = machine(prefetch);
        let v = m.vlmax(LMUL);
        let p = pack_data_matrix(&a, k, cols, v);
        let (_, rp) = sim_gemm_dense(&mut m, &w, rows, &p, 8, LMUL);
        let mut m = machine(prefetch);
        let aa = m.alloc(&a);
        let (_, ru) = sim_gemm_dense_unpacked(&mut m, &w, rows, aa, k, cols, 8, LMUL);
        let pf = if prefetch { "on" } else { "off" };
        let bcfg = RecordConfig::new(LMUL, 8, 1);
        let case = format!("B packed cycles prefetch={pf}");
        reporter.record_value(&case, bcfg, rp.cycles as f64, "cycles", true);
        let case = format!("B unpacked cycles prefetch={pf}");
        reporter.record_value(&case, bcfg, ru.cycles as f64, "cycles", true);
        tb.row(&[
            if prefetch { "prefetch ON" } else { "prefetch OFF" }.into(),
            format!("{}", rp.cycles),
            format!("{}", ru.cycles),
            format!("{:.2}x", ru.cycles as f64 / rp.cycles as f64),
        ]);
    }
    tb.print();
    println!("claim B: the packed/unpacked gap collapses without the stream prefetcher\n");

    // ---- C: CNHW vs NCHW strip utilisation across batch sizes ----
    let mut tc = Table::new(
        "Ablation C — batch-level packing: strip-lane utilisation (V=32)",
        &["layer", "batch", "CNHW strips", "NCHW strips", "CNHW util", "NCHW util"],
    );
    let v = 32usize;
    for l in resnet50_fig5_layers(1) {
        let s = l.shape;
        if s.w_out() * s.h_out() >= 4 * v {
            continue; // §5's effect appears when per-image cols are small
        }
        for batch in [1usize, 2, 4] {
            let per_image = s.h_out() * s.w_out();
            let cols = batch * per_image;
            // CNHW: one matrix, rows span batches.
            let cnhw_strips = cols.div_ceil(v);
            // NCHW: per-image matrices, each padded to strip width.
            let nchw_strips = batch * per_image.div_ceil(v);
            tc.row(&[
                l.name.into(),
                format!("{batch}"),
                format!("{cnhw_strips}"),
                format!("{nchw_strips}"),
                format!("{:.0}%", 100.0 * cols as f64 / (cnhw_strips * v) as f64),
                format!("{:.0}%", 100.0 * cols as f64 / (nchw_strips * v) as f64),
            ]);
        }
    }
    tc.print();
    println!("claim C: CNHW keeps strips full as batch grows; NCHW wastes tail lanes per image\n");

    // ---- D: column-wise structured vs unstructured CSR, equal sparsity ----
    let mut td = Table::new(
        "Ablation D — column-wise (ours) vs unstructured CSR at equal sparsity (native)",
        &["sparsity", "colwise ms", "CSR ms", "colwise/CSR"],
    );
    let (rows, k, cols, v, tile) = (64usize, 576usize, 1024usize, 32usize, 8usize);
    let w = rng.normal_vec(rows * k, 1.0);
    let a = rng.normal_vec(k * cols, 1.0);
    let p = pack_data_matrix(&a, k, cols, v);
    let cfg = BenchConfig::quick();
    let sparsities: &[f64] = if quick { &[0.5, 0.9] } else { &[0.5, 0.75, 0.9] };
    for &sparsity in sparsities {
        let cp = prune_colwise_adaptive(&w, rows, k, tile, sparsity);
        let bc = bench("colwise", cfg, || nmprune::gemm::spmm_colwise(&cp, &p));
        let csr = Csr::from_dense(&prune_unstructured(&w, sparsity), rows, k);
        let bu = bench("csr", cfg, || {
            // Strip-by-strip CSR SpMM over the same packed operand.
            let mut out = vec![0.0f32; rows * p.strips * v];
            for s in 0..p.strips {
                let y = csr.spmm(p.strip(s), v);
                out[s * rows * v..(s + 1) * rows * v].copy_from_slice(&y);
            }
            out
        });
        let flops_exec = (1.0 - sparsity) * 2.0 * (rows * k * cols) as f64;
        let dcfg = RecordConfig::new(0, tile, 1);
        let case = format!("D colwise {:.0}%", sparsity * 100.0);
        reporter.record(&case, dcfg, &bc.summary, Some(flops_exec));
        let case = format!("D csr {:.0}%", sparsity * 100.0);
        reporter.record(&case, dcfg, &bu.summary, Some(flops_exec));
        td.row(&[
            format!("{:.0}%", sparsity * 100.0),
            format!("{:.3}", bc.mean_ms()),
            format!("{:.3}", bu.mean_ms()),
            format!("{:.2}x faster", bu.mean_ns() / bc.mean_ns()),
        ]);
    }
    td.print();
    println!(
        "claim D: same executed FLOPs, but the shared column-index set and \
         register-resident accumulators make the structured kernel win"
    );
    reporter.finish();
}
