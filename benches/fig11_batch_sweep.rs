//! Fig. 11 — end-to-end ResNet-50 inference time across batch sizes
//! {1, 2, 4} for: dense NHWC (SiFive-style), dense CNHW, and our sparse
//! CNHW at 25/50/75% sparsity (§4.5).
//!
//! Paper claims: dense CNHW beats NHWC at batch 1–2, the gap narrows at
//! batch 4; sparse beats both at every batch; at 75% sparsity the
//! speedups over dense NHWC are 3.0×/1.9×/1.5× for batches 1/2/4.
//!
//! `NMPRUNE_BENCH_QUICK=1` drops the resolution to 112 to keep CI fast;
//! the full run uses the paper's 224×224 ImageNet geometry.

use nmprune::benchlib::{bench, bench_pool, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::engine::{ExecConfig, Executor};
use nmprune::models::{build_model, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::util::XorShiftRng;

const THREADS: usize = 4;

fn main() {
    let quick = is_quick();
    let res = if quick { 112 } else { 224 };
    let batches: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(0),
        measure: std::time::Duration::from_millis(if quick { 1 } else { 2000 }),
        min_samples: if quick { 1 } else { 2 },
        max_samples: if quick { 2 } else { 5 },
    };

    let mut t = Table::new(
        &format!("Fig. 11 — ResNet-50 end-to-end time (ms) @{res}, 4 threads"),
        &[
            "batch",
            "dense NHWC",
            "dense CNHW",
            "sparse 25%",
            "sparse 50%",
            "sparse 75%",
            "75% vs NHWC",
        ],
    );

    let mut rep = Reporter::from_env("fig11_batch_sweep");
    let mut rng = XorShiftRng::new(0xF11);
    let pool = bench_pool(THREADS);
    for &b in batches {
        let variants: Vec<(String, ExecConfig)> = vec![
            ("nhwc".into(), ExecConfig::dense_nhwc(pool.clone())),
            ("cnhw".into(), ExecConfig::dense_cnhw(pool.clone())),
            ("s25".into(), ExecConfig::sparse_cnhw(pool.clone(), 0.25)),
            ("s50".into(), ExecConfig::sparse_cnhw(pool.clone(), 0.5)),
            ("s75".into(), ExecConfig::sparse_cnhw(pool.clone(), 0.75)),
        ];
        let x = Tensor::random(&[b, res, res, 3], &mut rng, 0.0, 1.0);
        let mut ms = Vec::new();
        for (name, cfg_exec) in variants {
            let exec = Executor::new(build_model(ModelArch::ResNet50, b, res), cfg_exec);
            let r = bench(&name, cfg, || exec.run(&x));
            let case = format!("resnet50@{res} {name} batch{b}");
            rep.record(&case, RecordConfig::new(0, 0, THREADS), &r.summary, None);
            ms.push(r.mean_ms());
        }
        t.row(&[
            format!("{b}"),
            format!("{:.1}", ms[0]),
            format!("{:.1}", ms[1]),
            format!("{:.1}", ms[2]),
            format!("{:.1}", ms[3]),
            format!("{:.1}", ms[4]),
            format!("{:.2}x", ms[0] / ms[4]),
        ]);
    }

    t.print();
    println!("paper: 75% sparsity vs dense NHWC = 3.0x (b1), 1.9x (b2), 1.5x (b4)");
    rep.finish();
}
