//! Fig. 12 — end-to-end dense inference time: NHWC (SiFive-style
//! XNNPACK indirection) vs the proposed CNHW layout, LMUL=4 equivalent,
//! across all seven evaluation models (§4.6).
//!
//! Paper claims: CNHW up to 1.8× faster for shallow ResNets (all-3×3
//! bodies benefit most from fused im2col+pack), up to 1.6× for deep
//! ResNets (1×1-heavy bottlenecks dilute the win), ~1.3× for
//! MobileNet-V2, and ≈1× (slight loss) for DenseNet-121, whose weight
//! tensors are smaller than its feature maps.

use nmprune::benchlib::{bench, bench_pool, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::engine::{ExecConfig, Executor};
use nmprune::models::{build_model, model_names, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::util::XorShiftRng;

const THREADS: usize = 4;

fn main() {
    let quick = is_quick();
    let res = if quick { 112 } else { 224 };
    let cfg = BenchConfig {
        warmup: std::time::Duration::from_millis(0),
        measure: std::time::Duration::from_millis(if quick { 1 } else { 1500 }),
        min_samples: if quick { 1 } else { 2 },
        max_samples: if quick { 2 } else { 5 },
    };

    let mut t = Table::new(
        &format!("Fig. 12 — dense NHWC vs CNHW end-to-end (ms) @{res}, batch 1"),
        &["model", "NHWC", "CNHW", "CNHW speedup"],
    );

    let mut rep = Reporter::from_env("fig12_layout");
    let mut rng = XorShiftRng::new(0xF12);
    let pool = bench_pool(THREADS);
    for &name in model_names() {
        if quick && matches!(name, "resnet101" | "resnet152") {
            continue; // trimmed in quick mode; full run covers all seven
        }
        let arch = ModelArch::parse(name).unwrap();
        let x = Tensor::random(&[1, res, res, 3], &mut rng, 0.0, 1.0);

        let en = Executor::new(
            build_model(arch, 1, res),
            ExecConfig::dense_nhwc(pool.clone()),
        );
        let bn = bench("nhwc", cfg, || en.run(&x));
        drop(en);
        let ec = Executor::new(
            build_model(arch, 1, res),
            ExecConfig::dense_cnhw(pool.clone()),
        );
        let bc = bench("cnhw", cfg, || ec.run(&x));

        let ecfg = RecordConfig::new(0, 0, THREADS);
        let case = format!("{name}@{res} nhwc");
        rep.record(&case, ecfg, &bn.summary, None);
        let case = format!("{name}@{res} cnhw");
        rep.record(&case, ecfg, &bc.summary, None);
        t.row(&[
            name.into(),
            format!("{:.1}", bn.mean_ms()),
            format!("{:.1}", bc.mean_ms()),
            format!("{:.2}x", bn.mean_ns() / bc.mean_ns()),
        ]);
    }

    t.print();
    println!(
        "paper: shallow ResNets up to 1.8x, deep ResNets up to 1.6x, \
         MobileNet-V2 ~1.3x, DenseNet-121 ~1x (slight loss)"
    );
    rep.finish();
}
