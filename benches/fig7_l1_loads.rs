//! Fig. 7 — reduction in L1-cache loads from fusing im2col and data
//! packing, relative to the separate two-pass baseline, across
//! LMUL ∈ {1, 2, 4, 8}, for the 3×3 conv2 layers of ResNet-50.
//!
//! Paper claims: up to 42% fewer L1 loads, and the reduction correlates
//! with the Fig. 6 speedups. The simulator counts loads at cache-line
//! granularity — the same event `perf`'s L1-dcache-loads counts on the
//! SpacemiT K1.

use nmprune::benchlib::{is_quick, RecordConfig, Reporter, Table};
use nmprune::models::resnet50_fig6_layers;
use nmprune::rvv::kernels::{sim_fused_im2col_pack, sim_separate_im2col_pack};
use nmprune::rvv::RvvMachine;
use nmprune::tensor::Tensor;
use nmprune::tuner::LMULS;
use nmprune::util::XorShiftRng;

fn main() {
    // Fig. 7 uses the 3×3 layers only (the stem is 7×7).
    let mut layers: Vec<_> = resnet50_fig6_layers(1)
        .into_iter()
        .filter(|l| l.shape.kh == 3)
        .collect();
    if is_quick() {
        layers.truncate(3);
    }
    let mut rep = Reporter::from_env("fig7_l1_loads");

    let mut t = Table::new(
        "Fig. 7 — L1-load reduction of fused vs separate im2col+pack (%)",
        &["layer", "LMUL=1", "LMUL=2", "LMUL=4", "LMUL=8"],
    );
    let mut max_red: f64 = 0.0;

    for l in &layers {
        let s = l.shape;
        let mut rng = XorShiftRng::new(0xF17 ^ s.c_in as u64);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
        let mut cells = vec![l.name.to_string()];
        for &lmul in &LMULS {
            let mut m = RvvMachine::k1();
            let x_addr = m.alloc(&x.data);
            let (_, fused) = sim_fused_im2col_pack(&mut m, x_addr, &s, lmul);
            let mut m = RvvMachine::k1();
            let x_addr = m.alloc(&x.data);
            let (_, sep) = sim_separate_im2col_pack(&mut m, x_addr, &s, lmul);
            let red = 100.0 * (1.0 - fused.l1_loads as f64 / sep.l1_loads as f64);
            max_red = max_red.max(red);
            let case = format!("l1-load reduction {}", l.name);
            rep.record_value(&case, RecordConfig::new(lmul, 0, 1), red, "percent", true);
            cells.push(format!("{red:.1}%"));
        }
        t.row(&cells);
    }

    t.print();
    println!("paper: up to 42% L1-load reduction; measured max {max_red:.1}%");
    rep.finish();
}
