//! Fig. 9 — convolution inference time across LMUL ∈ {1, 2, 4, 8} with
//! column-wise N:M pruning (50% sparsity), multi-threaded tile dispatch
//! (§4.4).
//!
//! Paper claims: the optimal LMUL varies per layer (LMUL=4 best for
//! Stage1-conv1, LMUL=2 for Stage1-conv2, LMUL=8 for Stage1-conv3, …)
//! and the best configuration is up to 4× faster than the worst — a
//! static LMUL is inadequate, motivating the §3.3 tuner.
//!
//! The register-pressure constraint (T+1)·LMUL ≤ 32 couples the two
//! template parameters: at LMUL=8 only T ≤ 3 fits, so wider vectors
//! trade away accumulator rows exactly as on the K1.

use nmprune::benchlib::{bench, bench_pool, is_quick, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::conv::Conv2dSparseCnhw;
use nmprune::models::resnet50_fig5_layers;
use nmprune::pruning::prune_colwise_adaptive;
use nmprune::rvv::kernels::{max_tile_for_lmul, sim_fused_im2col_pack, sim_spmm_colwise};
use nmprune::rvv::RvvMachine;
use nmprune::tensor::layout::oihw_to_filter_matrix;
use nmprune::tensor::Tensor;
use nmprune::tuner::LMULS;
use nmprune::util::XorShiftRng;

const SPARSITY: f64 = 0.5;
const THREADS: usize = 4;

fn main() {
    let quick = is_quick();
    let mut layers = resnet50_fig5_layers(1);
    if quick {
        layers.truncate(4);
    }
    let cfg = BenchConfig::quick();
    let mut rep = Reporter::from_env("fig9_lmul_sweep");

    let mut nat_t = Table::new(
        "Fig. 9 (native) — sparse conv wall-clock (ms) across LMUL, 4 threads",
        &["layer", "LMUL=1", "LMUL=2", "LMUL=4", "LMUL=8", "best", "worst/best"],
    );
    let mut sim_t = Table::new(
        "Fig. 9 (sim) — sparse conv RVV cycles across LMUL (pack+GEMM)",
        &["layer", "LMUL=1", "LMUL=2", "LMUL=4", "LMUL=8", "best", "worst/best"],
    );

    for l in &layers {
        let s = l.shape;
        let mut rng = XorShiftRng::new(0xF19 ^ s.c_out as u64);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
        let f = oihw_to_filter_matrix(&w);

        // --- native wall-clock across v = 8·LMUL ---
        let pool = bench_pool(THREADS);
        let mut cells = vec![l.name.to_string()];
        let mut times = Vec::new();
        for &lmul in &LMULS {
            let v = 8 * lmul;
            let tile = (32 / lmul - 1).min(8);
            let op = Conv2dSparseCnhw::new_adaptive(s, &w, v, tile, SPARSITY);
            let b = bench("conv", cfg, || op.run(&x, &pool));
            let case = format!("native sparse conv {}", l.name);
            let ncfg = RecordConfig::new(lmul, tile, THREADS);
            rep.record(&case, ncfg, &b.summary, None);
            times.push(b.mean_ns());
            cells.push(format!("{:.3}", b.mean_ms()));
        }
        let (bi, &bv) = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let wv = times.iter().cloned().fold(0.0f64, f64::max);
        cells.push(format!("LMUL={}", LMULS[bi]));
        cells.push(format!("{:.2}x", wv / bv));
        nat_t.row(&cells);

        // --- simulator cycles (bounded strips; per-strip cost is exact) ---
        let mut cells = vec![l.name.to_string()];
        let mut cycs = Vec::new();
        for &lmul in &LMULS {
            let m0 = RvvMachine::k1();
            let v = m0.vlmax(lmul);
            let tile = max_tile_for_lmul(&m0, lmul).min(8);
            let full_cols = s.gemm_cols();
            let cap = if quick { 2 * v } else { 8 * v };
            let cols = full_cols.min(cap);
            let scale = full_cols as f64 / cols as f64;
            // Pack phase on a proportionally shrunk input (W scaled).
            let mut m = RvvMachine::k1();
            let xa = m.alloc(&x.data);
            let (_, rp) = sim_fused_im2col_pack(&mut m, xa, &s, lmul);
            // GEMM phase on bounded strips (cycle cost depends only on
            // shape, so a random A of the right geometry suffices).
            let cp = prune_colwise_adaptive(&f.data, s.c_out, s.k(), tile, SPARSITY);
            let a = rng.normal_vec(s.k() * cols, 1.0);
            let bounded = nmprune::im2col::pack_data_matrix(&a, s.k(), cols, v);
            let mut m = RvvMachine::k1();
            let (_, rg) = sim_spmm_colwise(&mut m, &cp, &bounded, lmul);
            let total = rp.cycles as f64 + rg.cycles as f64 * scale;
            let case = format!("sim sparse conv {}", l.name);
            let scfg = RecordConfig::new(lmul, tile, 1);
            rep.record_value(&case, scfg, total, "cycles", true);
            cycs.push(total);
            cells.push(format!("{total:.0}"));
        }
        let (bi, &bv) = cycs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let wv = cycs.iter().cloned().fold(0.0f64, f64::max);
        cells.push(format!("LMUL={}", LMULS[bi]));
        cells.push(format!("{:.2}x", wv / bv));
        sim_t.row(&cells);
    }

    nat_t.print();
    sim_t.print();
    println!("paper: optimal LMUL varies per layer; best vs worst up to 4x");
    rep.finish();
}
