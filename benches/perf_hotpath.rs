//! §Perf microbenchmarks — the native hot-path kernels in isolation.
//! Used by the EXPERIMENTS.md §Perf iteration log (before/after per
//! optimization step). GFLOP/s is effective (counting pruned-away FLOPs
//! for sparse kernels would flatter them; we count executed MACs ×2).

use std::time::Duration;

use nmprune::benchlib::{bench, bench_pool, BenchConfig, RecordConfig, Reporter, Table};
use nmprune::conv::{Conv2dSparseCnhw, ConvShape};
use nmprune::engine::{
    ExecConfig, Executor, Priority, QueueDiscipline, Server, ServerConfig, ServerStats,
};
use nmprune::gemm::threaded::spmm_colwise_parallel_capped;
use nmprune::gemm::{
    gemm_dense, gemm_dense_i8_with, gemm_dense_with, kernels, spmm_colwise, spmm_colwise_i8_with,
    spmm_colwise_with, KernelId,
};
use nmprune::im2col::{fused_im2col_pack_cnhw, pack_data_matrix, quantize_panel_into, QuantPanel};
use nmprune::models::{build_model, ModelArch};
use nmprune::pruning::{prune_colwise_adaptive, ColwiseQuant, QuantDense};
use nmprune::runtime::PackedArtifact;
use nmprune::tensor::{Dtype, Tensor};
use nmprune::util::allocwatch::{self, CountingAlloc};
use nmprune::util::XorShiftRng;

// The memory-plane rows below report *measured* allocation traffic, so
// this bench binary registers the counting allocator the way the
// zero-alloc tests do. Counting is thread-local and opt-in per scope;
// the kernel measurements above it are unaffected.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    // NMPRUNE_BENCH_QUICK=1: CI's bit-rot smoke profile — tiny windows,
    // same code paths, so the bench is *run* (not just compiled) on
    // every push without burning minutes.
    let cfg = if nmprune::benchlib::is_quick() {
        BenchConfig::quick()
    } else {
        BenchConfig {
            warmup: std::time::Duration::from_millis(150),
            measure: std::time::Duration::from_millis(1200),
            min_samples: 8,
            max_samples: 400,
        }
    };
    let mut t = Table::new(
        "§Perf hot-path kernels",
        &["kernel", "shape", "time", "GFLOP/s (executed)"],
    );
    // NMPRUNE_BENCH_JSON=<path>: also emit machine-readable records
    // (roofline-normalized) for the BENCH_*.json trajectory.
    let mut rep = Reporter::from_env("perf_hotpath");
    let mut rng = XorShiftRng::new(0x9E6F);

    // Representative GEMM geometry: Stage1-conv2-like (K=576, cols=3136).
    let (rows, k, cols, v, tile) = (64usize, 576usize, 3136usize, 32usize, 8usize);
    let w = rng.normal_vec(rows * k, 1.0);
    let a = rng.normal_vec(k * cols, 1.0);
    let p = pack_data_matrix(&a, k, cols, v);

    let r = bench("dense", cfg, || gemm_dense(&w, rows, &p, tile));
    let flops = 2.0 * rows as f64 * k as f64 * cols as f64;
    let kcfg = RecordConfig::new(0, tile, 1);
    rep.record("gemm_dense 64x576x3136", kcfg, &r.summary, Some(flops));
    t.row(&[
        "gemm_dense".into(),
        format!("{rows}x{k}x{cols} v{v} t{tile}"),
        format!("{:.3} ms", r.mean_ms()),
        format!("{:.2}", flops / r.mean_ns()),
    ]);

    let cp = prune_colwise_adaptive(&w, rows, k, tile, 0.5);
    let r = bench("colwise", cfg, || spmm_colwise(&cp, &p));
    rep.record(
        "spmm_colwise 50% 64x576x3136",
        kcfg,
        &r.summary,
        Some(0.5 * flops),
    );
    t.row(&[
        "spmm_colwise 50%".into(),
        format!("{rows}x{k}x{cols} v{v} t{tile}"),
        format!("{:.3} ms", r.mean_ms()),
        format!("{:.2}", 0.5 * flops / r.mean_ns()),
    ]);

    let cp75 = prune_colwise_adaptive(&w, rows, k, tile, 0.75);
    let r = bench("colwise75", cfg, || spmm_colwise(&cp75, &p));
    rep.record(
        "spmm_colwise 75% 64x576x3136",
        kcfg,
        &r.summary,
        Some(0.25 * flops),
    );
    t.row(&[
        "spmm_colwise 75%".into(),
        format!("{rows}x{k}x{cols} v{v} t{tile}"),
        format!("{:.3} ms", r.mean_ms()),
        format!("{:.2}", 0.25 * flops / r.mean_ns()),
    ]);

    // Kernel identity: the same GEMM/spMM geometry with the backend
    // pinned to the scalar oracle and to the best native backend this
    // host resolves. The Auto rows above already *run* the native
    // backend; these rows make the scalar-vs-native gap an explicit,
    // tracked pair in every BENCH_*.json (and in CI's forced-kernel
    // legs, where NMPRUNE_KERNEL overrides both pins identically).
    let mut kernel_ids = vec![KernelId::Scalar];
    let best = kernels::best_available();
    if best != KernelId::Scalar {
        kernel_ids.push(best);
    }
    for &kid in &kernel_ids {
        let r = bench("dense-kern", cfg, || gemm_dense_with(&w, rows, &p, tile, kid));
        rep.record(
            "gemm_dense 64x576x3136",
            RecordConfig::new(0, tile, 1).with_kernel(kid),
            &r.summary,
            Some(flops),
        );
        t.row(&[
            format!("gemm_dense [{}]", kid.name()),
            format!("{rows}x{k}x{cols} v{v} t{tile}"),
            format!("{:.3} ms", r.mean_ms()),
            format!("{:.2}", flops / r.mean_ns()),
        ]);
        let r = bench("colwise-kern", cfg, || spmm_colwise_with(&cp, &p, kid));
        rep.record(
            "spmm_colwise 50% 64x576x3136",
            RecordConfig::new(0, tile, 1).with_kernel(kid),
            &r.summary,
            Some(0.5 * flops),
        );
        t.row(&[
            format!("spmm_colwise 50% [{}]", kid.name()),
            format!("{rows}x{k}x{cols} v{v} t{tile}"),
            format!("{:.3} ms", r.mean_ms()),
            format!("{:.2}", 0.5 * flops / r.mean_ns()),
        ]);
    }

    // Quantized plane: the same geometry through the int8 strip kernels
    // (i8×i8→i32 accumulate, requantize-to-f32 epilogue), scalar oracle
    // next to the best native backend. Quantization runs outside the
    // timed region, mirroring the serving path where activations are
    // staged into the arena's QuantPanel once per conv, not per strip.
    // Records carry dtype=i8 and normalize against the int8 roofline.
    // GOP/s counts one multiply-add as 2 ops, same as the f32 rows, so
    // the int8-vs-f32 speedup reads directly off the table.
    let qw = QuantDense::quantize(&w, rows, k);
    let mut qp = QuantPanel::zeros(k, cols, v);
    quantize_panel_into(&p, &mut qp);
    let qcp = ColwiseQuant::quantize(&cp);
    for &kid in &kernel_ids {
        let r = bench("dense-i8", cfg, || gemm_dense_i8_with(&qw, &qp, tile, kid));
        rep.record(
            "gemm_dense 64x576x3136",
            RecordConfig::new(0, tile, 1)
                .with_kernel(kid)
                .with_dtype(Dtype::I8),
            &r.summary,
            Some(flops),
        );
        t.row(&[
            format!("gemm_dense i8 [{}]", kid.name()),
            format!("{rows}x{k}x{cols} v{v} t{tile}"),
            format!("{:.3} ms", r.mean_ms()),
            format!("{:.2}", flops / r.mean_ns()),
        ]);
        let r = bench("colwise-i8", cfg, || spmm_colwise_i8_with(&qcp, &qp, kid));
        rep.record(
            "spmm_colwise 50% 64x576x3136",
            RecordConfig::new(0, tile, 1)
                .with_kernel(kid)
                .with_dtype(Dtype::I8),
            &r.summary,
            Some(0.5 * flops),
        );
        t.row(&[
            format!("spmm_colwise 50% i8 [{}]", kid.name()),
            format!("{rows}x{k}x{cols} v{v} t{tile}"),
            format!("{:.3} ms", r.mean_ms()),
            format!("{:.2}", 0.5 * flops / r.mean_ns()),
        ]);
    }

    // Fused pack on the matching conv (64ch 56×56, 3×3 s1 p1).
    let s = ConvShape::square(1, 64, 56, 64, 3, 1, 1);
    let x = Tensor::random(&[64, 1, 56, 56], &mut rng, -1.0, 1.0);
    let r = bench("pack", cfg, || fused_im2col_pack_cnhw(&x, &s, v));
    let bytes = (s.k() * s.gemm_cols() * 4) as f64;
    rep.record(
        "fused_im2col_pack 64ch56x56",
        RecordConfig::new(0, 0, 1),
        &r.summary,
        None,
    );
    t.row(&[
        "fused_im2col_pack".into(),
        format!("{s}"),
        format!("{:.3} ms", r.mean_ms()),
        format!("{:.2} GB/s out", bytes / r.mean_ns()),
    ]);

    // Whole sparse conv (pack + GEMM + alloc) on persistent pools of 1
    // and 4 workers — the measured loop never spawns a thread.
    let wt = Tensor::random(&[64, 64, 3, 3], &mut rng, -0.5, 0.5);
    let op = Conv2dSparseCnhw::new_adaptive(s, &wt, v, tile, 0.5);
    let pool1 = bench_pool(1);
    let pool4 = bench_pool(4);
    let r1 = bench("conv1t", cfg, || op.run(&x, &pool1));
    let r4 = bench("conv4t", cfg, || op.run(&x, &pool4));
    rep.record(
        "conv sparse 50% 64ch56x56",
        RecordConfig::new(0, tile, 1),
        &r1.summary,
        Some(0.5 * flops),
    );
    rep.record(
        "conv sparse 50% 64ch56x56",
        RecordConfig::new(0, tile, 4),
        &r4.summary,
        Some(0.5 * flops),
    );
    t.row(&[
        "conv sparse 1thr".into(),
        format!("{s}"),
        format!("{:.3} ms", r1.mean_ms()),
        format!("{:.2}", 0.5 * flops / r1.mean_ns()),
    ]);
    t.row(&[
        "conv sparse 4thr".into(),
        format!("{s}"),
        format!("{:.3} ms", r4.mean_ms()),
        format!("{:.2}", 0.5 * flops / r4.mean_ns()),
    ]);

    // Per-layer parallelism caps on a *small* GEMM (late-stage conv
    // geometry: big K, few output columns → few strips): pool-wide
    // dispatch pays chunk/barrier traffic for work that fits on one or
    // two workers. The acceptance check is that a capped dispatch is no
    // slower than waking the whole pool.
    let (srows, sk, scols) = (64usize, 576usize, 4 * v);
    let sw = rng.normal_vec(srows * sk, 1.0);
    let sa = rng.normal_vec(sk * scols, 1.0);
    let sp = pack_data_matrix(&sa, sk, scols, v);
    let scp = prune_colwise_adaptive(&sw, srows, sk, tile, 0.5);
    let sflops = 0.5 * 2.0 * srows as f64 * sk as f64 * scols as f64;
    let rw = bench("small-wide", cfg, || {
        spmm_colwise_parallel_capped(&scp, &sp, &pool4, None)
    });
    let rc = bench("small-capped", cfg, || {
        spmm_colwise_parallel_capped(&scp, &sp, &pool4, Some(2))
    });
    rep.record(
        "small spmm 50% 64x576x128 pool-wide",
        RecordConfig::new(0, tile, 4),
        &rw.summary,
        Some(sflops),
    );
    rep.record(
        "small spmm 50% 64x576x128 cap=2",
        RecordConfig::new(0, tile, 2),
        &rc.summary,
        Some(sflops),
    );
    t.row(&[
        "small spmm pool-wide".into(),
        format!("{srows}x{sk}x{scols} v{v} 4thr"),
        format!("{:.3} ms", rw.mean_ms()),
        format!("{:.2}", sflops / rw.mean_ns()),
    ]);
    t.row(&[
        "small spmm cap=2".into(),
        format!("{srows}x{sk}x{scols} v{v} 4thr"),
        format!("{:.3} ms", rc.mean_ms()),
        format!("{:.2}", sflops / rc.mean_ns()),
    ]);
    t.print();

    // Load-aware serving: adaptive vs static per-run caps under a deep-
    // queue burst and a reply-paced trickle. The observable is the cap
    // range the adaptive controller chose — a burst slices the 4-worker
    // pool across the 2 executors (caps down to 2), a trickle hands a
    // lone batch every worker (cap 4).
    let res = 32usize;
    let serve = |adaptive: bool, burst: bool| -> (f64, f64, String) {
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(bench_pool(4), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2, 4],
                batch_window: Duration::from_millis(3),
                executors: 2,
                adaptive,
                ..ServerConfig::default()
            },
        );
        let mut rng = XorShiftRng::new(0xBEEF);
        let mut image = || Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0);
        let mut handles = Vec::new();
        if burst {
            // Open-loop: two waves of 16, fired regardless of progress.
            for wave in 0..2 {
                for _ in 0..16 {
                    handles.push(server.submit(image()));
                }
                if wave == 0 {
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        } else {
            // Closed-loop trickle: queue depth is 0 at every dispatch.
            for _ in 0..8 {
                let rx = server.submit(image());
                let _ = rx.recv();
            }
        }
        for h in handles {
            let _ = h.recv();
        }
        let stats = server.shutdown();
        let caps = match stats.cap_range {
            Some((lo, hi)) => format!("{lo}..{hi}"),
            None => "static".into(),
        };
        (stats.throughput_rps, stats.latency.p95 / 1e6, caps)
    };
    let mut st = Table::new(
        "§Serve load-aware caps (ResNet-18 @32, 2 executors on a 4-worker pool)",
        &["mode", "load", "throughput", "p95 latency", "chosen caps"],
    );
    for (mode, adaptive) in [("static", false), ("adaptive", true)] {
        for (load, burst) in [("burst", true), ("trickle", false)] {
            let (rps, p95, caps) = serve(adaptive, burst);
            // Serving throughput is scheduler-noise-bound: recorded for
            // the trajectory but never a CI gate.
            let case = format!("serve {mode} {load} throughput");
            rep.record_value(&case, RecordConfig::NONE, rps, "rps", false);
            st.row(&[
                mode.into(),
                load.into(),
                format!("{rps:.2} req/s"),
                format!("{p95:.1} ms"),
                caps,
            ]);
        }
    }
    st.print();
    println!(
        "adaptive caps follow queue depth: deep bursts slice the pool so \
         batches overlap, trickles give a lone batch all workers"
    );

    // Mixed-traffic serving: the same open-loop 50/50 interactive +
    // background load with tight interactive deadlines, once on the
    // FIFO intake and once on the priority/deadline intake. The
    // observables are the interactive class's p95 and deadline-miss
    // rate — the numbers priority scheduling exists to improve — next
    // to the background p95 it pays for them with. Logits are bitwise
    // identical across the two rows (test-enforced in
    // rust/tests/server_load.rs); this table is about latency only.
    let serve_mixed = |discipline: QueueDiscipline| -> ServerStats {
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(bench_pool(4), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2, 4],
                batch_window: Duration::from_millis(3),
                executors: 2,
                adaptive: true,
                discipline,
                ..ServerConfig::default()
            },
        );
        let mut rng = XorShiftRng::new(0x317ED);
        let mut image = || Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0);
        let mut handles = Vec::new();
        // Three open-loop waves of 16, alternating classes; interactive
        // requests carry a 40 ms deadline.
        for wave in 0..3 {
            for i in 0..16usize {
                handles.push(if i % 2 == 0 {
                    server.submit_with(
                        image(),
                        Priority::Interactive,
                        Some(Duration::from_millis(40)),
                    )
                } else {
                    server.submit_with(image(), Priority::Batch, None)
                });
            }
            if wave < 2 {
                std::thread::sleep(Duration::from_millis(20));
            }
        }
        for h in handles {
            let _ = h.recv();
        }
        server.shutdown()
    };
    let mut mt = Table::new(
        "§Serve mixed traffic (50/50 interactive+background, 40 ms deadlines, \
         ResNet-18 @32, 2 executors on a 4-worker pool)",
        &[
            "intake",
            "interactive p95",
            "interactive miss-rate",
            "background p95",
            "mean batch",
        ],
    );
    for (label, discipline) in [
        ("fifo", QueueDiscipline::Fifo),
        ("priority", QueueDiscipline::Priority),
    ] {
        let stats = serve_mixed(discipline);
        let inter = stats.class(Priority::Interactive);
        let bg = stats.class(Priority::Batch);
        let case = format!("serve mixed {label} interactive p95");
        rep.record_value(&case, RecordConfig::NONE, inter.latency.p95, "ns", false);
        let case = format!("serve mixed {label} miss-rate");
        let miss_pct = inter.miss_rate() * 100.0;
        rep.record_value(&case, RecordConfig::NONE, miss_pct, "percent", false);
        mt.row(&[
            label.into(),
            format!("{:.1} ms", inter.latency.p95 / 1e6),
            format!(
                "{:.0}% ({}/{})",
                inter.miss_rate() * 100.0,
                inter.deadline_missed,
                inter.deadline_total
            ),
            format!("{:.1} ms", bg.latency.p95 / 1e6),
            format!("{:.2}", stats.mean_batch),
        ]);
    }
    mt.print();
    println!(
        "priority intake serves interactive requests ahead of queued \
         background work (starvation-bounded), trading background p95 for \
         interactive p95 and fewer deadline misses"
    );

    // Memory plane: model-load time online-pack vs AOT artifact, and
    // the compute plane's per-request allocation traffic. The counting
    // allocator registered at the top of this file makes the
    // bytes-per-request row a real measurement — production binaries
    // leave the instrumentation inert. Neither record gates CI: load
    // time is dominated by prune/pack (online) vs disk I/O (AOT), and
    // the allocation row is enforced exactly (as zero) by
    // rust/tests/zero_alloc.rs — these rows exist so the perf
    // trajectory shows when either side moves.
    let lres = 64usize;
    let dir = std::env::temp_dir().join("nmprune_perf_hotpath");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let apath = dir.join("resnet18_s50.nmpk");
    Executor::new(
        build_model(ModelArch::ResNet18, 1, lres),
        ExecConfig::sparse_cnhw(bench_pool(1), 0.5),
    )
    .to_artifact()
    .save(&apath)
    .expect("pack artifact");
    let r_online = bench("load-online", cfg, || {
        Executor::new(
            build_model(ModelArch::ResNet18, 1, lres),
            ExecConfig::sparse_cnhw(bench_pool(1), 0.5),
        )
    });
    let r_aot = bench("load-aot", cfg, || {
        let art = PackedArtifact::load(&apath).expect("load artifact");
        Executor::from_artifact(
            build_model(ModelArch::ResNet18, 1, lres),
            bench_pool(1),
            &art,
        )
        .expect("artifact matches graph")
    });
    rep.record_value(
        "model load online pack resnet18@64",
        RecordConfig::NONE,
        r_online.summary.median,
        "ns",
        false,
    );
    rep.record_value(
        "model load AOT artifact resnet18@64",
        RecordConfig::NONE,
        r_aot.summary.median,
        "ns",
        false,
    );
    let exec = Executor::new(
        build_model(ModelArch::ResNet18, 1, lres),
        ExecConfig::sparse_cnhw(bench_pool(1), 0.5),
    );
    let mut arena = exec.scratch();
    let x = Tensor::random(&[1, lres, lres, 3], &mut rng, 0.0, 1.0);
    exec.run_in(&x, &mut arena);
    let (_, mem) = allocwatch::scoped(|| {
        exec.run_in(&x, &mut arena);
    });
    rep.record_value(
        "compute-plane bytes per request resnet18@64",
        RecordConfig::new(0, 0, 1),
        mem.bytes as f64,
        "bytes",
        false,
    );
    let mut pt = Table::new(
        "§Memory plane (ResNet-18 @64, sparse 50%, 1-worker pool)",
        &["metric", "value"],
    );
    pt.row(&[
        "model load, online pack".into(),
        format!("{:.1} ms", r_online.mean_ms()),
    ]);
    pt.row(&[
        "model load, AOT artifact".into(),
        format!("{:.1} ms", r_aot.mean_ms()),
    ]);
    pt.row(&[
        "compute plane per request (warmed arena)".into(),
        format!("{} allocs / {} bytes", mem.allocs, mem.bytes),
    ]);
    pt.print();
    std::fs::remove_dir_all(&dir).ok();

    // End-to-end dtype pair: the same graph and warmed arena at f32 and
    // int8 (per-layer requantize epilogues included). Whole-request
    // latency is scheduler-noise-bound, so both rows are trajectory-
    // only (never a CI gate); the kernel-level int8 speedup is gated
    // above.
    let mut icfg = ExecConfig::sparse_cnhw(bench_pool(1), 0.5);
    icfg.default_choice.dtype = Dtype::I8;
    let iexec = Executor::new(build_model(ModelArch::ResNet18, 1, lres), icfg);
    let mut iarena = iexec.scratch();
    iexec.run_in(&x, &mut iarena);
    let r_f32 = bench("e2e-f32", cfg, || exec.run_in(&x, &mut arena));
    let r_i8 = bench("e2e-i8", cfg, || iexec.run_in(&x, &mut iarena));
    rep.record_value(
        "e2e request resnet18@64 sparse 50%",
        RecordConfig::new(0, 0, 1),
        r_f32.summary.median,
        "ns",
        false,
    );
    rep.record_value(
        "e2e request resnet18@64 sparse 50%",
        RecordConfig::new(0, 0, 1).with_dtype(Dtype::I8),
        r_i8.summary.median,
        "ns",
        false,
    );
    println!(
        "e2e dtype pair (ResNet-18 @64, sparse 50%, 1 thread): \
         f32 {:.2} ms vs i8 {:.2} ms per request",
        r_f32.mean_ms(),
        r_i8.mean_ms()
    );

    println!(
        "small-layer dispatch: cap=2 {:.3} ms vs pool-wide {:.3} ms ({})",
        rc.mean_ms(),
        rw.mean_ms(),
        if rc.summary.median <= rw.summary.median * 1.05 {
            "capped is no slower — per-layer caps pay off"
        } else {
            "pool-wide won here — tuner would keep the full pool for this layer"
        }
    );
    rep.finish();
}
