//! End-to-end driver: all three layers composed on a real workload.
//!
//! This is the proof that the stack holds together:
//!
//!   L1  Pallas column-wise-SpMM + fused im2col/pack kernels …
//!   L2  … inside the jax `smallcnn` forward, AOT-lowered once by
//!       `make artifacts` to HLO text, …
//!   L3  … compiled and served here by the Rust coordinator: a dynamic
//!       batcher groups incoming requests to the largest available AOT
//!       batch variant (b ∈ {1, 2, 4}) and executes via PJRT — Python is
//!       never on the request path.
//!
//! The driver (1) verifies numerics against the Python-side expected
//! output for the saved sample input, (2) serves a stream of requests
//! through the batcher, and (3) reports throughput and latency, which
//! EXPERIMENTS.md records.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pjrt_serving`

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use nmprune::runtime::{load_flat_f32, read_manifest, PjrtRuntime};
use nmprune::util::stats::Summary;
use nmprune::util::{allclose, XorShiftRng};

const RES: usize = 16; // smallcnn artifact resolution (aot.py --res)
const BATCHES: [usize; 3] = [4, 2, 1]; // largest-first

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = dir.join("manifest.tsv");
    if !manifest.exists() {
        eprintln!("run `make artifacts` first (no {manifest:?})");
        std::process::exit(1);
    }

    // ---- L3 runtime: compile every artifact once ----
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let entries = read_manifest(&manifest).expect("manifest");
    for e in &entries {
        rt.load_hlo_text(&e.name, &e.file, e.input_arity)
            .unwrap_or_else(|err| panic!("compile {}: {err}", e.name));
    }
    println!(
        "platform {}: compiled {} artifacts",
        rt.platform(),
        entries.len()
    );

    // ---- model operands: the pruned weights are runtime parameters ----
    // smallcnn_b* inputs are [x, op1..op7]; load the saved operands.
    let operands: Vec<(Vec<usize>, Vec<f32>)> = (1..8)
        .map(|i| {
            load_flat_f32(&dir.join(format!("smallcnn_b1.input{i}.txt"))).expect("operand")
        })
        .collect();

    // ---- numerics parity: serve the saved sample input, compare ----
    let (x_dims, x_data) = load_flat_f32(&dir.join("smallcnn_b1.input0.txt")).unwrap();
    let (_, expected) = load_flat_f32(&dir.join("smallcnn_b1.expected0.txt")).unwrap();
    let logits = run_batch(&rt, &x_data, &x_dims, &operands);
    assert!(
        allclose(&logits, &expected, 1e-4, 1e-5),
        "Rust-served logits disagree with the Python-side expected output"
    );
    println!("numerics parity vs python: OK ({} logits)", expected.len());

    // ---- serving loop with a dynamic batcher ----
    let n_requests = std::env::args()
        .skip_while(|a| a != "--requests")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64usize);
    let mut rng = XorShiftRng::new(11);
    let mut queue: VecDeque<(usize, Vec<f32>, Instant)> = (0..n_requests)
        .map(|i| {
            let img = rng.normal_vec(RES * RES * 3, 1.0);
            (i, img, Instant::now())
        })
        .collect();

    let t0 = Instant::now();
    let mut latencies = Vec::new();
    let mut batches_used = Vec::new();
    let mut served = 0usize;
    while !queue.is_empty() {
        // Batcher policy: largest AOT batch variant that the queue fills.
        let b = *BATCHES.iter().find(|&&b| queue.len() >= b).unwrap();
        let reqs: Vec<_> = queue.drain(..b).collect();
        let mut x = Vec::with_capacity(b * RES * RES * 3);
        for (_, img, _) in &reqs {
            x.extend_from_slice(img);
        }
        let dims = [b, RES, RES, 3];
        let out = run_batch(&rt, &x, &dims, &operands);
        let classes = out.len() / b;
        for (slot, (_, _, enq)) in reqs.iter().enumerate() {
            let _logits = &out[slot * classes..(slot + 1) * classes];
            latencies.push(enq.elapsed().as_nanos() as f64);
            served += 1;
        }
        batches_used.push(b);
    }
    let wall = t0.elapsed();
    let lat = Summary::of(&latencies);
    let mean_batch =
        batches_used.iter().sum::<usize>() as f64 / batches_used.len() as f64;
    println!(
        "served {served} requests in {:.1} ms  ({:.0} req/s, mean batch {mean_batch:.2})",
        wall.as_secs_f64() * 1e3,
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "latency: mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms",
        lat.mean / 1e6,
        lat.median / 1e6,
        lat.p95 / 1e6
    );
}

/// Execute the right smallcnn batch variant for `x[b, RES, RES, 3]`.
fn run_batch(
    rt: &PjrtRuntime,
    x: &[f32],
    x_dims: &[usize],
    operands: &[(Vec<usize>, Vec<f32>)],
) -> Vec<f32> {
    let b = x_dims[0];
    let name = format!("smallcnn_b{b}");
    let mut inputs: Vec<(&[f32], &[usize])> = vec![(x, x_dims)];
    for (dims, data) in operands {
        inputs.push((data, dims));
    }
    let mut outs = rt
        .execute_f32(&name, &inputs)
        .unwrap_or_else(|e| panic!("execute {name}: {e}"));
    outs.remove(0)
}
