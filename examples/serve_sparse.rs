//! Serving demo — the Layer-3 coordinator under load.
//!
//! Starts the dynamic-batching inference server with a sparse (50%)
//! ResNet-18, drives it with an open-loop load generator, and reports
//! throughput, mean batch size, and the latency distribution — then
//! repeats with the dense NHWC baseline for comparison.
//!
//! `--executors N` runs N concurrent batch executors against the one
//! shared pool — with >1, one batch computes while the next forms.
//! `--adaptive` switches the server to load-aware mode: the per-batch
//! thread cap and the number of actively draining dispatchers follow
//! queue depth (deep burst → slice the pool so batches overlap; trickle
//! → a lone batch takes every worker, surplus dispatchers park). The
//! chosen cap range is printed per configuration. `--pin` core-pins the
//! pool workers (Linux `sched_setaffinity`; a graceful no-op
//! elsewhere — `NMPRUNE_PIN=1` does the same for shared pools).
//!
//! The load generator is open-loop and bursty: `--bursts B` waves of
//! `--burst N` requests, fired every `--gap-ms G` regardless of how far
//! the server got — queue depth genuinely builds up during a wave and
//! drains between waves, which is what the adaptive controller reacts
//! to. `--bursts 1` degenerates to the old single-burst behaviour.
//!
//! Run: `cargo run --release --example serve_sparse -- [--res 112]
//!       [--threads 2] [--executors 2] [--adaptive] [--pin]
//!       [--bursts 4] [--burst 8] [--gap-ms 30]`

use std::sync::Arc;

use nmprune::engine::{ExecConfig, Server, ServerConfig};
use nmprune::models::{build_model, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::util::cli::Args;
use nmprune::util::{ThreadPool, XorShiftRng};

struct Load {
    bursts: usize,
    burst: usize,
    gap: std::time::Duration,
}

fn drive(label: &str, cfg: ExecConfig, res: usize, load: &Load, executors: usize, adaptive: bool) {
    let server = Server::start(
        |b| build_model(ModelArch::ResNet18, b, res),
        cfg,
        res,
        ServerConfig {
            batch_sizes: vec![1, 2, 4],
            batch_window: std::time::Duration::from_millis(10),
            executors,
            adaptive,
        },
    );
    let mut rng = XorShiftRng::new(99);
    // Open-loop waves: each burst is submitted in full, then the
    // generator sleeps for the gap — it never waits for replies, so
    // queue depth reflects the offered load, not the service rate.
    let mut handles = Vec::new();
    for b in 0..load.bursts {
        for _ in 0..load.burst {
            handles.push(server.submit(Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0)));
        }
        if b + 1 < load.bursts {
            std::thread::sleep(load.gap);
        }
    }
    for h in handles.drain(..) {
        let reply = h.recv().expect("reply");
        assert_eq!(reply.logits.len(), 1000, "full logits per request");
    }
    let stats = server.shutdown();
    let caps = match stats.cap_range {
        Some((lo, hi)) => format!("caps={lo}..{hi}"),
        None => "caps=static".into(),
    };
    println!(
        "{label:<14} served={:<4} throughput={:>7.2} req/s  mean_batch={:.2}  \
         latency p50={:.0} ms p95={:.0} ms  {caps}",
        stats.served,
        stats.throughput_rps,
        stats.mean_batch,
        stats.latency.median / 1e6,
        stats.latency.p95 / 1e6,
    );
}

fn main() {
    let args = Args::from_env();
    let res = args.get_parsed("res", 112usize);
    let threads = args.get_parsed("threads", 2usize);
    let executors = args.get_parsed("executors", 2usize);
    let adaptive = args.has_flag("adaptive");
    let pin = args.has_flag("pin");
    let load = Load {
        bursts: args.get_parsed("bursts", 4usize),
        burst: args.get_parsed("burst", 8usize),
        gap: std::time::Duration::from_millis(args.get_parsed("gap-ms", 30u64)),
    };
    // One persistent pool serves every configuration below; the
    // executors share it without oversubscription (per-run caps).
    let pool = if pin {
        Arc::new(ThreadPool::new_pinned(threads))
    } else {
        ThreadPool::shared(threads)
    };
    println!(
        "serving ResNet-18 @{res}, {}x{} requests ({}ms gaps) per config, \
         {executors} batch executors on one {threads}-worker pool \
         (adaptive={adaptive}, pinned={})\n",
        load.bursts,
        load.burst,
        load.gap.as_millis(),
        if pin { "requested" } else { "no" },
    );
    drive(
        "sparse 50%",
        ExecConfig::sparse_cnhw(pool.clone(), 0.5),
        res,
        &load,
        executors,
        adaptive,
    );
    drive(
        "sparse 75%",
        ExecConfig::sparse_cnhw(pool.clone(), 0.75),
        res,
        &load,
        executors,
        adaptive,
    );
    drive(
        "dense CNHW",
        ExecConfig::dense_cnhw(pool.clone()),
        res,
        &load,
        executors,
        adaptive,
    );
    drive(
        "dense NHWC",
        ExecConfig::dense_nhwc(pool),
        res,
        &load,
        executors,
        adaptive,
    );
    println!("\n(paper Table 2: sparse ResNet-18 up to 4.0x over the dense NHWC baseline)");
}
