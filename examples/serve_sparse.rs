//! Serving demo — the Layer-3 coordinator under load.
//!
//! Starts the dynamic-batching inference server with a sparse (50%)
//! ResNet-18, drives it with an open-loop load generator, and reports
//! throughput, mean batch size, and the latency distribution — then
//! repeats with the dense NHWC baseline for comparison.
//!
//! `--executors N` runs N concurrent batch executors against the one
//! shared pool — with >1, one batch computes while the next forms.
//! `--adaptive` switches the server to load-aware mode: the batch size,
//! the per-batch thread cap and the number of actively draining
//! dispatchers follow the queue gauge (deep burst → largest compiled
//! batch and a sliced pool so batches overlap; trickle or tight
//! deadline → smallest batch, a lone batch takes every worker, surplus
//! dispatchers park). The chosen cap range and batch-size histogram are
//! printed per configuration. `--pin` core-pins the pool workers (Linux
//! `sched_setaffinity`; a graceful no-op elsewhere — `NMPRUNE_PIN=1`
//! does the same for shared pools).
//!
//! Mixed traffic: `--prio-mix F` submits fraction F of each burst as
//! `Interactive` (with a `--deadline-ms D` deadline, default 50) and
//! the rest as background `Batch` traffic on the priority/deadline
//! intake; `--fifo` keeps the FIFO intake so the two disciplines can be
//! compared under the identical load. Per-class p50/p95 and
//! deadline-miss rates are printed whenever both classes are present.
//!
//! The load generator is open-loop and bursty: `--bursts B` waves of
//! `--burst N` requests, fired every `--gap-ms G` regardless of how far
//! the server got — queue depth genuinely builds up during a wave and
//! drains between waves, which is what the adaptive controller reacts
//! to. `--bursts 1` degenerates to the old single-burst behaviour.
//!
//! AOT weights: `--artifact F` serves the sparse-50% configuration
//! from a packed weight artifact (packing it on first run if `F` does
//! not exist yet) — model load becomes a validation pass, and the
//! served logits are bitwise identical to the online-packed run.
//!
//! Run: `cargo run --release --example serve_sparse -- [--res 112]
//!       [--threads 2] [--executors 2] [--adaptive] [--pin]
//!       [--bursts 4] [--burst 8] [--gap-ms 30]
//!       [--prio-mix 0.5] [--deadline-ms 50] [--fifo]
//!       [--artifact resnet18_sparse.nmpk]`

use std::sync::Arc;

use nmprune::engine::{
    ExecConfig, Executor, Priority, QueueDiscipline, Server, ServerConfig,
};
use nmprune::models::{build_model, ModelArch};
use nmprune::runtime::PackedArtifact;
use nmprune::tensor::Tensor;
use nmprune::util::cli::Args;
use nmprune::util::{ThreadPool, XorShiftRng};

struct Load {
    bursts: usize,
    burst: usize,
    gap: std::time::Duration,
    /// Fraction of each burst submitted as Interactive (1.0 = all).
    prio_mix: f64,
    /// Deadline attached to interactive requests (mixed traffic only).
    deadline: Option<std::time::Duration>,
    discipline: QueueDiscipline,
}

fn drive(
    label: &str,
    cfg: ExecConfig,
    res: usize,
    load: &Load,
    executors: usize,
    adaptive: bool,
    artifact: Option<&PackedArtifact>,
) {
    let scfg = ServerConfig {
        batch_sizes: vec![1, 2, 4],
        batch_window: std::time::Duration::from_millis(10),
        executors,
        adaptive,
        discipline: load.discipline,
        ..ServerConfig::default()
    };
    let server = match artifact {
        // AOT path: executors validate and adopt the packed weights —
        // bitwise the same logits as the online-packed run below.
        Some(art) => Server::start_packed(
            |b| build_model(ModelArch::ResNet18, b, res),
            cfg.pool.clone(),
            art,
            scfg,
        )
        .expect("artifact matches the serving model"),
        None => Server::start(|b| build_model(ModelArch::ResNet18, b, res), cfg, res, scfg),
    };
    // Mixed-traffic reporting follows what was actually configured —
    // `--prio-mix 1.0 --deadline-ms 10` still tracks (and must print)
    // deadline misses even though only one class is in play.
    let mixed = load.prio_mix < 1.0 || load.deadline.is_some();
    let mut rng = XorShiftRng::new(99);
    // Open-loop waves: each burst is submitted in full, then the
    // generator sleeps for the gap — it never waits for replies, so
    // queue depth reflects the offered load, not the service rate.
    let mut handles = Vec::new();
    let mut n_interactive = 0usize;
    let mut submitted = 0usize;
    for b in 0..load.bursts {
        for _ in 0..load.burst {
            let image = Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0);
            submitted += 1;
            // Deterministic interleave tracking the target mix.
            let interactive =
                !mixed || (n_interactive as f64) < submitted as f64 * load.prio_mix;
            handles.push(if interactive {
                n_interactive += 1;
                server.submit_with(image, Priority::Interactive, load.deadline)
            } else {
                server.submit_with(image, Priority::Batch, None)
            });
        }
        if b + 1 < load.bursts {
            std::thread::sleep(load.gap);
        }
    }
    for h in handles.drain(..) {
        let reply = h.recv().expect("reply");
        assert_eq!(reply.logits.len(), 1000, "full logits per request");
    }
    let stats = server.shutdown();
    let caps = match stats.cap_range {
        Some((lo, hi)) => format!("caps={lo}..{hi}"),
        None => "caps=static".into(),
    };
    let hist: Vec<String> = stats
        .batch_hist
        .iter()
        .map(|(b, n)| format!("{b}x{n}"))
        .collect();
    println!(
        "{label:<14} served={:<4} throughput={:>7.2} req/s  mean_batch={:.2}  \
         latency p50={:.0} ms p95={:.0} ms  {caps}  batches[{}]",
        stats.served,
        stats.throughput_rps,
        stats.mean_batch,
        stats.latency.median / 1e6,
        stats.latency.p95 / 1e6,
        hist.join(" "),
    );
    if mixed {
        for p in Priority::ALL {
            let cls = stats.class(p);
            if cls.served == 0 {
                continue;
            }
            println!(
                "  {:<12} served={:<4} p50={:.0} ms p95={:.0} ms  miss {}/{} ({:.0}%)",
                p.name(),
                cls.served,
                cls.latency.median / 1e6,
                cls.latency.p95 / 1e6,
                cls.deadline_missed,
                cls.deadline_total,
                cls.miss_rate() * 100.0,
            );
        }
    }
}

fn main() {
    let args = Args::from_env();
    let res = args.get_parsed("res", 112usize);
    let threads = args.get_parsed("threads", 2usize);
    let executors = args.get_parsed("executors", 2usize);
    let adaptive = args.has_flag("adaptive");
    let pin = args.has_flag("pin");
    let prio_mix = args.get_parsed("prio-mix", 1.0f64).clamp(0.0, 1.0);
    // Same rule as `nmprune serve`: either flag opts into mixed-traffic
    // mode (so `--deadline-ms` alone is never a silent no-op).
    let mixed = args.get("prio-mix").is_some() || args.get("deadline-ms").is_some();
    let load = Load {
        bursts: args.get_parsed("bursts", 4usize),
        burst: args.get_parsed("burst", 8usize),
        gap: std::time::Duration::from_millis(args.get_parsed("gap-ms", 30u64)),
        prio_mix,
        deadline: if mixed {
            Some(std::time::Duration::from_millis(
                args.get_parsed("deadline-ms", 50u64),
            ))
        } else {
            None
        },
        discipline: if mixed && !args.has_flag("fifo") {
            QueueDiscipline::Priority
        } else {
            QueueDiscipline::Fifo
        },
    };
    // One persistent pool serves every configuration below; the
    // executors share it without oversubscription (per-run caps).
    let pool = if pin {
        Arc::new(ThreadPool::new_pinned(threads))
    } else {
        ThreadPool::shared(threads)
    };
    // `--artifact F`: serve the sparse-50% configuration from an
    // AOT-packed weight artifact, packing one on first run so the demo
    // is self-contained.
    let artifact = args.get("artifact").map(|p| {
        let path = std::path::Path::new(p);
        if !path.exists() {
            Executor::new(
                build_model(ModelArch::ResNet18, 4, res),
                ExecConfig::sparse_cnhw(pool.clone(), 0.5),
            )
            .to_artifact()
            .save(path)
            .expect("write artifact");
            println!("packed sparse-50% ResNet-18 @{res} -> {p}");
        }
        let t0 = std::time::Instant::now();
        let art = PackedArtifact::load(path).expect("load artifact");
        println!(
            "validated + loaded {p} in {:.1} ms ({} layers, {:.1} MiB weights)",
            t0.elapsed().as_secs_f64() * 1e3,
            art.layers.len(),
            art.weight_bytes() as f64 / (1 << 20) as f64,
        );
        art
    });
    println!(
        "serving ResNet-18 @{res}, {}x{} requests ({}ms gaps) per config, \
         {executors} batch executors on one {threads}-worker pool \
         (adaptive={adaptive}, pinned={}, intake={:?}, prio-mix={:.2})\n",
        load.bursts,
        load.burst,
        load.gap.as_millis(),
        if pin { "requested" } else { "no" },
        load.discipline,
        load.prio_mix,
    );
    drive(
        "sparse 50%",
        ExecConfig::sparse_cnhw(pool.clone(), 0.5),
        res,
        &load,
        executors,
        adaptive,
        artifact.as_ref(),
    );
    drive(
        "sparse 75%",
        ExecConfig::sparse_cnhw(pool.clone(), 0.75),
        res,
        &load,
        executors,
        adaptive,
        None,
    );
    drive(
        "dense CNHW",
        ExecConfig::dense_cnhw(pool.clone()),
        res,
        &load,
        executors,
        adaptive,
        None,
    );
    drive(
        "dense NHWC",
        ExecConfig::dense_nhwc(pool),
        res,
        &load,
        executors,
        adaptive,
        None,
    );
    println!("\n(paper Table 2: sparse ResNet-18 up to 4.0x over the dense NHWC baseline)");
}
