//! Serving demo — the Layer-3 coordinator under load.
//!
//! Starts the dynamic-batching inference server with a sparse (50%)
//! ResNet-18, fires a burst of requests from several client threads, and
//! reports throughput, mean batch size, and the latency distribution —
//! then repeats with the dense NHWC baseline for comparison.
//!
//! `--executors N` runs N concurrent batch executors against the one
//! shared pool (the server slices per-layer parallelism caps so they
//! never oversubscribe it) — with >1, one batch computes while the
//! next forms.
//!
//! Run: `cargo run --release --example serve_sparse -- [--requests 24]
//!       [--res 112] [--threads 2] [--executors 2]`

use nmprune::engine::{ExecConfig, Server, ServerConfig};
use nmprune::models::{build_model, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::util::cli::Args;
use nmprune::util::{ThreadPool, XorShiftRng};

fn drive(label: &str, cfg: ExecConfig, res: usize, requests: usize, executors: usize) {
    let server = Server::start(
        |b| build_model(ModelArch::ResNet18, b, res),
        cfg,
        res,
        ServerConfig {
            batch_sizes: vec![1, 2, 4],
            batch_window: std::time::Duration::from_millis(10),
            executors,
        },
    );
    let mut rng = XorShiftRng::new(99);
    // Two bursts: a full burst (batcher should coalesce), then a trickle
    // (batcher should fall back to singles after the window).
    let mut handles = Vec::new();
    for _ in 0..requests {
        handles.push(server.submit(Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0)));
    }
    for h in handles.drain(..) {
        let reply = h.recv().expect("reply");
        assert_eq!(reply.logits.len(), 1000, "full logits per request");
    }
    let stats = server.shutdown();
    println!(
        "{label:<14} served={:<4} throughput={:>7.2} req/s  mean_batch={:.2}  \
         latency p50={:.0} ms p95={:.0} ms",
        stats.served,
        stats.throughput_rps,
        stats.mean_batch,
        stats.latency.median / 1e6,
        stats.latency.p95 / 1e6,
    );
}

fn main() {
    let args = Args::from_env();
    let requests = args.get_parsed("requests", 24usize);
    let res = args.get_parsed("res", 112usize);
    let threads = args.get_parsed("threads", 2usize);
    let executors = args.get_parsed("executors", 2usize);
    // One persistent pool serves every configuration below; the
    // executors share it without oversubscription (per-run caps).
    let pool = ThreadPool::shared(threads);
    println!(
        "serving ResNet-18 @{res}, {requests} requests per config, \
         {executors} batch executors on one {threads}-worker pool\n"
    );
    drive("sparse 50%", ExecConfig::sparse_cnhw(pool.clone(), 0.5), res, requests, executors);
    drive("sparse 75%", ExecConfig::sparse_cnhw(pool.clone(), 0.75), res, requests, executors);
    drive("dense CNHW", ExecConfig::dense_cnhw(pool.clone()), res, requests, executors);
    drive("dense NHWC", ExecConfig::dense_nhwc(pool), res, requests, executors);
    println!("\n(paper Table 2: sparse ResNet-18 up to 4.0x over the dense NHWC baseline)");
}
