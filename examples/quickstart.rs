//! Quickstart — the library in ~60 lines.
//!
//! Builds one convolution layer, prunes it column-wise at 50% sparsity
//! (adaptive M = K, the paper's full method), runs the dense and sparse
//! paths on the same input, checks that the sparse output equals a dense
//! convolution with the masked weights, and prints the speedup.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Instant;

use nmprune::conv::{Conv2dDenseCnhw, Conv2dSparseCnhw, ConvShape};
use nmprune::gemm::matmul_ref;
use nmprune::im2col::im2col_cnhw;
use nmprune::tensor::Tensor;
use nmprune::util::{allclose, ThreadPool, XorShiftRng};

fn main() {
    // A ResNet-ish 3×3 layer: 64→64 channels on a 56×56 map, batch 1.
    let shape = ConvShape::square(1, 64, 56, 64, 3, 1, 1);
    let mut rng = XorShiftRng::new(42);
    let x = Tensor::random(&[64, 1, 56, 56], &mut rng, -1.0, 1.0); // CNHW
    let w = Tensor::random(&[64, 64, 3, 3], &mut rng, -0.5, 0.5); // OIHW

    // Micro-kernel template parameters: strip width V = 16 lanes
    // (LMUL=2 on a 256-bit RVV machine) and tile T = 8 accumulators.
    let (v, tile) = (16, 8);

    let dense = Conv2dDenseCnhw::new(shape, &w, v, tile);
    let sparse = Conv2dSparseCnhw::new_adaptive(shape, &w, v, tile, 0.5);
    println!(
        "pruned {:.1}% of weights (column-wise, M = K = {})",
        100.0 * sparse.sparsity(),
        shape.k()
    );

    // Warmup + timed runs on a single persistent worker (serial path).
    let pool = ThreadPool::new(1);
    let y_dense = dense.run(&x, &pool);
    let y_sparse = sparse.run(&x, &pool);
    let t0 = Instant::now();
    let _ = dense.run(&x, &pool);
    let t_dense = t0.elapsed();
    let t1 = Instant::now();
    let _ = sparse.run(&x, &pool);
    let t_sparse = t1.elapsed();

    // Correctness: the sparse path must equal a reference GEMM with the
    // decompressed (masked) filter matrix over the im2col data matrix.
    let masked = sparse.weights.decompress(); // [c_out, K], zeros pruned
    let a = im2col_cnhw(&x, &shape);
    let y_ref = matmul_ref(&masked, &a, shape.c_out, shape.k(), shape.gemm_cols());
    assert!(
        allclose(&y_sparse.data, &y_ref, 1e-4, 1e-5),
        "sparse path disagrees with masked dense reference"
    );
    assert_eq!(y_dense.shape, y_sparse.shape);

    println!(
        "dense:  {:7.2} ms\nsparse: {:7.2} ms  ({:.2}x speedup, outputs verified)",
        t_dense.as_secs_f64() * 1e3,
        t_sparse.as_secs_f64() * 1e3,
        t_dense.as_secs_f64() / t_sparse.as_secs_f64()
    );
}
