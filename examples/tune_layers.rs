//! Auto-tuning walkthrough (§3.3) — profile the (T, LMUL) template
//! space per conv layer on two backends and show why profiling must
//! happen *on the deployment target* (AITemplate's core argument).
//!
//! For each representative ResNet-50 layer:
//!   * sim-tune   — deterministic RVV-simulator cycles (the paper's K1
//!                  twin): what you would ship to the RISC-V board;
//!   * native-tune — wall-clock on *this* host: what you ship here.
//!
//! The two winners differ per layer — a config tuned for one machine is
//! routinely suboptimal on another, which is exactly why the framework
//! re-profiles per target instead of hard-coding tile/LMUL tables.
//!
//! Run: `cargo run --release --example tune_layers -- [--sparsity 0.5]`

use nmprune::benchlib::{bench, BenchConfig, Table};
use nmprune::conv::Conv2dSparseCnhw;
use nmprune::models::resnet50_fig5_layers;
use nmprune::tensor::Tensor;
use nmprune::tuner::{candidate_space, tune_native, tune_sim_colwise};
use nmprune::util::cli::Args;
use nmprune::util::{ThreadPool, XorShiftRng};

fn main() {
    let args = Args::from_env();
    let sparsity = args.get_parsed("sparsity", 0.5f64);
    let tile_cap = args.get_parsed("tile-cap", 8usize);
    let threads = args.get_parsed("threads", 2usize);
    println!(
        "candidate space: {} (T, LMUL) pairs, sparsity {sparsity}",
        candidate_space(tile_cap).len()
    );

    let mut t = Table::new(
        "Per-layer tuning: sim-chosen (LMUL, T) vs native-chosen (LMUL, T, P), and the native win",
        &[
            "layer",
            "sim (LMUL,T)",
            "native (LMUL,T,P)",
            "native tuned ms",
            "static (4,7) ms",
            "tuned gain",
            "same winner?",
        ],
    );

    let cfg = BenchConfig::quick();
    let pool = ThreadPool::shared(threads);
    let mut agree = 0usize;
    let layers = resnet50_fig5_layers(1);
    for l in &layers {
        let s = l.shape;
        let rs = tune_sim_colwise(&s, sparsity, tile_cap);
        let rn = tune_native(&s, Some(sparsity), &pool, tile_cap);

        let mut rng = XorShiftRng::new(0x7E ^ s.c_out as u64);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);

        // The tuned operator replays the full native choice — including
        // the per-layer parallelism degree; the static baseline always
        // wakes the whole pool.
        let tuned = Conv2dSparseCnhw::new_adaptive(s, &w, rn.best.v, rn.best.tile, sparsity)
            .with_thread_cap(rn.best.threads);
        let fixed = Conv2dSparseCnhw::new_adaptive(s, &w, 32, 7, sparsity);
        let bt = bench("tuned", cfg, || tuned.run(&x, &pool));
        let bf = bench("static", cfg, || fixed.run(&x, &pool));

        let same = rs.best.lmul == rn.best.lmul && rs.best.tile == rn.best.tile;
        agree += same as usize;
        t.row(&[
            l.name.into(),
            format!("({},{})", rs.best.lmul, rs.best.tile),
            format!("({},{},{})", rn.best.lmul, rn.best.tile, rn.best.threads),
            format!("{:.3}", bt.mean_ms()),
            format!("{:.3}", bf.mean_ms()),
            format!("{:.2}x", bf.mean_ns() / bt.mean_ns()),
            format!("{same}"),
        ]);
    }
    t.print();
    println!(
        "sim and native winners agree on {agree}/{} layers — profiling must run on the \
         deployment target (§3.3); a static (LMUL, T) is inadequate (§4.4)",
        layers.len()
    );
}
