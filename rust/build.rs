//! Build probe: gate AVX-512 kernels on the compiler that is actually
//! building us. The `core::arch::x86_64::_mm512_*` intrinsics are only
//! stable from rustc 1.89, but the crate floats on `channel = "stable"`
//! with `rust-version = "1.75"` — so the AVX-512 backend is compiled in
//! only when the probe proves the compiler supports it, and the scalar /
//! AVX2 / NEON backends carry every older toolchain.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-..)" → 89. Nightly/beta suffixes parse too.
    let semver = text.split_whitespace().nth(1)?;
    let mut parts = semver.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    if major == 1 {
        Some(minor)
    } else {
        // A hypothetical 2.x compiler supports everything 1.89 does.
        Some(u32::MAX)
    }
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    let minor = rustc_minor().unwrap_or(0);
    // `--check-cfg` only exists from 1.80; older cargos would choke on
    // the directive itself, so it is version-gated like the cfg it
    // declares.
    if minor >= 80 {
        println!("cargo:rustc-check-cfg=cfg(nmprune_avx512)");
    }
    let arch = std::env::var("CARGO_CFG_TARGET_ARCH").unwrap_or_default();
    if arch == "x86_64" && minor >= 89 {
        println!("cargo:rustc-cfg=nmprune_avx512");
    }
}
