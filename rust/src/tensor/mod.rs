//! Dense f32 tensors with explicit data layouts.
//!
//! The paper's pipeline converts activations NHWC → CNHW at model entry,
//! keeps CNHW through all conv layers, and converts back at the end
//! (§4.1.2, §5). Weights arrive OIHW (framework order) and are flattened
//! to the `[C_out, K_h*K_w*C_in]` GEMM filter matrix. This module owns
//! those shapes and conversions.

pub mod dtype;
pub mod layout;

pub use dtype::Dtype;
pub use layout::{ActLayout, WeightLayout};

/// A dense, row-major f32 tensor of arbitrary rank.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// Tensor from data; checks element count.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Tensor filled with uniform random values from `rng` in [lo, hi).
    pub fn random(shape: &[usize], rng: &mut crate::util::XorShiftRng, lo: f32, hi: f32) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: rng.uniform_vec(n, lo, hi),
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rank (number of dimensions).
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides of the current shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.shape[i + 1];
        }
        strides
    }

    /// Flat index of a multi-dimensional coordinate (debug-checked).
    /// Strides are folded in-line rather than materialised — `at` /
    /// `at_mut` sit inside op inner loops on the zero-alloc serving
    /// path, so this must not heap-allocate.
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        let mut stride = 1;
        for d in (0..self.shape.len()).rev() {
            debug_assert!(idx[d] < self.shape[d], "index {} out of bound {}", idx[d], self.shape[d]);
            off += idx[d] * stride;
            stride *= self.shape[d];
        }
        off
    }

    /// Element access by coordinate.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    /// Mutable element access by coordinate.
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let i = self.flat(idx);
        &mut self.data[i]
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// General permutation of axes (out-of-place).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.rank());
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!(p < perm.len() && !seen[p], "bad permutation {perm:?}");
            seen[p] = true;
        }
        let out_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let in_strides = self.strides();
        let mut out = Tensor::zeros(&out_shape);
        let out_strides = out.strides();
        // Iterate over output coordinates via a mixed-radix counter.
        let mut coord = vec![0usize; out_shape.len()];
        for out_flat in 0..out.data.len() {
            let mut in_flat = 0;
            for (d, &c) in coord.iter().enumerate() {
                in_flat += c * in_strides[perm[d]];
            }
            out.data[out_flat] = self.data[in_flat];
            // increment coord
            for d in (0..coord.len()).rev() {
                coord[d] += 1;
                if coord[d] < out_shape[d] {
                    break;
                }
                coord[d] = 0;
            }
        }
        debug_assert_eq!(out.strides(), out_strides);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.rank(), 3);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn flat_index_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "reshape")]
    fn reshape_bad_count_panics() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn permute_transposes_matrix() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = t.permute(&[1, 0]);
        assert_eq!(p.shape, vec![3, 2]);
        assert_eq!(p.data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn permute_identity_is_noop() {
        let mut r = XorShiftRng::new(2);
        let t = Tensor::random(&[3, 4, 5], &mut r, -1.0, 1.0);
        assert_eq!(t.permute(&[0, 1, 2]).data, t.data);
    }

    #[test]
    fn permute_composes_to_identity() {
        let mut r = XorShiftRng::new(3);
        let t = Tensor::random(&[2, 3, 4, 5], &mut r, -1.0, 1.0);
        let p = t.permute(&[3, 1, 0, 2]);
        // inverse of [3,1,0,2] is [2,1,3,0]
        let back = p.permute(&[2, 1, 3, 0]);
        assert_eq!(back.data, t.data);
        assert_eq!(back.shape, t.shape);
    }

    #[test]
    fn at_mut_writes() {
        let mut t = Tensor::zeros(&[2, 2]);
        *t.at_mut(&[1, 1]) = 7.0;
        assert_eq!(t.at(&[1, 1]), 7.0);
        assert_eq!(t.data[3], 7.0);
    }
}
