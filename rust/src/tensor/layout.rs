//! Activation and weight layout conversions.
//!
//! §5 of the paper: NHWC → CNHW is exactly one transpose (move C to the
//! front); CNHW back to NHWC is the inverse. NCHW is implemented too for
//! the layout-comparison discussion (Elsen et al. use NCHW).

use super::Tensor;

/// Activation (feature-map) layouts used in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActLayout {
    /// Batch, Height, Width, Channels — XNNPACK's dense CPU default.
    Nhwc,
    /// Channels, Batch, Height, Width — the paper's layout: W contiguous
    /// and a channel's rows span the whole batch (better strip packing).
    Cnhw,
    /// Batch, Channels, Height, Width — Elsen et al. alternative.
    Nchw,
}

/// Weight layouts. Frameworks store OIHW; the paper's kernels consume the
/// flattened `[C_out, K_h*K_w*C_in]` filter matrix in OHWI order so that
/// the reduction dimension matches the im2col patch order (k-major, then
/// input channel).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WeightLayout {
    /// Out-channels, In-channels, Kernel-H, Kernel-W (torch default).
    Oihw,
    /// Out-channels, Kernel-H, Kernel-W, In-channels (paper Fig. 4).
    Ohwi,
}

/// Convert an activation tensor of shape `[N, H, W, C]` (NHWC) into CNHW
/// `[C, N, H, W]`. One permutation — the cheap conversion §5 argues for.
pub fn nhwc_to_cnhw(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4, "activation must be rank 4");
    x.permute(&[3, 0, 1, 2])
}

/// [`nhwc_to_cnhw`] writing into a caller-provided tensor already shaped
/// `[C, N, H, W]` (zero-alloc hot-path entry for the serving arena).
// nmprune: zero-alloc
pub fn nhwc_to_cnhw_into(x: &Tensor, out: &mut Tensor) {
    assert_eq!(x.rank(), 4, "activation must be rank 4");
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(out.shape, [c, n, h, w], "output tensor shape");
    let hw = h * w;
    for ni in 0..n {
        for p in 0..hw {
            let src = &x.data[(ni * hw + p) * c..(ni * hw + p + 1) * c];
            for (ci, &v) in src.iter().enumerate() {
                out.data[(ci * n + ni) * hw + p] = v;
            }
        }
    }
}

/// CNHW `[C, N, H, W]` back to NHWC `[N, H, W, C]`.
pub fn cnhw_to_nhwc(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4);
    x.permute(&[1, 2, 3, 0])
}

/// NHWC `[N, H, W, C]` to NCHW `[N, C, H, W]`.
pub fn nhwc_to_nchw(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4);
    x.permute(&[0, 3, 1, 2])
}

/// NCHW `[N, C, H, W]` to NHWC `[N, H, W, C]`.
pub fn nchw_to_nhwc(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 4);
    x.permute(&[0, 2, 3, 1])
}

/// OIHW weights `[O, I, Kh, Kw]` to the flattened GEMM filter matrix
/// `[O, Kh*Kw*I]` with k-major ordering (kernel position outer, input
/// channel inner) matching the fused im2col output row order (Fig. 4).
pub fn oihw_to_filter_matrix(w: &Tensor) -> Tensor {
    assert_eq!(w.rank(), 4, "weights must be rank 4 (OIHW)");
    let ohwi = w.permute(&[0, 2, 3, 1]); // [O, Kh, Kw, I]
    let (o, kh, kw, i) = (
        ohwi.shape[0],
        ohwi.shape[1],
        ohwi.shape[2],
        ohwi.shape[3],
    );
    ohwi.reshape(&[o, kh * kw * i])
}

impl ActLayout {
    /// Shape of a tensor holding `[n, h, w, c]` logical dims in this layout.
    pub fn shape(&self, n: usize, h: usize, w: usize, c: usize) -> Vec<usize> {
        match self {
            ActLayout::Nhwc => vec![n, h, w, c],
            ActLayout::Cnhw => vec![c, n, h, w],
            ActLayout::Nchw => vec![n, c, h, w],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    #[test]
    fn nhwc_cnhw_roundtrip() {
        let mut r = XorShiftRng::new(1);
        let x = Tensor::random(&[2, 4, 5, 3], &mut r, -1.0, 1.0);
        let c = nhwc_to_cnhw(&x);
        assert_eq!(c.shape, vec![3, 2, 4, 5]);
        let back = cnhw_to_nhwc(&c);
        assert_eq!(back, x);
    }

    #[test]
    fn nhwc_to_cnhw_into_matches_permute() {
        let mut r = XorShiftRng::new(3);
        let x = Tensor::random(&[2, 4, 5, 3], &mut r, -1.0, 1.0);
        let want = nhwc_to_cnhw(&x);
        let mut out = Tensor::zeros(&[3, 2, 4, 5]);
        nhwc_to_cnhw_into(&x, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn nhwc_nchw_roundtrip() {
        let mut r = XorShiftRng::new(2);
        let x = Tensor::random(&[2, 4, 5, 3], &mut r, -1.0, 1.0);
        let n = nhwc_to_nchw(&x);
        assert_eq!(n.shape, vec![2, 3, 4, 5]);
        assert_eq!(nchw_to_nhwc(&n), x);
    }

    #[test]
    fn cnhw_element_mapping() {
        // x[n,h,w,c] must land at c[c,n,h,w].
        let mut x = Tensor::zeros(&[2, 3, 4, 5]);
        *x.at_mut(&[1, 2, 3, 4]) = 9.0;
        let c = nhwc_to_cnhw(&x);
        assert_eq!(c.at(&[4, 1, 2, 3]), 9.0);
    }

    #[test]
    fn filter_matrix_order_is_khwi() {
        // O=1, I=2, Kh=1, Kw=2: OIHW data [o0i0k00, o0i0k01, o0i1k00, o0i1k01]
        let w = Tensor::from_vec(&[1, 2, 1, 2], vec![1., 2., 3., 4.]);
        let f = oihw_to_filter_matrix(&w);
        assert_eq!(f.shape, vec![1, 4]);
        // k-major, channel-inner: (k=0,i=0)=1, (k=0,i=1)=3, (k=1,i=0)=2, (k=1,i=1)=4
        assert_eq!(f.data, vec![1., 3., 2., 4.]);
    }

    #[test]
    fn layout_shapes() {
        assert_eq!(ActLayout::Nhwc.shape(1, 2, 3, 4), vec![1, 2, 3, 4]);
        assert_eq!(ActLayout::Cnhw.shape(1, 2, 3, 4), vec![4, 1, 2, 3]);
        assert_eq!(ActLayout::Nchw.shape(1, 2, 3, 4), vec![1, 4, 2, 3]);
    }
}
