//! Per-layer compute datatype — the fifth tuner dimension.
//!
//! A conv layer's GEMM plane runs either in `f32` (the baseline) or in
//! symmetric signed `i8` with i32 accumulation and a requantize-to-f32
//! epilogue (the quantized path; see docs/ARCHITECTURE.md
//! "Quantization plane"). The dtype is a *per-layer* choice like the
//! micro-kernel backend: the tuner picks it, artifacts record it, and
//! `NMPRUNE_DTYPE` can force it process-wide for CI legs.

use std::sync::OnceLock;

/// Compute datatype of a conv layer's GEMM. `F32` is the historical
/// default; `I8` quantizes both the packed activation panel and the
/// (pruned or dense) weights symmetrically, accumulates in i32, and
/// requantizes to f32 at the strip epilogue so downstream ops and
/// logits stay f32.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Dtype {
    #[default]
    F32,
    I8,
}

/// Every dtype, in artifact-code order.
pub const ALL_DTYPES: [Dtype; 2] = [Dtype::F32, Dtype::I8];

impl Dtype {
    /// Stable lower-case name (TSV / env / CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I8 => "i8",
        }
    }

    /// Inverse of [`Dtype::name`].
    pub fn from_name(s: &str) -> Option<Dtype> {
        ALL_DTYPES.into_iter().find(|d| d.name() == s)
    }

    /// Stable numeric code used by the packed-artifact format (v3+).
    pub fn code(self) -> u32 {
        match self {
            Dtype::F32 => 0,
            Dtype::I8 => 1,
        }
    }

    /// Inverse of [`Dtype::code`].
    pub fn from_code(c: u32) -> Option<Dtype> {
        ALL_DTYPES.into_iter().find(|d| d.code() == c)
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Parse an `NMPRUNE_DTYPE` value. `Ok(None)` means no forcing
/// (unset/empty/`auto`); `Err` carries the loud-failure message for an
/// unknown dtype — same fail-loud convention as `NMPRUNE_KERNEL`.
fn parse_forced(raw: &str) -> Result<Option<Dtype>, String> {
    let name = raw.trim().to_ascii_lowercase();
    if name.is_empty() || name == "auto" {
        return Ok(None);
    }
    Dtype::from_name(&name).map(Some).ok_or_else(|| {
        let known = ALL_DTYPES.map(|d| d.name()).join(", ");
        format!("NMPRUNE_DTYPE={raw}: unknown dtype (known: {known}, auto)")
    })
}

/// The process-wide forced dtype from `NMPRUNE_DTYPE`, memoised.
/// Panics (once, loudly) if the variable names an unknown dtype —
/// forcing must never silently fall back. Applied when executors are
/// *built* (op preparation), never on the zero-alloc run path.
pub fn forced() -> Option<Dtype> {
    static FORCED: OnceLock<Option<Dtype>> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var("NMPRUNE_DTYPE") {
        Ok(v) => parse_forced(&v).unwrap_or_else(|e| panic!("{e}")),
        Err(_) => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_and_code_round_trip() {
        for d in ALL_DTYPES {
            assert_eq!(Dtype::from_name(d.name()), Some(d));
            assert_eq!(Dtype::from_code(d.code()), Some(d));
            assert_eq!(format!("{d}"), d.name());
        }
        assert_eq!(Dtype::from_name("fp16"), None);
        assert_eq!(Dtype::from_code(9), None);
        assert_eq!(Dtype::default(), Dtype::F32);
    }

    #[test]
    fn parse_forced_accepts_auto_and_rejects_junk() {
        assert_eq!(parse_forced("").unwrap(), None);
        assert_eq!(parse_forced("auto").unwrap(), None);
        assert_eq!(parse_forced(" AUTO ").unwrap(), None);
        assert_eq!(parse_forced("f32").unwrap(), Some(Dtype::F32));
        assert_eq!(parse_forced(" I8 ").unwrap(), Some(Dtype::I8));
        assert!(parse_forced("int4").is_err());
    }
}
