//! Per-instruction cycle cost model for the simulated RVV core.
//!
//! Calibrated to the shape of an in-order dual-issue embedded RVV core
//! like the SpacemiT K1's X60: a 256-bit vector unit that processes one
//! 256-bit beat per cycle, so an LMUL=m vector op retires in m beats;
//! loads pay an issue cost plus a per-line cost, and L1 misses stall for
//! a fixed penalty. Absolute cycles are a model — only *ratios* between
//! kernels are claimed, matching how EXPERIMENTS.md reports results.

/// Cycle costs per instruction class.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// vsetvli and scalar ALU ops.
    pub scalar_op: u64,
    /// Scalar load/store issue (hit).
    pub scalar_mem: u64,
    /// Vector instruction base issue cost.
    pub vector_issue: u64,
    /// Per-256-bit-beat cost of a vector ALU op (×LMUL per instr).
    pub vector_beat: u64,
    /// Per-cache-line cost of a vector load/store (hit).
    pub vector_mem_line: u64,
    /// Extra cost per element of a *strided* load (vlse splits into
    /// element accesses on the K1).
    pub strided_elem: u64,
    /// L1 miss penalty per line (LPDDR4x ~ 30 core cycles to L2).
    pub miss_penalty: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            scalar_op: 1,
            scalar_mem: 2,
            vector_issue: 1,
            vector_beat: 1,
            vector_mem_line: 2,
            strided_elem: 1,
            miss_penalty: 30,
        }
    }
}

impl CostModel {
    /// Cycles for a vector ALU op at a given LMUL (beats = LMUL).
    pub fn valu(&self, lmul: usize) -> u64 {
        self.vector_issue + self.vector_beat * lmul as u64
    }

    /// Cycles for a unit-stride vector memory op touching `lines` lines
    /// of which `misses` missed.
    pub fn vmem(&self, lines: u64, misses: u64) -> u64 {
        self.vector_issue + self.vector_mem_line * lines + self.miss_penalty * misses
    }

    /// Cycles for a strided vector load of `elems` elements with
    /// `misses` line misses.
    pub fn vmem_strided(&self, elems: u64, misses: u64) -> u64 {
        self.vector_issue + self.strided_elem * elems + self.miss_penalty * misses
    }

    /// Cycles for a scalar load/store with `misses` (0 or 1) misses.
    pub fn smem(&self, misses: u64) -> u64 {
        self.scalar_mem + self.miss_penalty * misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lmul_scales_alu_cost() {
        let m = CostModel::default();
        assert!(m.valu(8) > m.valu(1));
        assert_eq!(m.valu(8) - m.valu(1), 7 * m.vector_beat);
    }

    #[test]
    fn misses_dominate() {
        let m = CostModel::default();
        assert!(m.vmem(1, 1) > 10 * m.vmem(1, 0) / 2);
    }

    #[test]
    fn strided_more_expensive_than_unit_for_long_vectors() {
        let m = CostModel::default();
        // 64 elements = 16 words/line → 4 lines unit-stride.
        assert!(m.vmem_strided(64, 0) > m.vmem(4, 0));
    }
}
