//! The RVV machine: register file, vector instructions, memory + cache.
//!
//! Functional *and* counting: instructions move real f32 data (so kernel
//! results are checked against the native implementations) while every
//! instruction updates counters and the cost model. Word-addressed
//! memory (1 address = 1 f32); "bytes" never appear.

use super::cache::{Cache, CacheConfig};
use super::cost::CostModel;

/// Architectural vector register index (0..num_regs). With grouping, a
/// logical register at LMUL=m occupies physical regs `v, v+1, …, v+m-1`
/// and `v` must be a multiple of m (RVV 1.0 constraint).
pub type VReg = usize;

/// Machine configuration. Defaults model the SpacemiT K1 (§4.1.1).
#[derive(Clone, Copy, Debug)]
pub struct RvvConfig {
    /// Vector register width in bits (K1: 256).
    pub vlen_bits: usize,
    /// Number of architectural vector registers (RVV: 32).
    pub num_regs: usize,
    pub cache: CacheConfig,
    pub cost: CostModel,
}

impl Default for RvvConfig {
    fn default() -> Self {
        Self {
            vlen_bits: 256,
            num_regs: 32,
            cache: CacheConfig::default(),
            cost: CostModel::default(),
        }
    }
}

/// Instruction-count counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    pub vsetvli: u64,
    /// Unit-stride vector loads (vle32.v).
    pub vle: u64,
    /// Strided vector loads (vlse32.v).
    pub vlse: u64,
    /// Unit-stride vector stores (vse32.v).
    pub vse: u64,
    /// Scalar-vector fused multiply-accumulate (vfmacc.vf).
    pub vfmacc: u64,
    /// Vector move/splat (vmv.v.x / vfmv.v.f).
    pub vmv: u64,
    pub scalar_loads: u64,
    pub scalar_stores: u64,
    pub scalar_ops: u64,
    /// Cost-model cycles.
    pub cycles: u64,
}

impl Counters {
    /// Total dynamic instruction count.
    pub fn instructions(&self) -> u64 {
        self.vsetvli
            + self.vle
            + self.vlse
            + self.vse
            + self.vfmacc
            + self.vmv
            + self.scalar_loads
            + self.scalar_stores
            + self.scalar_ops
    }
}

/// The simulated machine.
pub struct RvvMachine {
    pub cfg: RvvConfig,
    /// Register file: `num_regs` physical registers × lanes each,
    /// flattened; a register group is a contiguous slice.
    regfile: Vec<f32>,
    /// Current vector length (elements), set by vsetvli.
    pub vl: usize,
    /// Current register-group multiplier.
    pub lmul: usize,
    /// Flat word-addressed memory.
    pub mem: Vec<f32>,
    pub cache: Cache,
    pub ctr: Counters,
}

impl RvvMachine {
    pub fn new(cfg: RvvConfig) -> Self {
        assert!(cfg.vlen_bits % 32 == 0);
        let lanes = cfg.vlen_bits / 32;
        Self {
            cfg,
            regfile: vec![0.0; cfg.num_regs * lanes],
            vl: 0,
            lmul: 1,
            mem: Vec::new(),
            cache: Cache::new(cfg.cache),
            ctr: Counters::default(),
        }
    }

    /// Machine with K1 defaults.
    pub fn k1() -> Self {
        Self::new(RvvConfig::default())
    }

    /// f32 lanes per physical register.
    pub fn lanes_per_reg(&self) -> usize {
        self.cfg.vlen_bits / 32
    }

    /// VLMAX for a given LMUL (elements per logical register).
    pub fn vlmax(&self, lmul: usize) -> usize {
        self.lanes_per_reg() * lmul
    }

    /// Number of logical registers available at a given LMUL.
    pub fn logical_regs(&self, lmul: usize) -> usize {
        self.cfg.num_regs / lmul
    }

    // ------------------------------------------------------------------
    // Memory management (host-side; not counted)

    /// Copy `data` into simulator memory; returns its base address.
    pub fn alloc(&mut self, data: &[f32]) -> usize {
        let addr = self.mem.len();
        self.mem.extend_from_slice(data);
        addr
    }

    /// Reserve `len` zeroed words; returns the base address.
    pub fn alloc_zeros(&mut self, len: usize) -> usize {
        let addr = self.mem.len();
        self.mem.resize(addr + len, 0.0);
        addr
    }

    /// Host-side read-back (not counted).
    pub fn read(&self, addr: usize, len: usize) -> &[f32] {
        &self.mem[addr..addr + len]
    }

    // ------------------------------------------------------------------
    // Register helpers

    fn check_group(&self, v: VReg) {
        assert!(
            v % self.lmul == 0 && v + self.lmul <= self.cfg.num_regs,
            "register v{v} invalid for LMUL={}",
            self.lmul
        );
    }

    fn reg_range(&self, v: VReg) -> std::ops::Range<usize> {
        let lanes = self.lanes_per_reg();
        v * lanes..v * lanes + self.vl
    }

    /// Inspect a logical register's active lanes (testing).
    pub fn reg(&self, v: VReg) -> &[f32] {
        self.check_group(v);
        &self.regfile[self.reg_range(v)]
    }

    // ------------------------------------------------------------------
    // Instructions

    /// `vsetvli`: request `avl` elements at `lmul`; returns granted VL =
    /// min(avl, VLMAX).
    pub fn vsetvli(&mut self, avl: usize, lmul: usize) -> usize {
        assert!(
            matches!(lmul, 1 | 2 | 4 | 8),
            "integer LMUL only (paper restricts to 1,2,4,8)"
        );
        self.lmul = lmul;
        self.vl = avl.min(self.vlmax(lmul));
        self.ctr.vsetvli += 1;
        self.ctr.cycles += self.cfg.cost.scalar_op;
        self.vl
    }

    /// `vle32.v vd, (addr)`: unit-stride load of VL elements.
    pub fn vle32(&mut self, vd: VReg, addr: usize) {
        self.check_group(vd);
        let vl = self.vl;
        let (lines, misses) = self.cache.load(addr, vl);
        let src = &self.mem[addr..addr + vl];
        let range = self.reg_range(vd);
        self.regfile[range].copy_from_slice(src);
        self.ctr.vle += 1;
        self.ctr.cycles += self.cfg.cost.vmem(lines, misses);
    }

    /// `vlse32.v vd, (addr), stride`: strided load (stride in words).
    pub fn vlse32(&mut self, vd: VReg, addr: usize, stride: usize) {
        self.check_group(vd);
        let vl = self.vl;
        let mut misses = 0u64;
        for i in 0..vl {
            let a = addr + i * stride;
            let (_, m) = self.cache.load(a, 1);
            misses += m;
            let lanes = self.lanes_per_reg();
            self.regfile[vd * lanes + i] = self.mem[a];
        }
        self.ctr.vlse += 1;
        self.ctr.cycles += self.cfg.cost.vmem_strided(vl as u64, misses);
    }

    /// `vse32.v vs, (addr)`: unit-stride store of VL elements.
    pub fn vse32(&mut self, vs: VReg, addr: usize) {
        self.check_group(vs);
        let vl = self.vl;
        let (lines, misses) = self.cache.store(addr, vl);
        let range = self.reg_range(vs);
        let src: Vec<f32> = self.regfile[range].to_vec();
        self.mem[addr..addr + vl].copy_from_slice(&src);
        self.ctr.vse += 1;
        self.ctr.cycles += self.cfg.cost.vmem(lines, misses);
    }

    /// `vfmv.v.f vd, f`: splat a scalar into all active lanes.
    pub fn vfmv_v_f(&mut self, vd: VReg, f: f32) {
        self.check_group(vd);
        let range = self.reg_range(vd);
        self.regfile[range].fill(f);
        self.ctr.vmv += 1;
        self.ctr.cycles += self.cfg.cost.valu(self.lmul);
    }

    /// `vfmacc.vf vd, rs1, vs2`: `vd[i] += rs1 · vs2[i]` — the paper's
    /// workhorse instruction (§3.1 footnote 2).
    pub fn vfmacc_vf(&mut self, vd: VReg, rs1: f32, vs2: VReg) {
        self.check_group(vd);
        self.check_group(vs2);
        let lanes = self.lanes_per_reg();
        let (d0, s0) = (vd * lanes, vs2 * lanes);
        for i in 0..self.vl {
            self.regfile[d0 + i] += rs1 * self.regfile[s0 + i];
        }
        self.ctr.vfmacc += 1;
        self.ctr.cycles += self.cfg.cost.valu(self.lmul);
    }

    /// `flw`: scalar f32 load (counted, cached).
    pub fn flw(&mut self, addr: usize) -> f32 {
        let (_, misses) = self.cache.load(addr, 1);
        self.ctr.scalar_loads += 1;
        self.ctr.cycles += self.cfg.cost.smem(misses);
        self.mem[addr]
    }

    /// `fsw`: scalar f32 store.
    pub fn fsw(&mut self, addr: usize, val: f32) {
        let (_, misses) = self.cache.store(addr, 1);
        self.ctr.scalar_stores += 1;
        self.ctr.cycles += self.cfg.cost.smem(misses);
        self.mem[addr] = val;
    }

    /// Account `n` scalar ALU ops (address arithmetic, loop control).
    pub fn scalar_ops(&mut self, n: u64) {
        self.ctr.scalar_ops += n;
        self.ctr.cycles += n * self.cfg.cost.scalar_op;
    }

    /// Snapshot of the load-access counter (the `perf` L1-loads analogue).
    pub fn l1_loads(&self) -> u64 {
        self.cache.load_accesses
    }

    /// Reset counters and cache counters (keep memory + cache contents).
    pub fn reset_counters(&mut self) {
        self.ctr = Counters::default();
        self.cache.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vsetvli_clamps_to_vlmax() {
        let mut m = RvvMachine::k1();
        assert_eq!(m.vsetvli(100, 1), 8); // 256/32 = 8 lanes
        assert_eq!(m.vsetvli(100, 8), 64);
        assert_eq!(m.vsetvli(3, 4), 3);
        assert_eq!(m.ctr.vsetvli, 3);
    }

    #[test]
    fn load_store_roundtrip() {
        let mut m = RvvMachine::k1();
        let a = m.alloc(&[1., 2., 3., 4., 5., 6., 7., 8.]);
        let b = m.alloc_zeros(8);
        m.vsetvli(8, 1);
        m.vle32(0, a);
        m.vse32(0, b);
        assert_eq!(m.read(b, 8), &[1., 2., 3., 4., 5., 6., 7., 8.]);
        assert_eq!(m.ctr.vle, 1);
        assert_eq!(m.ctr.vse, 1);
    }

    #[test]
    fn lmul_grouping_loads_wide() {
        let mut m = RvvMachine::k1();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let a = m.alloc(&data);
        m.vsetvli(64, 8);
        m.vle32(0, a); // v0..v7 as one logical register
        assert_eq!(m.reg(0), &data[..]);
    }

    #[test]
    #[should_panic(expected = "invalid for LMUL")]
    fn misaligned_group_panics() {
        let mut m = RvvMachine::k1();
        m.vsetvli(16, 4);
        m.vfmv_v_f(2, 1.0); // v2 not a multiple of LMUL=4
    }

    #[test]
    fn vfmacc_computes_fma() {
        let mut m = RvvMachine::k1();
        let a = m.alloc(&[1., 2., 3., 4.]);
        m.vsetvli(4, 1);
        m.vfmv_v_f(1, 10.0); // acc = 10
        m.vle32(2, a);
        m.vfmacc_vf(1, 2.0, 2); // acc += 2*a
        assert_eq!(m.reg(1), &[12., 14., 16., 18.]);
    }

    #[test]
    fn strided_load_gathers() {
        let mut m = RvvMachine::k1();
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let a = m.alloc(&data);
        m.vsetvli(4, 1);
        m.vlse32(0, a + 1, 4);
        assert_eq!(m.reg(0), &[1., 5., 9., 13.]);
        assert_eq!(m.ctr.vlse, 1);
    }

    #[test]
    fn partial_vl_only_touches_active_lanes() {
        let mut m = RvvMachine::k1();
        let a = m.alloc(&[9., 9., 9., 9., 9., 9., 9., 9.]);
        m.vsetvli(8, 1);
        m.vfmv_v_f(0, 1.0);
        m.vsetvli(3, 1); // shrink VL
        m.vle32(0, a); // overwrites lanes 0..3 only
        m.vsetvli(8, 1);
        assert_eq!(m.reg(0), &[9., 9., 9., 1., 1., 1., 1., 1.]);
    }

    #[test]
    fn cycles_accumulate_and_misses_cost_more() {
        let mut m = RvvMachine::k1();
        let data = vec![0.0f32; 1024];
        let a = m.alloc(&data);
        m.vsetvli(8, 1);
        m.vle32(0, a); // cold miss
        let cold = m.ctr.cycles;
        m.reset_counters();
        m.vsetvli(8, 1);
        m.vle32(0, a); // warm hit
        let warm = m.ctr.cycles;
        assert!(cold > warm);
    }

    #[test]
    fn l1_loads_counts_line_accesses() {
        let mut m = RvvMachine::k1();
        let data = vec![0.0f32; 128];
        let a = m.alloc(&data);
        m.vsetvli(64, 8); // 64 words = 4 lines of 16 words
        m.vle32(0, a);
        assert_eq!(m.l1_loads(), 4);
    }

    #[test]
    fn logical_reg_count() {
        let m = RvvMachine::k1();
        assert_eq!(m.logical_regs(1), 32);
        assert_eq!(m.logical_regs(8), 4);
    }
}
