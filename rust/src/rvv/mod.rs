//! RISC-V Vector (RVV 1.0) functional simulator with an L1-D cache and a
//! cycle cost model.
//!
//! This substrate replaces the paper's Banana Pi BPI-F3 / SpacemiT K1
//! testbed (§4.1.1: VLEN = 256 bit, 32 vector registers, RVV 1.0). The
//! paper's headline metrics — `perf` L1-cache loads, relative kernel
//! speedups, LMUL trade-offs — are memory-traffic and instruction-count
//! phenomena, so a trace-driven cache + per-instruction cost model
//! reproduces them without the board. Every micro-kernel of the paper
//! (Algorithm 1, Algorithm 2, and all baselines) is written against this
//! machine in [`kernels`], computing *real* f32 results that are checked
//! against the native [`crate::gemm`] implementations, while the machine
//! counts instructions, cache-line accesses, misses and model cycles.
//!
//! Counter definitions:
//! * `l1_load_accesses` — cache-line-granularity load accesses, the
//!   analogue of `perf`'s L1-dcache-loads on a core that splits vector
//!   loads into per-line μops (as the K1 does).
//! * `cycles` — cost-model cycles; see [`cost`] for the per-class costs.

pub mod machine;
pub mod cache;
pub mod cost;
pub mod kernels;

pub use cache::{Cache, CacheConfig};
pub use cost::CostModel;
pub use machine::{Counters, RvvConfig, RvvMachine, VReg};
