//! The paper's micro-kernels, written against the RVV machine.
//!
//! Each kernel is the instruction-level twin of a native implementation
//! in [`crate::gemm`] / [`crate::im2col`]; unit tests check the simulated
//! f32 results against the native ones, so the counter reports describe
//! kernels that are *provably computing the right thing*.
//!
//! Register allocation convention: logical register `i` (at the current
//! LMUL) is physical register `i·LMUL`. Algorithm 1 uses logical regs
//! `0..T` as accumulators and logical reg `T` as the data register, which
//! requires `(T+1)·LMUL ≤ 32` — the register-pressure constraint the
//! tuner (§3.3) navigates.

use crate::conv::ConvShape;
use crate::gemm::outer::ColumnView;
use crate::im2col::PackedMatrix;
use crate::pruning::{ColwisePruned, RowNmPruned};

use super::machine::RvvMachine;

/// Counter snapshot for one simulated kernel run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimReport {
    /// L1 load accesses at cache-line granularity (`perf` L1-loads twin).
    pub l1_loads: u64,
    pub l1_load_misses: u64,
    pub l1_stores: u64,
    pub l1_store_misses: u64,
    pub instructions: u64,
    pub cycles: u64,
}

impl SimReport {
    fn capture(m: &RvvMachine) -> Self {
        Self {
            l1_loads: m.cache.load_accesses,
            l1_load_misses: m.cache.load_misses,
            l1_stores: m.cache.store_accesses,
            l1_store_misses: m.cache.store_misses,
            instructions: m.ctr.instructions(),
            cycles: m.ctr.cycles,
        }
    }
}

/// Maximum tile size T for a given LMUL on a 32-register machine:
/// T accumulators + 1 data register.
pub fn max_tile_for_lmul(m: &RvvMachine, lmul: usize) -> usize {
    m.logical_regs(lmul).saturating_sub(1)
}

// ----------------------------------------------------------------------
// Algorithm 1: column-wise N:M sparse GEMM

/// Simulate Algorithm 1 over compressed weights `w` and packed data `a`.
/// `a.v` must equal VLMAX(lmul). Returns (output `[rows, cols]`, report).
pub fn sim_spmm_colwise(
    m: &mut RvvMachine,
    w: &ColwisePruned,
    a: &PackedMatrix,
    lmul: usize,
) -> (Vec<f32>, SimReport) {
    assert_eq!(w.cols, a.k, "reduction dim mismatch");
    assert_eq!(a.v, m.vlmax(lmul), "strip width must equal VLMAX(lmul)");
    assert!(
        w.tile + 1 <= m.logical_regs(lmul),
        "tile {} + data reg exceed {} logical regs at LMUL={lmul}",
        w.tile,
        m.logical_regs(lmul)
    );
    // Lay the operands out in simulator memory.
    let a_addr = m.alloc(&a.data);
    let out_addr = m.alloc_zeros(w.rows * a.cols);
    // Weights: per tile, a value block [row_count, nret] and an index
    // array (stored as f32 for the scalar load path).
    let tile_meta: Vec<(usize, usize)> = w
        .tiles
        .iter()
        .map(|t| {
            let vals = m.alloc(&t.values);
            let idxf: Vec<f32> = t.indices.iter().map(|&i| i as f32).collect();
            let idxs = m.alloc(&idxf);
            (vals, idxs)
        })
        .collect();
    m.reset_counters();

    let data_reg = |t: usize, lmul: usize| t * lmul; // logical -> physical
    for strip in 0..a.strips {
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        for (tile, &(vals_addr, idx_addr)) in w.tiles.iter().zip(&tile_meta) {
            let t = tile.row_count;
            let nret = tile.indices.len();
            m.vsetvli(valid, lmul);
            for ti in 0..t {
                m.vfmv_v_f(data_reg(ti, lmul), 0.0); // acc_t ← 0
            }
            let va = data_reg(t, lmul); // the single data register
            for j in 0..nret {
                let idx = m.flw(idx_addr + j) as usize; // Idx[n]
                m.scalar_ops(1); // address computation A + Idx[n]·V
                m.vle32(va, a_addr + (strip * a.k + idx) * a.v);
                for ti in 0..t {
                    let wv = m.flw(vals_addr + ti * nret + j); // scalar weight
                    m.vfmacc_vf(data_reg(ti, lmul), wv, va);
                }
            }
            for ti in 0..t {
                let r = tile.row_start + ti;
                m.scalar_ops(1);
                m.vse32(data_reg(ti, lmul), out_addr + r * a.cols + col0);
            }
        }
    }
    let report = SimReport::capture(m);
    (m.read(out_addr, w.rows * a.cols).to_vec(), report)
}

// ----------------------------------------------------------------------
// Dense tiled GEMM (dense baseline of Fig. 5 / Fig. 10)

/// Simulate the dense packed GEMM at tile size `tile`.
pub fn sim_gemm_dense(
    m: &mut RvvMachine,
    filter: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    lmul: usize,
) -> (Vec<f32>, SimReport) {
    assert_eq!(filter.len(), rows * a.k);
    assert_eq!(a.v, m.vlmax(lmul));
    assert!(tile + 1 <= m.logical_regs(lmul));
    let a_addr = m.alloc(&a.data);
    let w_addr = m.alloc(filter);
    let out_addr = m.alloc_zeros(rows * a.cols);
    m.reset_counters();

    let lreg = |i: usize| i * lmul;
    for strip in 0..a.strips {
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        let mut row = 0;
        while row < rows {
            let t = tile.min(rows - row);
            m.vsetvli(valid, lmul);
            for ti in 0..t {
                m.vfmv_v_f(lreg(ti), 0.0);
            }
            let va = lreg(t);
            for k in 0..a.k {
                m.scalar_ops(1);
                m.vle32(va, a_addr + (strip * a.k + k) * a.v);
                for ti in 0..t {
                    let wv = m.flw(w_addr + (row + ti) * a.k + k);
                    m.vfmacc_vf(lreg(ti), wv, va);
                }
            }
            for ti in 0..t {
                m.scalar_ops(1);
                m.vse32(lreg(ti), out_addr + (row + ti) * a.cols + col0);
            }
            row += t;
        }
    }
    let report = SimReport::capture(m);
    (m.read(out_addr, rows * a.cols).to_vec(), report)
}

/// Dense tiled GEMM over an *unpacked* row-major `A[k, cols]` resident at
/// `a_addr` — the "no data packing" configuration of Fig. 8a. The loop
/// structure matches [`sim_gemm_dense`]; only the A addressing differs:
/// successive reduction steps of one strip touch addresses `cols` apart
/// instead of `v` apart, so the strip's working set spans `k` distinct
/// line groups and cache locality collapses for large `cols`.
pub fn sim_gemm_dense_unpacked(
    m: &mut RvvMachine,
    filter: &[f32],
    rows: usize,
    a_addr: usize,
    k: usize,
    cols: usize,
    tile: usize,
    lmul: usize,
) -> (Vec<f32>, SimReport) {
    assert_eq!(filter.len(), rows * k);
    let v = m.vlmax(lmul);
    assert!(tile + 1 <= m.logical_regs(lmul));
    let strips = cols.div_ceil(v).max(1);
    let w_addr = m.alloc(filter);
    let out_addr = m.alloc_zeros(rows * cols);
    m.reset_counters();

    let lreg = |i: usize| i * lmul;
    for strip in 0..strips {
        let col0 = strip * v;
        let valid = v.min(cols.saturating_sub(col0));
        if valid == 0 {
            continue;
        }
        let mut row = 0;
        while row < rows {
            let t = tile.min(rows - row);
            m.vsetvli(valid, lmul);
            for ti in 0..t {
                m.vfmv_v_f(lreg(ti), 0.0);
            }
            let va = lreg(t);
            for kk in 0..k {
                m.scalar_ops(1);
                // Row-major A: stride `cols` between reduction rows.
                m.vle32(va, a_addr + kk * cols + col0);
                for ti in 0..t {
                    let wv = m.flw(w_addr + (row + ti) * k + kk);
                    m.vfmacc_vf(lreg(ti), wv, va);
                }
            }
            for ti in 0..t {
                m.scalar_ops(1);
                m.vse32(lreg(ti), out_addr + (row + ti) * cols + col0);
            }
            row += t;
        }
    }
    let report = SimReport::capture(m);
    (m.read(out_addr, rows * cols).to_vec(), report)
}

// ----------------------------------------------------------------------
// Conventional row-based N:M baselines (§3.1)

/// Inner-product row-based N:M SpMM: redundant data-row loads.
pub fn sim_spmm_inner_rownm(
    m: &mut RvvMachine,
    w: &RowNmPruned,
    a: &PackedMatrix,
    lmul: usize,
) -> (Vec<f32>, SimReport) {
    assert_eq!(w.cols, a.k);
    assert_eq!(a.v, m.vlmax(lmul));
    let a_addr = m.alloc(&a.data);
    let vals_addr = m.alloc(&w.values);
    let idxf: Vec<f32> = w.indices.iter().map(|&i| i as f32).collect();
    let idx_addr = m.alloc(&idxf);
    let out_addr = m.alloc_zeros(w.rows * a.cols);
    m.reset_counters();

    let (acc, va) = (0, lmul); // logical regs 0 and 1
    for strip in 0..a.strips {
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        for r in 0..w.rows {
            m.vsetvli(valid, lmul);
            m.vfmv_v_f(acc, 0.0);
            for j in 0..w.per_row {
                let idx = m.flw(idx_addr + r * w.per_row + j) as usize;
                let wv = m.flw(vals_addr + r * w.per_row + j);
                m.scalar_ops(1);
                // Every output row re-fetches its data rows: no reuse
                // across rows because each row's index set differs.
                m.vle32(va, a_addr + (strip * a.k + idx) * a.v);
                m.vfmacc_vf(acc, wv, va);
            }
            m.scalar_ops(1);
            m.vse32(acc, out_addr + r * a.cols + col0);
        }
    }
    let report = SimReport::capture(m);
    (m.read(out_addr, w.rows * a.cols).to_vec(), report)
}

/// Outer-product row-based N:M SpMM — the "conventional N:M" of Fig. 5:
/// data rows are reused, but partial sums are read-modify-written to the
/// scattered output rows through memory.
pub fn sim_spmm_outer_rownm(
    m: &mut RvvMachine,
    w: &RowNmPruned,
    a: &PackedMatrix,
    lmul: usize,
) -> (Vec<f32>, SimReport) {
    assert_eq!(w.cols, a.k);
    assert_eq!(a.v, m.vlmax(lmul));
    let view = ColumnView::build(w);
    let a_addr = m.alloc(&a.data);
    let out_addr = m.alloc_zeros(w.rows * a.cols);
    // Column-view hit arrays in memory: rows and values per column.
    let rowsf: Vec<f32> = view.hits.iter().map(|&(r, _)| r as f32).collect();
    let valsf: Vec<f32> = view.hits.iter().map(|&(_, v)| v).collect();
    let rows_addr = m.alloc(&rowsf);
    let vals_addr = m.alloc(&valsf);
    m.reset_counters();

    let (va, part) = (0, lmul); // logical regs 0 and 1
    for strip in 0..a.strips {
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        for k in 0..w.cols {
            let (lo, hi) = (view.offsets[k] as usize, view.offsets[k + 1] as usize);
            if lo == hi {
                continue;
            }
            m.vsetvli(valid, lmul);
            // Data row loaded once per column (the reuse win)…
            m.scalar_ops(1);
            m.vle32(va, a_addr + (strip * a.k + k) * a.v);
            for h in lo..hi {
                let r = m.flw(rows_addr + h) as usize;
                let wv = m.flw(vals_addr + h);
                m.scalar_ops(1);
                // …but the accumulator lives in memory: load partial,
                // FMA, store back — the redundant-store pathology.
                m.vle32(part, out_addr + r * a.cols + col0);
                m.vfmacc_vf(part, wv, va);
                m.vse32(part, out_addr + r * a.cols + col0);
            }
        }
    }
    let report = SimReport::capture(m);
    (m.read(out_addr, w.rows * a.cols).to_vec(), report)
}

// ----------------------------------------------------------------------
// Algorithm 2: fused im2col + data packing, and the separate baseline

/// Simulate the fused im2col+pack pass (Algorithm 2) over a CNHW input
/// already resident at `x_addr`. Returns (packed address, report); the
/// packed layout matches [`PackedMatrix`] with `v = VLMAX(lmul)`.
pub fn sim_fused_im2col_pack(
    m: &mut RvvMachine,
    x_addr: usize,
    s: &ConvShape,
    lmul: usize,
) -> (usize, SimReport) {
    let v = m.vlmax(lmul);
    let (h_out, w_out) = (s.h_out(), s.w_out());
    let cols = s.n * h_out * w_out;
    let k = s.k();
    let strips = cols.div_ceil(v).max(1);
    let out_addr = m.alloc_zeros(strips * k * v);
    m.reset_counters();
    sim_strip_moves(m, x_addr, s, lmul, v, strips, cols, |strip, row, lane| {
        (strip * k + row) * v + lane + out_addr
    });
    let report = SimReport::capture(m);
    (out_addr, report)
}

/// Simulate a standalone im2col producing the dense `A[k, cols]` matrix.
pub fn sim_im2col(
    m: &mut RvvMachine,
    x_addr: usize,
    s: &ConvShape,
    lmul: usize,
) -> (usize, SimReport) {
    let v = m.vlmax(lmul);
    let cols = s.gemm_cols();
    let k = s.k();
    let strips = cols.div_ceil(v).max(1);
    let a_addr = m.alloc_zeros(k * cols);
    m.reset_counters();
    // Same source traversal, but the destination is the row-major A
    // matrix (strip decomposition only segments the loop).
    sim_strip_moves(m, x_addr, s, lmul, v, strips, cols, |strip, row, lane| {
        row * cols + strip * v + lane + a_addr
    });
    let report = SimReport::capture(m);
    (a_addr, report)
}

/// Simulate the standalone packing pass over an existing `A[k, cols]`.
pub fn sim_pack(
    m: &mut RvvMachine,
    a_addr: usize,
    k: usize,
    cols: usize,
    lmul: usize,
) -> (usize, SimReport) {
    let v = m.vlmax(lmul);
    let strips = cols.div_ceil(v).max(1);
    let out_addr = m.alloc_zeros(strips * k * v);
    m.reset_counters();
    for strip in 0..strips {
        let valid = v.min(cols - (strip * v).min(cols));
        if valid == 0 {
            continue;
        }
        for row in 0..k {
            m.vsetvli(valid, lmul);
            m.scalar_ops(1);
            m.vle32(0, a_addr + row * cols + strip * v);
            m.vse32(0, out_addr + (strip * k + row) * v);
        }
    }
    let report = SimReport::capture(m);
    (out_addr, report)
}

/// Separate im2col followed by packing — the baseline of §4.3. Returns
/// (packed address, combined report).
pub fn sim_separate_im2col_pack(
    m: &mut RvvMachine,
    x_addr: usize,
    s: &ConvShape,
    lmul: usize,
) -> (usize, SimReport) {
    let (a_addr, r1) = sim_im2col(m, x_addr, s, lmul);
    let (p_addr, r2) = sim_pack(m, a_addr, s.k(), s.gemm_cols(), lmul);
    let combined = SimReport {
        l1_loads: r1.l1_loads + r2.l1_loads,
        l1_load_misses: r1.l1_load_misses + r2.l1_load_misses,
        l1_stores: r1.l1_stores + r2.l1_stores,
        l1_store_misses: r1.l1_store_misses + r2.l1_store_misses,
        instructions: r1.instructions + r2.instructions,
        cycles: r1.cycles + r2.cycles,
    };
    (p_addr, combined)
}

/// Shared source-traversal for the im2col family: walks (strip, segment,
/// tap, channel) and issues one vector move per valid run, exactly like
/// the native [`crate::im2col::fused_im2col_pack_cnhw`]. `dst` maps
/// (strip, data-matrix row, lane) to a destination address.
#[allow(clippy::too_many_arguments)]
fn sim_strip_moves<F: Fn(usize, usize, usize) -> usize>(
    m: &mut RvvMachine,
    x_addr: usize,
    s: &ConvShape,
    lmul: usize,
    v: usize,
    strips: usize,
    cols: usize,
    dst: F,
) {
    let (h_out, w_out) = (s.h_out(), s.w_out());
    for strip in 0..strips {
        let strip_base = strip * v;
        let valid = v.min(cols.saturating_sub(strip_base));
        let mut lane = 0usize;
        while lane < valid {
            let col = strip_base + lane;
            let n = col / (h_out * w_out);
            let rem = col % (h_out * w_out);
            let ho = rem / w_out;
            let wo0 = rem % w_out;
            let seg = (w_out - wo0).min(valid - lane);
            m.scalar_ops(2); // segment decomposition arithmetic
            for kh in 0..s.kh {
                let hi = (ho * s.stride + kh) as isize - s.pad as isize;
                if hi < 0 || hi >= s.h_in as isize {
                    continue; // padding: skipped, not copied (§4.3)
                }
                let hi = hi as usize;
                for kw in 0..s.kw {
                    let wi0 = (wo0 * s.stride + kw) as isize - s.pad as isize;
                    let j_lo = if wi0 >= 0 {
                        0
                    } else {
                        ((-wi0) as usize).div_ceil(s.stride)
                    };
                    let j_hi = if wi0 >= s.w_in as isize {
                        0
                    } else {
                        (((s.w_in as isize - 1 - wi0) / s.stride as isize) + 1).max(0) as usize
                    }
                    .min(seg);
                    if j_lo >= j_hi {
                        continue;
                    }
                    for c in 0..s.c_in {
                        let row = (kh * s.kw + kw) * s.c_in + c;
                        let in_base = ((c * s.n + n) * s.h_in + hi) * s.w_in;
                        let len = j_hi - j_lo;
                        // Dynamic VL: exactly the valid run (§3.2 — no
                        // masked loads, no padded copies).
                        m.vsetvli(len, lmul);
                        m.scalar_ops(1);
                        let src0 =
                            (in_base as isize + wi0 + (j_lo * s.stride) as isize) as usize;
                        if s.stride == 1 {
                            m.vle32(0, x_addr + src0);
                        } else {
                            m.vlse32(0, x_addr + src0, s.stride);
                        }
                        m.vse32(0, dst(strip, row, lane + j_lo));
                    }
                }
            }
            lane += seg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{gemm_dense, matmul_ref, spmm_colwise, spmm_inner_rownm, spmm_outer_rownm};
    use crate::im2col::{fused_im2col_pack_cnhw, im2col_cnhw, pack_data_matrix};
    use crate::pruning::{prune_colwise, prune_rownm};
    use crate::tensor::Tensor;
    use crate::util::{allclose, XorShiftRng};

    fn machine() -> RvvMachine {
        RvvMachine::k1()
    }

    #[test]
    fn sim_colwise_matches_native() {
        let mut r = XorShiftRng::new(201);
        let (rows, k, cols) = (8, 16, 40);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        for lmul in [1, 2, 4] {
            let mut m = machine();
            let v = m.vlmax(lmul);
            let cp = prune_colwise(&w, rows, k, 4, 2, 4);
            let p = pack_data_matrix(&a, k, cols, v);
            let native = spmm_colwise(&cp, &p);
            let (got, rep) = sim_spmm_colwise(&mut m, &cp, &p, lmul);
            assert!(allclose(&got, &native, 1e-5, 1e-6), "lmul={lmul}");
            assert!(rep.l1_loads > 0 && rep.cycles > 0);
        }
    }

    #[test]
    fn sim_dense_matches_native() {
        let mut r = XorShiftRng::new(202);
        let (rows, k, cols) = (9, 12, 25);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let mut m = machine();
        let v = m.vlmax(2);
        let p = pack_data_matrix(&a, k, cols, v);
        let native = gemm_dense(&w, rows, &p, 4);
        let (got, _) = sim_gemm_dense(&mut m, &w, rows, &p, 4, 2);
        assert!(allclose(&got, &native, 1e-5, 1e-6));
        assert!(allclose(&got, &matmul_ref(&w, &a, rows, k, cols), 1e-4, 1e-5));
    }

    #[test]
    fn sim_dense_unpacked_matches_reference_and_loads_more_lines() {
        let mut r = XorShiftRng::new(208);
        let (rows, k, cols) = (8, 24, 200);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let lmul = 2;
        let mut m = machine();
        let a_addr = m.alloc(&a);
        let (got, rep_un) =
            sim_gemm_dense_unpacked(&mut m, &w, rows, a_addr, k, cols, 4, lmul);
        assert!(allclose(
            &got,
            &matmul_ref(&w, &a, rows, k, cols),
            1e-4,
            1e-5
        ));
        // Same arithmetic against packed A: identical results, but the
        // packed layout must not miss more than the strided one.
        let mut m2 = machine();
        let v = m2.vlmax(lmul);
        let p = pack_data_matrix(&a, k, cols, v);
        let (got_p, rep_pk) = sim_gemm_dense(&mut m2, &w, rows, &p, 4, lmul);
        assert!(allclose(&got, &got_p, 1e-5, 1e-6));
        assert!(
            rep_pk.l1_load_misses <= rep_un.l1_load_misses,
            "packed {} vs unpacked {} misses",
            rep_pk.l1_load_misses,
            rep_un.l1_load_misses
        );
    }

    #[test]
    fn sim_inner_and_outer_match_native() {
        let mut r = XorShiftRng::new(203);
        let (rows, k, cols) = (10, 20, 30);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let rp = prune_rownm(&w, rows, k, 2, 4);
        let mut m = machine();
        let v = m.vlmax(1);
        let p = pack_data_matrix(&a, k, cols, v);
        let native_i = spmm_inner_rownm(&rp, &p);
        let native_o = spmm_outer_rownm(&rp, &p);
        let (got_i, _) = sim_spmm_inner_rownm(&mut m, &rp, &p, 1);
        let mut m2 = machine();
        let (got_o, _) = sim_spmm_outer_rownm(&mut m2, &rp, &p, 1);
        assert!(allclose(&got_i, &native_i, 1e-5, 1e-6));
        assert!(allclose(&got_o, &native_o, 1e-5, 1e-6));
    }

    #[test]
    fn sim_fused_matches_native_packing() {
        let mut r = XorShiftRng::new(204);
        for (s, lmul) in [
            (ConvShape::square(1, 3, 8, 4, 3, 1, 1), 1),
            (ConvShape::square(2, 2, 9, 4, 3, 2, 1), 2),
            (ConvShape::square(1, 2, 12, 4, 7, 2, 3), 4),
        ] {
            let mut m = machine();
            let v = m.vlmax(lmul);
            let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
            let native = fused_im2col_pack_cnhw(&x, &s, v);
            let x_addr = m.alloc(&x.data);
            let (p_addr, rep) = sim_fused_im2col_pack(&mut m, x_addr, &s, lmul);
            let got = m.read(p_addr, native.data.len());
            assert!(allclose(got, &native.data, 0.0, 0.0), "{s} lmul={lmul}");
            assert!(rep.instructions > 0);
        }
    }

    #[test]
    fn sim_separate_produces_same_bits_as_fused() {
        let mut r = XorShiftRng::new(205);
        let s = ConvShape::square(1, 3, 10, 4, 3, 1, 1);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
        let lmul = 2;
        let mut m1 = machine();
        let xa1 = m1.alloc(&x.data);
        let (pf, _) = sim_fused_im2col_pack(&mut m1, xa1, &s, lmul);
        let mut m2 = machine();
        let xa2 = m2.alloc(&x.data);
        let (ps, _) = sim_separate_im2col_pack(&mut m2, xa2, &s, lmul);
        let v = m1.vlmax(lmul);
        let len = s.gemm_cols().div_ceil(v) * s.k() * v;
        assert_eq!(m1.read(pf, len), m2.read(ps, len));
    }

    #[test]
    fn sim_im2col_matches_native_a_matrix() {
        let mut r = XorShiftRng::new(206);
        let s = ConvShape::square(1, 2, 7, 3, 3, 1, 1);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
        let native = im2col_cnhw(&x, &s);
        let mut m = machine();
        let xa = m.alloc(&x.data);
        let (aa, _) = sim_im2col(&mut m, xa, &s, 2);
        assert_eq!(m.read(aa, native.len()), &native[..]);
    }

    // ---------------- paper-shape sanity checks ----------------

    #[test]
    fn fusion_reduces_l1_loads() {
        // Fig. 7's claim: fused im2col+pack touches memory once.
        let mut r = XorShiftRng::new(207);
        let s = ConvShape::square(1, 8, 14, 8, 3, 1, 1);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
        for lmul in [1, 2, 4, 8] {
            let mut m1 = machine();
            let xa = m1.alloc(&x.data);
            let (_, fused) = sim_fused_im2col_pack(&mut m1, xa, &s, lmul);
            let mut m2 = machine();
            let xa2 = m2.alloc(&x.data);
            let (_, sep) = sim_separate_im2col_pack(&mut m2, xa2, &s, lmul);
            assert!(
                fused.l1_loads < sep.l1_loads,
                "lmul={lmul}: fused {} !< separate {}",
                fused.l1_loads,
                sep.l1_loads
            );
            assert!(fused.cycles < sep.cycles, "lmul={lmul}");
        }
    }

    #[test]
    fn colwise_beats_outer_product_and_dense_in_cycles() {
        // Fig. 5's ordering at 50% sparsity: colwise < dense < outer.
        let mut r = XorShiftRng::new(208);
        let (rows, k, cols) = (32, 64, 256);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let lmul = 2;
        let mut m = machine();
        let v = m.vlmax(lmul);
        let p = pack_data_matrix(&a, k, cols, v);

        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let (_, rep_col) = sim_spmm_colwise(&mut m, &cp, &p, lmul);

        let mut m2 = machine();
        let (_, rep_dense) = sim_gemm_dense(&mut m2, &w, rows, &p, 8, lmul);

        let rp = prune_rownm(&w, rows, k, 2, 4);
        let mut m3 = machine();
        let (_, rep_outer) = sim_spmm_outer_rownm(&mut m3, &rp, &p, lmul);

        assert!(
            rep_col.cycles < rep_dense.cycles,
            "colwise {} !< dense {}",
            rep_col.cycles,
            rep_dense.cycles
        );
        assert!(
            rep_outer.cycles > rep_dense.cycles,
            "outer {} !> dense {} (paper: conventional N:M is *slower*)",
            rep_outer.cycles,
            rep_dense.cycles
        );
    }

    #[test]
    fn inner_product_reloads_more_than_colwise() {
        let mut r = XorShiftRng::new(209);
        let (rows, k, cols) = (32, 32, 128);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let lmul = 1;
        let mut m = machine();
        let v = m.vlmax(lmul);
        let p = pack_data_matrix(&a, k, cols, v);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let (_, rep_col) = sim_spmm_colwise(&mut m, &cp, &p, lmul);
        let rp = prune_rownm(&w, rows, k, 2, 4);
        let mut m2 = machine();
        let (_, rep_inner) = sim_spmm_inner_rownm(&mut m2, &rp, &p, lmul);
        // Same FLOPs, but inner-product re-fetches data rows per output
        // row while colwise fetches once per tile.
        assert!(rep_col.l1_loads < rep_inner.l1_loads);
    }

    #[test]
    fn max_tile_respects_register_file() {
        let m = machine();
        assert_eq!(max_tile_for_lmul(&m, 1), 31);
        assert_eq!(max_tile_for_lmul(&m, 8), 3);
    }
}
