//! Set-associative L1-D cache model with LRU replacement.
//!
//! Word-addressed (one f32 = one address unit); line size is given in
//! words. Defaults model the SpacemiT K1's 32 KiB, 8-way, 64-byte-line
//! L1-D cache.

/// Cache geometry.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Total capacity in f32 words (32 KiB = 8192 words).
    pub capacity_words: usize,
    /// Associativity (ways per set).
    pub ways: usize,
    /// Line size in f32 words (64 B = 16 words).
    pub line_words: usize,
    /// Next-line prefetch on loads (the K1's L1-D stream prefetcher):
    /// a load touching line L warms L+1, so unit-stride streams miss
    /// only on the first line while large-stride streams get no help —
    /// the locality difference data packing exists to exploit.
    pub prefetch: bool,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            capacity_words: 8192,
            ways: 8,
            line_words: 16,
            prefetch: true,
        }
    }
}

/// LRU set-associative cache. Tracks hits/misses for loads and stores
/// separately (write-allocate, write-back — dirty state not modelled
/// because only traffic counts matter here).
#[derive(Clone, Debug)]
pub struct Cache {
    pub cfg: CacheConfig,
    sets: usize,
    /// tags[set * ways + way] = Some(tag), ordered by recency per set
    /// (index 0 = MRU) — simple vector-shift LRU, fine for 8 ways.
    tags: Vec<Option<usize>>,
    pub load_accesses: u64,
    pub load_misses: u64,
    pub store_accesses: u64,
    pub store_misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        assert!(cfg.line_words.is_power_of_two());
        assert!(cfg.capacity_words % (cfg.line_words * cfg.ways) == 0);
        let sets = cfg.capacity_words / (cfg.line_words * cfg.ways);
        Self {
            cfg,
            sets,
            tags: vec![None; sets * cfg.ways],
            load_accesses: 0,
            load_misses: 0,
            store_accesses: 0,
            store_misses: 0,
        }
    }

    fn set_and_tag(&self, line: usize) -> (usize, usize) {
        (line % self.sets, line / self.sets)
    }

    /// Access one line; returns true on hit. Updates LRU and counters.
    fn touch_line(&mut self, line: usize, is_store: bool) -> bool {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.cfg.ways;
        let ways = &mut self.tags[base..base + self.cfg.ways];
        let hit_way = ways.iter().position(|t| *t == Some(tag));
        let hit = hit_way.is_some();
        match hit_way {
            Some(w) => {
                // Move to MRU.
                ways[..=w].rotate_right(1);
                ways[0] = Some(tag);
            }
            None => {
                // Evict LRU (last), insert at MRU.
                ways.rotate_right(1);
                ways[0] = Some(tag);
            }
        }
        if is_store {
            self.store_accesses += 1;
            if !hit {
                self.store_misses += 1;
            }
        } else {
            self.load_accesses += 1;
            if !hit {
                self.load_misses += 1;
            }
        }
        hit
    }

    /// Load access covering `[addr, addr+words)`. Returns the number of
    /// lines touched and how many of them missed.
    pub fn load(&mut self, addr: usize, words: usize) -> (u64, u64) {
        self.span(addr, words, false)
    }

    /// Store access covering `[addr, addr+words)`.
    pub fn store(&mut self, addr: usize, words: usize) -> (u64, u64) {
        self.span(addr, words, true)
    }

    fn span(&mut self, addr: usize, words: usize, is_store: bool) -> (u64, u64) {
        if words == 0 {
            return (0, 0);
        }
        let first = addr / self.cfg.line_words;
        let last = (addr + words - 1) / self.cfg.line_words;
        let mut misses = 0;
        for line in first..=last {
            if !self.touch_line(line, is_store) {
                misses += 1;
            }
        }
        // Next-line prefetch: warm line last+1 without counting an
        // access or a miss (the fill happens off the critical path).
        if self.cfg.prefetch && !is_store {
            self.warm_line(last + 1);
        }
        ((last - first + 1) as u64, misses)
    }

    /// Insert a line at MRU without touching counters (prefetch fill).
    fn warm_line(&mut self, line: usize) {
        let (set, tag) = self.set_and_tag(line);
        let base = set * self.cfg.ways;
        let ways = &mut self.tags[base..base + self.cfg.ways];
        match ways.iter().position(|t| *t == Some(tag)) {
            Some(w) => {
                ways[..=w].rotate_right(1);
                ways[0] = Some(tag);
            }
            None => {
                ways.rotate_right(1);
                ways[0] = Some(tag);
            }
        }
    }

    /// Reset counters (keep cache contents — useful for warm-cache runs).
    pub fn reset_counters(&mut self) {
        self.load_accesses = 0;
        self.load_misses = 0;
        self.store_accesses = 0;
        self.store_misses = 0;
    }

    /// Flush contents and counters.
    pub fn flush(&mut self) {
        self.tags.fill(None);
        self.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets x 2 ways x 4-word lines = 32 words. Prefetch off so the
        // LRU/mapping tests below stay exact.
        Cache::new(CacheConfig {
            capacity_words: 32,
            ways: 2,
            line_words: 4,
            prefetch: false,
        })
    }

    fn small_prefetch() -> Cache {
        Cache::new(CacheConfig {
            capacity_words: 32,
            ways: 2,
            line_words: 4,
            prefetch: true,
        })
    }

    #[test]
    fn prefetch_hides_sequential_stream_misses() {
        let mut c = small_prefetch();
        // Sequential lines 0..4: only line 0 misses; 1..3 were warmed.
        for line in 0..4 {
            c.load(line * 4, 4);
        }
        assert_eq!(c.load_accesses, 4);
        assert_eq!(c.load_misses, 1);
    }

    #[test]
    fn prefetch_does_not_help_large_strides() {
        let mut c = small_prefetch();
        // Stride 2 lines: warmed line L+1 is never used.
        for i in 0..3 {
            c.load(i * 8, 4); // lines 0, 2, 4
        }
        assert_eq!(c.load_misses, 3);
    }

    #[test]
    fn prefetch_not_triggered_by_stores() {
        let mut c = small_prefetch();
        c.store(0, 4); // line 0; must NOT warm line 1
        c.load(4, 4); // line 1 → miss
        assert_eq!(c.load_misses, 1);
    }

    #[test]
    fn repeated_access_hits() {
        let mut c = small();
        c.load(0, 4); // miss
        c.load(0, 4); // hit
        c.load(2, 1); // same line, hit
        assert_eq!(c.load_accesses, 3);
        assert_eq!(c.load_misses, 1);
    }

    #[test]
    fn span_counts_lines() {
        let mut c = small();
        let (lines, misses) = c.load(2, 8); // words 2..10 → lines 0,1,2
        assert_eq!(lines, 3);
        assert_eq!(misses, 3);
    }

    #[test]
    fn lru_eviction() {
        let mut c = small(); // 4 sets; lines mapping to set 0: 0, 4, 8...
        c.load(0, 1); // line 0 -> set 0
        c.load(16, 1); // line 4 -> set 0
        c.load(0, 1); // hit, line 0 becomes MRU
        c.load(32, 1); // line 8 -> set 0, evicts line 4 (LRU)
        c.load(0, 1); // still resident: hit
        c.load(16, 1); // evicted: miss
        assert_eq!(c.load_misses, 4);
    }

    #[test]
    fn stores_counted_separately() {
        let mut c = small();
        c.store(0, 4);
        c.store(0, 4);
        assert_eq!(c.store_accesses, 2);
        assert_eq!(c.store_misses, 1);
        assert_eq!(c.load_accesses, 0);
    }

    #[test]
    fn store_then_load_same_line_hits() {
        let mut c = small();
        c.store(0, 4);
        c.load(0, 4);
        assert_eq!(c.load_misses, 0);
    }

    #[test]
    fn flush_clears_contents() {
        let mut c = small();
        c.load(0, 4);
        c.flush();
        c.load(0, 4);
        assert_eq!(c.load_misses, 1);
        assert_eq!(c.load_accesses, 1);
    }

    #[test]
    fn default_is_32kib_8way() {
        let c = Cache::new(CacheConfig::default());
        assert_eq!(c.sets, 64);
    }
}
