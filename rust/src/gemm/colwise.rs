//! Algorithm 1: the column-wise N:M sparse micro-kernel.
//!
//! For each (strip, tile): reserve T accumulators; for each retained
//! column `Idx[j]` of the tile, load the data row `A[Idx[j]]` **once**
//! and `vfmacc.vf` it into all T accumulators with each row's scalar
//! weight. Work is proportional to retained columns only; every data row
//! fetched is reused T times; accumulators never touch memory until the
//! final store — the three properties the paper's design targets.

use crate::im2col::{PackedMatrix, QuantPanel};
use crate::pruning::{ColwisePruned, ColwiseQuant};

use super::dense::MAX_TILE;
use super::kernels::{self, KernelId};

/// `C[rows, cols] = Wc · A`, Wc column-wise compressed, A packed.
/// Runs on the dispatched backend ([`KernelId::Auto`]).
pub fn spmm_colwise(w: &ColwisePruned, a: &PackedMatrix) -> Vec<f32> {
    spmm_colwise_with(w, a, KernelId::Auto)
}

/// [`spmm_colwise`] on an explicit micro-kernel backend.
pub fn spmm_colwise_with(w: &ColwisePruned, a: &PackedMatrix, kernel: KernelId) -> Vec<f32> {
    let mut c = vec![0.0f32; w.rows * a.cols];
    spmm_colwise_into_with(w, a, kernel, &mut c);
    c
}

/// In-place variant (hot-path entry), dispatched backend.
// nmprune: zero-alloc
pub fn spmm_colwise_into(w: &ColwisePruned, a: &PackedMatrix, c: &mut [f32]) {
    spmm_colwise_into_with(w, a, KernelId::Auto, c)
}

/// In-place variant on an explicit micro-kernel backend.
///
/// §Perf note: a width-monomorphised variant (const-V dispatch with
/// array-ref FMA bodies) was tried and *regressed* ~2.3× — the
/// per-iteration slice→array conversions defeated LLVM's existing
/// auto-vectorisation of the `zip` loop. Strip widths stay dynamic in
/// every backend; see EXPERIMENTS.md §Perf step 2.
// nmprune: zero-alloc
pub fn spmm_colwise_into_with(
    w: &ColwisePruned,
    a: &PackedMatrix,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.cols, a.k, "reduction dim mismatch");
    assert!(c.len() >= w.rows * a.cols);
    assert!(w.tile <= MAX_TILE, "tile {} > {}", w.tile, MAX_TILE);
    let kern = kernels::resolve(kernel);
    for strip in 0..a.strips {
        // SAFETY: `c` is a unique borrow covering the whole output, so
        // the strip kernel's disjoint-write requirement holds trivially.
        unsafe { kern.spmm_strip(w, a, strip, c.as_mut_ptr(), c.len()) }
    }
}

/// Quantized `C = dequant(Wq · Aq)`: i8×i8→i32 strip kernels with a
/// requantize-to-f32 epilogue. Dispatched backend.
pub fn spmm_colwise_i8(w: &ColwiseQuant, a: &QuantPanel) -> Vec<f32> {
    spmm_colwise_i8_with(w, a, KernelId::Auto)
}

/// [`spmm_colwise_i8`] on an explicit micro-kernel backend.
pub fn spmm_colwise_i8_with(w: &ColwiseQuant, a: &QuantPanel, kernel: KernelId) -> Vec<f32> {
    let mut c = vec![0.0f32; w.rows * a.cols];
    spmm_colwise_i8_into_with(w, a, kernel, &mut c);
    c
}

/// In-place quantized variant on an explicit backend (hot-path entry).
// nmprune: zero-alloc
pub fn spmm_colwise_i8_into_with(
    w: &ColwiseQuant,
    a: &QuantPanel,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.cols, a.k, "reduction dim mismatch");
    assert!(c.len() >= w.rows * a.cols);
    assert!(w.tile <= MAX_TILE, "tile {} > {}", w.tile, MAX_TILE);
    let kern = kernels::resolve(kernel);
    for strip in 0..a.strips {
        // SAFETY: `c` is a unique borrow covering the whole output, so
        // the strip kernel's disjoint-write requirement holds trivially.
        unsafe { kern.spmm_strip_i8(w, a, strip, c.as_mut_ptr(), c.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_ref;
    use crate::im2col::pack_data_matrix;
    use crate::pruning::{prune_colwise, prune_colwise_adaptive};
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn matches_reference_on_masked_weights() {
        let mut r = XorShiftRng::new(71);
        let (rows, k, cols) = (16, 32, 50);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        for (tile, n, m) in [(8, 2, 4), (4, 1, 4), (8, 3, 4), (1, 2, 4), (5, 4, 8)] {
            let cp = prune_colwise(&w, rows, k, tile, n, m);
            let want = matmul_ref(&cp.decompress(), &a, rows, k, cols);
            for v in [8, 16, 32] {
                let p = pack_data_matrix(&a, k, cols, v);
                let got = spmm_colwise(&cp, &p);
                assert!(
                    allclose(&got, &want, 1e-4, 1e-5),
                    "tile={tile} {n}:{m} v={v}"
                );
            }
        }
    }

    #[test]
    fn adaptive_m_full_row_groups() {
        let mut r = XorShiftRng::new(72);
        let (rows, k, cols) = (8, 64, 30);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise_adaptive(&w, rows, k, 8, 0.75);
        let p = pack_data_matrix(&a, k, cols, 16);
        let got = spmm_colwise(&cp, &p);
        let want = matmul_ref(&cp.decompress(), &a, rows, k, cols);
        assert!(allclose(&got, &want, 1e-4, 1e-5));
        // 75% sparsity → 16 of 64 columns retained per tile.
        assert_eq!(cp.retained_per_tile(), 16);
    }

    #[test]
    #[should_panic(expected = "accumulator capacity")]
    fn oversized_strip_width_rejected_at_kernel() {
        // The packing layer refuses v > MAX_STRIP_WIDTH, but the struct
        // fields are public — a hand-built matrix must still be caught
        // before it overruns the fixed accumulators.
        let w = prune_colwise(&[1.0], 1, 1, 1, 1, 1);
        let a = PackedMatrix {
            v: 128,
            k: 1,
            cols: 128,
            strips: 1,
            data: vec![0.0; 128],
        };
        spmm_colwise(&w, &a);
    }

    #[test]
    fn zero_retained_columns_outputs_zero() {
        // 0:M (n = 0) is rejected by prune_colwise — emulate an all-kept
        // tile whose retained values happen to be zero instead.
        let w = vec![0.0f32; 4 * 8];
        let cp = prune_colwise(&w, 4, 8, 2, 2, 4);
        let a: Vec<f32> = (0..8 * 6).map(|i| i as f32).collect();
        let p = pack_data_matrix(&a, 8, 6, 4);
        let got = spmm_colwise(&cp, &p);
        assert!(got.iter().all(|&x| x == 0.0));
    }

    /// Documented quantization-error contract: per output element,
    /// `|y_i8 − y_f32| ≤ Σ_retained (|w|·sa/2 + |a|·sw/2 + sw·sa/4)`
    /// plus f32 summation slack — the bound the conv fuzz harness
    /// rechecks end-to-end.
    #[test]
    fn i8_matches_f32_within_quantization_bound() {
        use crate::im2col::{quantize_panel_into, QuantPanel};
        use crate::pruning::ColwiseQuant;
        let mut r = XorShiftRng::new(74);
        let (rows, k, cols) = (12, 32, 33);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 4, 2, 4);
        let qw = ColwiseQuant::quantize(&cp);
        let p = pack_data_matrix(&a, k, cols, 8);
        let mut qa = QuantPanel::zeros(1, 1, 1);
        quantize_panel_into(&p, &mut qa);
        let f32_out = spmm_colwise_with(&cp, &p, KernelId::Scalar);
        let i8_out = spmm_colwise_i8(&qw, &qa);
        let dense = cp.decompress();
        for r_ in 0..rows {
            let sw = qw.scales[r_];
            for col in 0..cols {
                let mut bound = 1e-4f32;
                for kk in 0..k {
                    let wv = dense[r_ * k + kk];
                    if wv != 0.0 {
                        let av = a[kk * cols + col];
                        bound += wv.abs() * qa.scale * 0.5
                            + av.abs() * sw * 0.5
                            + sw * qa.scale * 0.25;
                    }
                }
                let d = (f32_out[r_ * cols + col] - i8_out[r_ * cols + col]).abs();
                assert!(d <= bound, "row {r_} col {col}: {d} > {bound}");
            }
        }
    }

    #[test]
    fn work_is_proportional_to_retained_columns() {
        // structural check: each tile iterates indices.len() columns.
        let mut r = XorShiftRng::new(73);
        let w = r.normal_vec(8 * 16, 1.0);
        let cp = prune_colwise(&w, 8, 16, 8, 1, 4);
        assert_eq!(cp.retained_per_tile(), 4); // 16/4 groups * 1
        let cp2 = prune_colwise(&w, 8, 16, 8, 3, 4);
        assert_eq!(cp2.retained_per_tile(), 12);
    }
}
