//! Dense tiled GEMM over packed strips — the dense baseline kernel.

use crate::im2col::{PackedMatrix, MAX_STRIP_WIDTH};

/// Maximum register-tile height supported without heap-allocating
/// accumulators (32 matches the RVV register file the paper tunes over).
pub const MAX_TILE: usize = 32;

/// `C[rows, cols] = W[rows, K] · A`, A packed in strips. `tile` output
/// rows are produced per micro-kernel invocation with accumulators kept
/// in a stack array (the vector-register analogue).
pub fn gemm_dense(w: &[f32], rows: usize, a: &PackedMatrix, tile: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; rows * a.cols];
    gemm_dense_into(w, rows, a, tile, &mut c);
    c
}

/// In-place variant writing into a caller-provided output buffer
/// (hot-path entry: avoids the allocation per conv layer).
pub fn gemm_dense_into(w: &[f32], rows: usize, a: &PackedMatrix, tile: usize, c: &mut [f32]) {
    let k = a.k;
    assert_eq!(w.len(), rows * k, "filter shape");
    assert!(c.len() >= rows * a.cols);
    assert!((1..=MAX_TILE).contains(&tile));
    assert!(
        a.v <= MAX_STRIP_WIDTH,
        "strip width {} exceeds accumulator capacity {MAX_STRIP_WIDTH}",
        a.v
    );
    // Accumulator block shared across micro-kernel invocations; each
    // invocation zeroes only its `t × valid` region (§Perf step 1).
    let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
    for strip in 0..a.strips {
        let sdata = a.strip(strip);
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        let mut row = 0;
        while row < rows {
            let t = tile.min(rows - row);
            micro_kernel_dense(w, row, t, k, sdata, a.v, valid, c, a.cols, col0, &mut acc);
            row += t;
        }
    }
}

/// One (strip, row-tile) micro-kernel: T accumulator rows over V lanes.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel_dense(
    w: &[f32],
    row0: usize,
    t: usize,
    k: usize,
    sdata: &[f32],
    v: usize,
    valid: usize,
    c: &mut [f32],
    cols: usize,
    col0: usize,
    acc: &mut [[f32; MAX_STRIP_WIDTH]; MAX_TILE],
) {
    // acc[t][v] — stack-resident, like the RVV accumulator registers.
    debug_assert!(v <= MAX_STRIP_WIDTH);
    for row in &mut acc[..t] {
        row[..valid].fill(0.0);
    }
    for kk in 0..k {
        let arow = &sdata[kk * v..kk * v + valid];
        for ti in 0..t {
            let wv = w[(row0 + ti) * k + kk];
            let accr = &mut acc[ti][..valid];
            for (aj, xj) in accr.iter_mut().zip(arow) {
                *aj += wv * xj; // vfmacc.vf
            }
        }
    }
    for ti in 0..t {
        let crow = &mut c[(row0 + ti) * cols + col0..(row0 + ti) * cols + col0 + valid];
        crow.copy_from_slice(&acc[ti][..valid]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_ref;
    use crate::im2col::pack_data_matrix;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn matches_reference_over_tiles() {
        let mut r = XorShiftRng::new(61);
        let (rows, k, cols) = (13, 24, 40);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let want = matmul_ref(&w, &a, rows, k, cols);
        for v in [4, 8, 16, 32] {
            let p = pack_data_matrix(&a, k, cols, v);
            for tile in [1, 2, 4, 7, 8, 13, 32] {
                let got = gemm_dense(&w, rows, &p, tile);
                assert!(
                    allclose(&got, &want, 1e-4, 1e-5),
                    "v={v} tile={tile}"
                );
            }
        }
    }

    #[test]
    fn single_element() {
        let p = pack_data_matrix(&[3.0], 1, 1, 8);
        let got = gemm_dense(&[2.0], 1, &p, 1);
        assert_eq!(got, vec![6.0]);
    }

    #[test]
    #[should_panic(expected = "filter shape")]
    fn wrong_filter_len_panics() {
        let p = pack_data_matrix(&[1.0, 2.0], 2, 1, 4);
        gemm_dense(&[1.0], 2, &p, 1);
    }
}
