//! Dense tiled GEMM over packed strips — the dense baseline kernel.

use crate::im2col::{PackedMatrix, QuantPanel};
use crate::pruning::QuantDense;

use super::kernels::{self, KernelId};

/// Maximum register-tile height supported without heap-allocating
/// accumulators (32 matches the RVV register file the paper tunes over).
pub const MAX_TILE: usize = 32;

/// `C[rows, cols] = W[rows, K] · A`, A packed in strips. `tile` output
/// rows are produced per micro-kernel invocation with accumulators kept
/// in a stack array (the vector-register analogue). Runs on the
/// dispatched backend ([`KernelId::Auto`]).
pub fn gemm_dense(w: &[f32], rows: usize, a: &PackedMatrix, tile: usize) -> Vec<f32> {
    gemm_dense_with(w, rows, a, tile, KernelId::Auto)
}

/// [`gemm_dense`] on an explicit micro-kernel backend.
pub fn gemm_dense_with(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    kernel: KernelId,
) -> Vec<f32> {
    let mut c = vec![0.0f32; rows * a.cols];
    gemm_dense_into_with(w, rows, a, tile, kernel, &mut c);
    c
}

/// In-place variant writing into a caller-provided output buffer
/// (hot-path entry: avoids the allocation per conv layer).
// nmprune: zero-alloc
pub fn gemm_dense_into(w: &[f32], rows: usize, a: &PackedMatrix, tile: usize, c: &mut [f32]) {
    gemm_dense_into_with(w, rows, a, tile, KernelId::Auto, c)
}

/// In-place variant on an explicit micro-kernel backend.
// nmprune: zero-alloc
pub fn gemm_dense_into_with(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.len(), rows * a.k, "filter shape");
    assert!(c.len() >= rows * a.cols);
    assert!((1..=MAX_TILE).contains(&tile));
    let kern = kernels::resolve(kernel);
    for strip in 0..a.strips {
        // SAFETY: `c` is a unique borrow covering the whole output, so
        // the strip kernel's disjoint-write requirement holds trivially.
        unsafe { kern.dense_strip(w, rows, a, tile, strip, c.as_mut_ptr(), c.len()) }
    }
}

/// Quantized dense GEMM: i8×i8→i32 strip kernels with a requantize-to-
/// f32 epilogue. Dispatched backend.
pub fn gemm_dense_i8(w: &QuantDense, a: &QuantPanel, tile: usize) -> Vec<f32> {
    gemm_dense_i8_with(w, a, tile, KernelId::Auto)
}

/// [`gemm_dense_i8`] on an explicit micro-kernel backend.
pub fn gemm_dense_i8_with(
    w: &QuantDense,
    a: &QuantPanel,
    tile: usize,
    kernel: KernelId,
) -> Vec<f32> {
    let mut c = vec![0.0f32; w.rows * a.cols];
    gemm_dense_i8_into_with(w, a, tile, kernel, &mut c);
    c
}

/// In-place quantized variant on an explicit backend (hot-path entry).
// nmprune: zero-alloc
pub fn gemm_dense_i8_into_with(
    w: &QuantDense,
    a: &QuantPanel,
    tile: usize,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.k, a.k, "reduction dim mismatch");
    assert_eq!(w.values.len(), w.rows * w.k, "filter shape");
    assert!(c.len() >= w.rows * a.cols);
    assert!((1..=MAX_TILE).contains(&tile));
    let kern = kernels::resolve(kernel);
    for strip in 0..a.strips {
        // SAFETY: `c` is a unique borrow covering the whole output, so
        // the strip kernel's disjoint-write requirement holds trivially.
        unsafe { kern.dense_strip_i8(w, a, tile, strip, c.as_mut_ptr(), c.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_ref;
    use crate::im2col::pack_data_matrix;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn matches_reference_over_tiles() {
        let mut r = XorShiftRng::new(61);
        let (rows, k, cols) = (13, 24, 40);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let want = matmul_ref(&w, &a, rows, k, cols);
        for v in [4, 8, 16, 32] {
            let p = pack_data_matrix(&a, k, cols, v);
            for tile in [1, 2, 4, 7, 8, 13, 32] {
                let got = gemm_dense(&w, rows, &p, tile);
                assert!(
                    allclose(&got, &want, 1e-4, 1e-5),
                    "v={v} tile={tile}"
                );
            }
        }
    }

    #[test]
    fn single_element() {
        let p = pack_data_matrix(&[3.0], 1, 1, 8);
        let got = gemm_dense(&[2.0], 1, &p, 1);
        assert_eq!(got, vec![6.0]);
    }

    /// i8 dense path approximates f32 closely on well-scaled data and
    /// is invariant to the tile parameter (tiling never changes integer
    /// arithmetic).
    #[test]
    fn i8_dense_tracks_f32_and_is_tile_invariant() {
        use crate::im2col::{quantize_panel_into, QuantPanel};
        let mut r = XorShiftRng::new(62);
        let (rows, k, cols) = (13, 24, 40);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let qw = QuantDense::quantize(&w, rows, k);
        let p = pack_data_matrix(&a, k, cols, 8);
        let mut qa = QuantPanel::zeros(1, 1, 1);
        quantize_panel_into(&p, &mut qa);
        let want = matmul_ref(&w, &a, rows, k, cols);
        let base = gemm_dense_i8(&qw, &qa, 1);
        // Coarse closeness only — comfortably inside the worst-case
        // quantization bound for k=24 (the precise per-element bound is
        // asserted in colwise.rs and the conv fuzz harness).
        assert!(allclose(&base, &want, 0.0, 0.75));
        for tile in [2, 4, 7, 13, 32] {
            assert_eq!(base, gemm_dense_i8(&qw, &qa, tile), "tile={tile}");
        }
    }

    #[test]
    #[should_panic(expected = "reduction dim mismatch")]
    fn i8_reduction_mismatch_panics() {
        let qw = QuantDense::quantize(&[1.0, 2.0], 1, 2);
        let mut qa = QuantPanel::zeros(3, 4, 4);
        qa.scale = 1.0;
        gemm_dense_i8(&qw, &qa, 1);
    }

    #[test]
    #[should_panic(expected = "filter shape")]
    fn wrong_filter_len_panics() {
        let p = pack_data_matrix(&[1.0, 2.0], 2, 1, 4);
        gemm_dense(&[1.0], 2, &p, 1);
    }
}
