//! Dense tiled GEMM over packed strips — the dense baseline kernel.

use crate::im2col::PackedMatrix;

use super::kernels::{self, KernelId};

/// Maximum register-tile height supported without heap-allocating
/// accumulators (32 matches the RVV register file the paper tunes over).
pub const MAX_TILE: usize = 32;

/// `C[rows, cols] = W[rows, K] · A`, A packed in strips. `tile` output
/// rows are produced per micro-kernel invocation with accumulators kept
/// in a stack array (the vector-register analogue). Runs on the
/// dispatched backend ([`KernelId::Auto`]).
pub fn gemm_dense(w: &[f32], rows: usize, a: &PackedMatrix, tile: usize) -> Vec<f32> {
    gemm_dense_with(w, rows, a, tile, KernelId::Auto)
}

/// [`gemm_dense`] on an explicit micro-kernel backend.
pub fn gemm_dense_with(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    kernel: KernelId,
) -> Vec<f32> {
    let mut c = vec![0.0f32; rows * a.cols];
    gemm_dense_into_with(w, rows, a, tile, kernel, &mut c);
    c
}

/// In-place variant writing into a caller-provided output buffer
/// (hot-path entry: avoids the allocation per conv layer).
// nmprune: zero-alloc
pub fn gemm_dense_into(w: &[f32], rows: usize, a: &PackedMatrix, tile: usize, c: &mut [f32]) {
    gemm_dense_into_with(w, rows, a, tile, KernelId::Auto, c)
}

/// In-place variant on an explicit micro-kernel backend.
// nmprune: zero-alloc
pub fn gemm_dense_into_with(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.len(), rows * a.k, "filter shape");
    assert!(c.len() >= rows * a.cols);
    assert!((1..=MAX_TILE).contains(&tile));
    let kern = kernels::resolve(kernel);
    for strip in 0..a.strips {
        // SAFETY: `c` is a unique borrow covering the whole output, so
        // the strip kernel's disjoint-write requirement holds trivially.
        unsafe { kern.dense_strip(w, rows, a, tile, strip, c.as_mut_ptr(), c.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_ref;
    use crate::im2col::pack_data_matrix;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn matches_reference_over_tiles() {
        let mut r = XorShiftRng::new(61);
        let (rows, k, cols) = (13, 24, 40);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let want = matmul_ref(&w, &a, rows, k, cols);
        for v in [4, 8, 16, 32] {
            let p = pack_data_matrix(&a, k, cols, v);
            for tile in [1, 2, 4, 7, 8, 13, 32] {
                let got = gemm_dense(&w, rows, &p, tile);
                assert!(
                    allclose(&got, &want, 1e-4, 1e-5),
                    "v={v} tile={tile}"
                );
            }
        }
    }

    #[test]
    fn single_element() {
        let p = pack_data_matrix(&[3.0], 1, 1, 8);
        let got = gemm_dense(&[2.0], 1, &p, 1);
        assert_eq!(got, vec![6.0]);
    }

    #[test]
    #[should_panic(expected = "filter shape")]
    fn wrong_filter_len_panics() {
        let p = pack_data_matrix(&[1.0, 2.0], 2, 1, 4);
        gemm_dense(&[1.0], 2, &p, 1);
    }
}
