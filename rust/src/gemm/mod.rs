//! Tiled GEMM micro-kernels over packed data strips.
//!
//! Every kernel computes `C[rows, cols] = W · A` where `W[rows, K]` is a
//! (possibly compressed) filter matrix and `A[K, cols]` arrives as a
//! [`crate::im2col::PackedMatrix`] of `V`-wide strips. One (strip,
//! row-tile) pair is a micro-kernel invocation — the unit XNNPACK
//! parallelises over and the unit the paper's tuner profiles.
//!
//! * [`dense`] — dense baseline: all K rows of the strip are streamed.
//! * [`colwise`] — Algorithm 1: outer-product over the tile's shared
//!   retained-column set, accumulators register-resident.
//! * [`inner`] — conventional row-based N:M, inner-product order: each
//!   output row gathers its own columns → data rows are re-fetched per
//!   row (the redundant-*load* pathology, §3.1).
//! * [`outer`] — conventional row-based N:M, outer-product order: data
//!   rows are reused but partial sums scatter to memory (the
//!   redundant-*store* pathology, §3.1). This is the "conventional N:M"
//!   configuration of Fig. 5.
//! * [`threaded`] — output-tile parallel driver shared by all kernels.
//! * [`kernels`] — runtime-dispatched SIMD micro-kernel backends (the
//!   scalar parity oracle plus AVX2/AVX-512/NEON `std::arch`
//!   implementations) behind the [`kernels::Kernel`] trait; the dense
//!   and colwise drivers above route every strip through it.

pub mod dense;
pub mod colwise;
pub mod inner;
pub mod kernels;
pub mod outer;
pub mod threaded;

pub use colwise::{spmm_colwise, spmm_colwise_i8, spmm_colwise_i8_with, spmm_colwise_with};
pub use dense::{gemm_dense, gemm_dense_i8, gemm_dense_i8_with, gemm_dense_with};
pub use inner::spmm_inner_rownm;
pub use kernels::KernelId;
pub use outer::spmm_outer_rownm;

/// Reference dense matmul `C[rows, cols] = W[rows, K] · A[K, cols]`,
/// unpacked and unoptimised — the oracle for every kernel here.
pub fn matmul_ref(w: &[f32], a: &[f32], rows: usize, k: usize, cols: usize) -> Vec<f32> {
    assert_eq!(w.len(), rows * k);
    assert_eq!(a.len(), k * cols);
    let mut c = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for kk in 0..k {
            let wv = w[r * k + kk];
            if wv == 0.0 {
                continue;
            }
            let arow = &a[kk * cols..(kk + 1) * cols];
            let crow = &mut c[r * cols..(r + 1) * cols];
            for (cj, aj) in crow.iter_mut().zip(arow) {
                *cj += wv * aj;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::pack_data_matrix;
    use crate::pruning::{prune_colwise, prune_rownm};
    use crate::util::{allclose, prop};

    /// All four kernels must agree with the reference on the *same*
    /// masked weights, across random shapes/tiles/vector widths.
    #[test]
    fn prop_all_kernels_match_reference() {
        prop::check_seeded(
            0x6E44,
            |r, size| {
                let rows = 1 + size % 24;
                let k = 4 * (1 + r.below(12));
                let cols = 1 + r.below(70);
                let v = [4, 8, 16, 32][r.below(4)];
                let tile = 1 + r.below(8);
                let w = r.normal_vec(rows * k, 1.0);
                let a = r.normal_vec(k * cols, 1.0);
                (w, a, rows, k, cols, v, tile)
            },
            |(w, a, rows, k, cols, v, tile)| {
                let packed = pack_data_matrix(a, *k, *cols, *v);

                // Column-wise kernel vs reference on its own mask.
                let cp = prune_colwise(w, *rows, *k, *tile, 2, 4);
                let got = spmm_colwise(&cp, &packed);
                let want = matmul_ref(&cp.decompress(), a, *rows, *k, *cols);
                if !allclose(&got, &want, 1e-4, 1e-5) {
                    return false;
                }

                // Row-based N:M kernels vs reference on their mask.
                let rp = prune_rownm(w, *rows, *k, 2, 4);
                let want_r = matmul_ref(&rp.decompress(), a, *rows, *k, *cols);
                let got_i = spmm_inner_rownm(&rp, &packed);
                let got_o = spmm_outer_rownm(&rp, &packed);
                if !allclose(&got_i, &want_r, 1e-4, 1e-5) {
                    return false;
                }
                if !allclose(&got_o, &want_r, 1e-4, 1e-5) {
                    return false;
                }

                // Dense kernel vs reference.
                let got_d = gemm_dense(w, *rows, &packed, *tile);
                let want_d = matmul_ref(w, a, *rows, *k, *cols);
                allclose(&got_d, &want_d, 1e-4, 1e-5)
            },
        );
    }
}
