//! Runtime-dispatched SIMD micro-kernel backends.
//!
//! The innermost strip/tile compute of the column-wise N:M spMM
//! (Algorithm 1) and the dense GEMM baseline is abstracted behind the
//! [`Kernel`] trait. The scalar implementation is the *permanent parity
//! oracle* — byte-for-byte the arithmetic this crate has always done —
//! and the `std::arch` implementations (x86_64 AVX2+FMA, AVX-512 where
//! the compiler supports it, aarch64 NEON) are selected at runtime via
//! CPU feature detection, the paper's `vfmacc.vf` realised as
//! `_mm256_fmadd_ps` / `vfmaq_n_f32`.
//!
//! Dispatch rules:
//!
//! * `NMPRUNE_KERNEL=<name>` forces a kernel process-wide. Forcing a
//!   kernel that is unknown or unavailable on the host **panics** — CI
//!   uses this to guarantee dispatch can never silently fall back.
//! * Without the override, [`KernelId::Auto`] resolves to
//!   [`best_available`], and an *advisory* non-`Auto` choice (from a
//!   tune cache or a packed artifact produced on another host) falls
//!   back to [`best_available`] when the requested kernel is not
//!   available here — artifacts stay portable.
//!
//! Parity contract: for a **fixed** kernel, results are bitwise
//! identical across serial/parallel/capped/adaptive execution (strip
//! decomposition never changes per-strip arithmetic). **Across**
//! kernels, FMA contraction reassociates rounding, so native outputs
//! are gated against the scalar oracle by the explicit bound
//! [`within_parity_bound`] in the differential fuzz harness
//! (`rust/tests/conv_fuzz.rs`).

use std::sync::OnceLock;

use crate::im2col::{PackedMatrix, QuantPanel, MAX_STRIP_WIDTH};
use crate::pruning::{ColwisePruned, ColwiseQuant, QuantDense};

use super::dense::MAX_TILE;

/// Identifies a micro-kernel backend. `Auto` is the "let dispatch
/// decide" value used by tuner/artifact metadata; it is never itself a
/// registered kernel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelId {
    /// Resolve to [`best_available`] at dispatch time.
    #[default]
    Auto,
    /// Plain Rust reference kernel — the parity oracle.
    Scalar,
    /// x86_64 AVX2 + FMA (8 f32 lanes).
    Avx2,
    /// x86_64 AVX-512F (16 f32 lanes); compiled only when the building
    /// rustc stabilises the intrinsics (see `rust/build.rs`).
    Avx512,
    /// aarch64 NEON (4 f32 lanes).
    Neon,
}

/// Every identifier, in artifact-code order.
pub const ALL_KERNEL_IDS: [KernelId; 5] = [
    KernelId::Auto,
    KernelId::Scalar,
    KernelId::Avx2,
    KernelId::Avx512,
    KernelId::Neon,
];

impl KernelId {
    /// Stable lower-case name (TSV / env / CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            KernelId::Auto => "auto",
            KernelId::Scalar => "scalar",
            KernelId::Avx2 => "avx2",
            KernelId::Avx512 => "avx512",
            KernelId::Neon => "neon",
        }
    }

    /// Inverse of [`KernelId::name`].
    pub fn from_name(s: &str) -> Option<KernelId> {
        ALL_KERNEL_IDS.into_iter().find(|id| id.name() == s)
    }

    /// Stable numeric code used by the packed-artifact format.
    pub fn code(self) -> u32 {
        match self {
            KernelId::Auto => 0,
            KernelId::Scalar => 1,
            KernelId::Avx2 => 2,
            KernelId::Avx512 => 3,
            KernelId::Neon => 4,
        }
    }

    /// Inverse of [`KernelId::code`].
    pub fn from_code(c: u32) -> Option<KernelId> {
        ALL_KERNEL_IDS.into_iter().find(|id| id.code() == c)
    }
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A strip-level micro-kernel backend: the unit of compute both the
/// serial and the pool-parallel drivers dispatch per strip.
pub trait Kernel: Sync {
    /// Which backend this is.
    fn id(&self) -> KernelId;

    /// Whether the host CPU can run this backend (checked at runtime).
    fn available(&self) -> bool;

    /// Column-wise N:M spMM over one strip, all tiles (Algorithm 1).
    ///
    /// # Safety
    /// `c` must be valid for reads and writes of `c_len >= w.rows *
    /// a.cols` f32s, `strip < a.strips`, and no other thread may
    /// concurrently access this strip's output column ranges.
    unsafe fn spmm_strip(
        &self,
        w: &ColwisePruned,
        a: &PackedMatrix,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    );

    /// Dense GEMM over one strip, all row-tiles of height `tile`.
    ///
    /// # Safety
    /// `c` must be valid for reads and writes of `c_len >= rows *
    /// a.cols` f32s, `w.len() == rows * a.k`, `strip < a.strips`,
    /// `1 <= tile <= MAX_TILE`, and no other thread may concurrently
    /// access this strip's output column ranges.
    #[allow(clippy::too_many_arguments)]
    unsafe fn dense_strip(
        &self,
        w: &[f32],
        rows: usize,
        a: &PackedMatrix,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    );

    /// Whether this backend has a *native* (SIMD) i8 path, as opposed
    /// to inheriting the shared scalar i8 body. Listing/roofline
    /// metadata only — dispatch always works either way.
    fn i8_native(&self) -> bool {
        false
    }

    /// Quantized column-wise N:M spMM over one strip, all tiles:
    /// i8×i8→i32 accumulation, requantize-to-f32 epilogue
    /// (`acc as f32 * (w.scales[row] * a.scale)`).
    ///
    /// Unlike the f32 kernels, **every** backend is bitwise identical
    /// here: integer accumulation is order-independent (no rounding
    /// until the single f32 multiply in the epilogue, which is the same
    /// scalar expression in all bodies). The conv fuzz harness asserts
    /// this cross-backend equality exactly.
    ///
    /// # Safety
    /// Same contract as [`Kernel::spmm_strip`]: `c` valid for
    /// reads/writes of `c_len >= w.rows * a.cols` f32s, `strip <
    /// a.strips`, exclusive access to this strip's output columns.
    unsafe fn spmm_strip_i8(
        &self,
        w: &ColwiseQuant,
        a: &QuantPanel,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: contract forwarded verbatim to the shared scalar body.
        unsafe { spmm_strip_i8_scalar(w, a, strip, c, c_len) }
    }

    /// Quantized dense GEMM over one strip, all row-tiles of height
    /// `tile`. Same bitwise-identical-across-backends contract as
    /// [`Kernel::spmm_strip_i8`].
    ///
    /// # Safety
    /// Same contract as [`Kernel::dense_strip`] with `rows = w.rows`:
    /// `c` valid for reads/writes of `c_len >= w.rows * a.cols` f32s,
    /// `w.k == a.k`, `strip < a.strips`, `1 <= tile <= MAX_TILE`,
    /// exclusive access to this strip's output columns.
    unsafe fn dense_strip_i8(
        &self,
        w: &QuantDense,
        a: &QuantPanel,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: contract forwarded verbatim to the shared scalar body.
        unsafe { dense_strip_i8_scalar(w, a, tile, strip, c, c_len) }
    }
}

/// Shared prologue: strip data, valid lane count, first output column.
/// The `v` bound is a hard assert, not `debug_assert` — `PackedMatrix`
/// fields are public and an oversized strip would overrun the fixed
/// accumulator block in release builds.
#[inline]
fn strip_geometry(a: &PackedMatrix, strip: usize) -> (&[f32], usize, usize) {
    assert!(
        a.v <= MAX_STRIP_WIDTH,
        "strip width {} exceeds accumulator capacity {MAX_STRIP_WIDTH}",
        a.v
    );
    (a.strip(strip), a.strip_valid(strip), strip * a.v)
}

/// [`strip_geometry`] for the quantized panel (same invariants).
#[inline]
fn quant_strip_geometry(a: &QuantPanel, strip: usize) -> (&[i8], usize, usize) {
    assert!(
        a.v <= MAX_STRIP_WIDTH,
        "strip width {} exceeds accumulator capacity {MAX_STRIP_WIDTH}",
        a.v
    );
    (a.strip(strip), a.strip_valid(strip), strip * a.v)
}

// ----------------------------------------------------- shared i8 bodies
//
// The scalar i8 bodies are free functions (not `ScalarKernel` methods)
// because they double as the default `Kernel` trait implementation:
// every backend without a native i8 path runs exactly this arithmetic.
// i32 accumulation of i8×i8 products is exact (|acc| <= K·127² — i32
// overflows only past K ≈ 133k, far beyond any conv reduction here),
// so the only rounding is the one f32 multiply in the epilogue.

/// Scalar quantized spMM strip body (and the trait default).
///
/// # Safety
/// Same contract as [`Kernel::spmm_strip_i8`].
unsafe fn spmm_strip_i8_scalar(
    w: &ColwiseQuant,
    a: &QuantPanel,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    let (sdata, valid, col0) = quant_strip_geometry(a, strip);
    let mut acc = [[0i32; MAX_STRIP_WIDTH]; MAX_TILE];
    for tile in &w.tiles {
        let t = tile.row_count;
        let nret = tile.indices.len();
        for row in &mut acc[..t] {
            row[..valid].fill(0);
        }
        for (j, &idx) in tile.indices.iter().enumerate() {
            let arow = &sdata[idx as usize * a.v..idx as usize * a.v + valid];
            for ti in 0..t {
                let wv = tile.values[ti * nret + j] as i32;
                for (aj, &xj) in acc[ti][..valid].iter_mut().zip(arow) {
                    *aj += wv * xj as i32;
                }
            }
        }
        for ti in 0..t {
            let r = tile.row_start + ti;
            let s = w.scales[r] * a.scale;
            let off = r * a.cols + col0;
            assert!(off + valid <= c_len, "output out of bounds");
            for (x, &av) in acc[ti][..valid].iter().enumerate() {
                // SAFETY: asserted off+valid <= c_len and the contract
                // gives exclusive access to these output columns.
                unsafe { *c.add(off + x) = av as f32 * s };
            }
        }
    }
}

/// Scalar quantized dense strip body (and the trait default).
///
/// # Safety
/// Same contract as [`Kernel::dense_strip_i8`].
unsafe fn dense_strip_i8_scalar(
    w: &QuantDense,
    a: &QuantPanel,
    tile: usize,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    let (sdata, valid, col0) = quant_strip_geometry(a, strip);
    let k = a.k;
    let rows = w.rows;
    let mut row = 0;
    while row < rows {
        let t = tile.min(rows - row);
        let mut acc = [[0i32; MAX_STRIP_WIDTH]; MAX_TILE];
        for kk in 0..k {
            let arow = &sdata[kk * a.v..kk * a.v + valid];
            for ti in 0..t {
                let wv = w.values[(row + ti) * k + kk] as i32;
                for (aj, &xj) in acc[ti][..valid].iter_mut().zip(arow) {
                    *aj += wv * xj as i32;
                }
            }
        }
        for ti in 0..t {
            let s = w.scales[row + ti] * a.scale;
            let off = (row + ti) * a.cols + col0;
            assert!(off + valid <= c_len, "output out of bounds");
            for (x, &av) in acc[ti][..valid].iter().enumerate() {
                // SAFETY: asserted off+valid <= c_len and the contract
                // gives exclusive access to these output columns.
                unsafe { *c.add(off + x) = av as f32 * s };
            }
        }
        row += t;
    }
}

// ---------------------------------------------------------------- scalar

/// The plain-Rust reference backend (auto-vectorised by LLVM, no
/// contraction: `a + w*x` rounds twice, deterministically).
pub struct ScalarKernel;

impl Kernel for ScalarKernel {
    fn id(&self) -> KernelId {
        KernelId::Scalar
    }

    fn available(&self) -> bool {
        true
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip` contract (c valid
    // for c_len f32s, strip in range, exclusive access to this strip's
    // output columns).
    unsafe fn spmm_strip(
        &self,
        w: &ColwisePruned,
        a: &PackedMatrix,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        let (sdata, valid, col0) = strip_geometry(a, strip);
        // One accumulator block for the whole strip; each tile zeroes
        // only the `t × valid` region it uses (§Perf step 1: the full
        // 8 KiB memset per tile dominated small tiles).
        let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
        for tile in &w.tiles {
            let t = tile.row_count;
            let nret = tile.indices.len();
            for row in &mut acc[..t] {
                row[..valid].fill(0.0);
            }
            for (j, &idx) in tile.indices.iter().enumerate() {
                // Single load of the data row, reused across all T rows.
                let arow = &sdata[idx as usize * a.v..idx as usize * a.v + valid];
                for ti in 0..t {
                    let wv = tile.values[ti * nret + j]; // scalar weight
                    let accr = &mut acc[ti][..valid];
                    for (aj, xj) in accr.iter_mut().zip(arow) {
                        *aj += wv * xj; // vfmacc.vf
                    }
                }
            }
            for ti in 0..t {
                let r = tile.row_start + ti;
                let off = r * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                // SAFETY: asserted off+valid <= c_len, the source is the
                // local accumulator row (valid <= MAX_STRIP_WIDTH), and
                // the contract gives exclusive access to these columns.
                unsafe { std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid) };
            }
        }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip` contract (c
    // valid for c_len f32s, w sized rows*k, tile <= MAX_TILE, strip in
    // range, exclusive access to this strip's output columns).
    unsafe fn dense_strip(
        &self,
        w: &[f32],
        rows: usize,
        a: &PackedMatrix,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        let (sdata, valid, col0) = strip_geometry(a, strip);
        let k = a.k;
        let mut row = 0;
        while row < rows {
            let t = tile.min(rows - row);
            let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
            for kk in 0..k {
                let arow = &sdata[kk * a.v..kk * a.v + valid];
                for ti in 0..t {
                    let wv = w[(row + ti) * k + kk];
                    for (aj, xj) in acc[ti][..valid].iter_mut().zip(arow) {
                        *aj += wv * xj;
                    }
                }
            }
            for ti in 0..t {
                let off = (row + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                // SAFETY: asserted off+valid <= c_len, the source is the
                // local accumulator row (valid <= MAX_STRIP_WIDTH), and
                // the contract gives exclusive access to these columns.
                unsafe { std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid) };
            }
            row += t;
        }
    }
}

// ------------------------------------------------------------ x86_64 AVX2

/// AVX2 + FMA backend: 8-lane fused multiply-add with a scalar tail.
#[cfg(target_arch = "x86_64")]
pub struct Avx2Kernel;

#[cfg(target_arch = "x86_64")]
impl Kernel for Avx2Kernel {
    fn id(&self) -> KernelId {
        KernelId::Avx2
    }

    fn available(&self) -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip` contract.
    unsafe fn spmm_strip(
        &self,
        w: &ColwisePruned,
        a: &PackedMatrix,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so avx2+fma are present on this CPU.
        unsafe { spmm_strip_avx2(w, a, strip, c, c_len) }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip` contract.
    unsafe fn dense_strip(
        &self,
        w: &[f32],
        rows: usize,
        a: &PackedMatrix,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so avx2+fma are present on this CPU.
        unsafe { dense_strip_avx2(w, rows, a, tile, strip, c, c_len) }
    }

    fn i8_native(&self) -> bool {
        true
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip_i8` contract.
    unsafe fn spmm_strip_i8(
        &self,
        w: &ColwiseQuant,
        a: &QuantPanel,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so avx2 is present on this CPU.
        unsafe { spmm_strip_i8_avx2(w, a, strip, c, c_len) }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip_i8` contract.
    unsafe fn dense_strip_i8(
        &self,
        w: &QuantDense,
        a: &QuantPanel,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so avx2 is present on this CPU.
        unsafe { dense_strip_i8_avx2(w, a, tile, strip, c, c_len) }
    }
}

/// Pack two i8 weights into the `(lo, hi)` i16 halves of one i32, for
/// broadcasting against [`_mm256_madd_epi16`]'s pairwise dot product.
/// `i8 as u16` sign-extends, so each half is the weight's i16 two's
/// complement.
#[cfg(target_arch = "x86_64")]
#[inline]
fn madd_weight_pair(w0: i8, w1: i8) -> i32 {
    (((w1 as u16 as u32) << 16) | (w0 as u16 as u32)) as i32
}

/// AVX2 quantized spMM strip body: retained columns are consumed in
/// *pairs* so each `_mm256_madd_epi16` computes `a0·w0 + a1·w1` for 8
/// output lanes at once. Exactness: both operands are clamped to ±127
/// at quantization, so every i16 pair-sum is `<= 2·127² = 32258 <
/// i16::MAX` away from `madd`'s only overflow case (`(-32768)²`), and
/// the i32 adds are exact — bitwise identical to the scalar body.
///
/// # Safety
/// Same contract as `Kernel::spmm_strip_i8`, plus: the host CPU must
/// support avx2 (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn spmm_strip_i8_avx2(
    w: &ColwiseQuant,
    a: &QuantPanel,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::x86_64::*;
    let (sdata, valid, col0) = quant_strip_geometry(a, strip);
    let mut acc = [[0i32; MAX_STRIP_WIDTH]; MAX_TILE];
    // SAFETY: one region for the whole strip body. Intrinsics are
    // runnable (avx2 per the fn contract); the 8-byte loads stay inside
    // the strip rows because x+8 <= valid <= a.v and each row holds a.v
    // bytes (an unpaired trailing column aliases p1 to p0 with w1 = 0,
    // so both loads still target a real row); accumulator loads/stores
    // stay inside acc[ti] because x+8 <= valid <= MAX_STRIP_WIDTH; the
    // epilogue writes c[off..off+valid] with off+valid <= c_len
    // asserted, and the contract gives exclusive access to those
    // columns.
    unsafe {
        for tile in &w.tiles {
            let t = tile.row_count;
            let nret = tile.indices.len();
            for row in &mut acc[..t] {
                row[..valid].fill(0);
            }
            let mut j = 0;
            while j < nret {
                let paired = j + 1 < nret;
                let idx0 = tile.indices[j] as usize;
                let idx1 = if paired { tile.indices[j + 1] as usize } else { idx0 };
                let p0 = sdata.as_ptr().add(idx0 * a.v);
                let p1 = sdata.as_ptr().add(idx1 * a.v);
                for ti in 0..t {
                    let w0 = tile.values[ti * nret + j];
                    let w1 = if paired { tile.values[ti * nret + j + 1] } else { 0 };
                    let wv = _mm256_set1_epi32(madd_weight_pair(w0, w1));
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 8 <= valid {
                        // 8 bytes of each column row, interleaved to
                        // (a0[i], a1[i]) i16 pairs for the madd.
                        let a0 = _mm_loadl_epi64(p0.add(x) as *const __m128i);
                        let a1 = _mm_loadl_epi64(p1.add(x) as *const __m128i);
                        let il = _mm_unpacklo_epi8(a0, a1);
                        let pairs = _mm256_cvtepi8_epi16(il);
                        let prod = _mm256_madd_epi16(pairs, wv);
                        let cv = _mm256_loadu_si256(accp.add(x) as *const __m256i);
                        _mm256_storeu_si256(
                            accp.add(x) as *mut __m256i,
                            _mm256_add_epi32(cv, prod),
                        );
                        x += 8;
                    }
                    while x < valid {
                        *accp.add(x) +=
                            w0 as i32 * *p0.add(x) as i32 + w1 as i32 * *p1.add(x) as i32;
                        x += 1;
                    }
                }
                j += 2;
            }
            for ti in 0..t {
                let r = tile.row_start + ti;
                let s = w.scales[r] * a.scale;
                let off = r * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                // Requantize epilogue: scalar on purpose — identical
                // expression in every backend keeps i8 outputs bitwise
                // equal across kernels.
                for (x, &av) in acc[ti][..valid].iter().enumerate() {
                    *c.add(off + x) = av as f32 * s;
                }
            }
        }
    }
}

/// AVX2 quantized dense strip body: consecutive reduction rows are
/// consumed in pairs, same `madd` scheme (and the same exactness
/// argument) as [`spmm_strip_i8_avx2`].
///
/// # Safety
/// Same contract as `Kernel::dense_strip_i8`, plus: the host CPU must
/// support avx2 (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dense_strip_i8_avx2(
    w: &QuantDense,
    a: &QuantPanel,
    tile: usize,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::x86_64::*;
    let (sdata, valid, col0) = quant_strip_geometry(a, strip);
    let k = a.k;
    let rows = w.rows;
    let mut row = 0;
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_i8_avx2 (feature-gated intrinsics, x+8 <= valid lane
    // bounds, a trailing odd reduction row aliases p1 to p0 with
    // w1 = 0, asserted off+valid <= c_len output range).
    unsafe {
        while row < rows {
            let t = tile.min(rows - row);
            let mut acc = [[0i32; MAX_STRIP_WIDTH]; MAX_TILE];
            let mut kk = 0;
            while kk < k {
                let paired = kk + 1 < k;
                let p0 = sdata.as_ptr().add(kk * a.v);
                let p1 = if paired { sdata.as_ptr().add((kk + 1) * a.v) } else { p0 };
                for ti in 0..t {
                    let w0 = w.values[(row + ti) * k + kk];
                    let w1 = if paired { w.values[(row + ti) * k + kk + 1] } else { 0 };
                    let wv = _mm256_set1_epi32(madd_weight_pair(w0, w1));
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 8 <= valid {
                        let a0 = _mm_loadl_epi64(p0.add(x) as *const __m128i);
                        let a1 = _mm_loadl_epi64(p1.add(x) as *const __m128i);
                        let il = _mm_unpacklo_epi8(a0, a1);
                        let pairs = _mm256_cvtepi8_epi16(il);
                        let prod = _mm256_madd_epi16(pairs, wv);
                        let cv = _mm256_loadu_si256(accp.add(x) as *const __m256i);
                        _mm256_storeu_si256(
                            accp.add(x) as *mut __m256i,
                            _mm256_add_epi32(cv, prod),
                        );
                        x += 8;
                    }
                    while x < valid {
                        *accp.add(x) +=
                            w0 as i32 * *p0.add(x) as i32 + w1 as i32 * *p1.add(x) as i32;
                        x += 1;
                    }
                }
                kk += 2;
            }
            for ti in 0..t {
                let s = w.scales[row + ti] * a.scale;
                let off = (row + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                for (x, &av) in acc[ti][..valid].iter().enumerate() {
                    *c.add(off + x) = av as f32 * s;
                }
            }
            row += t;
        }
    }
}

/// AVX2 strip body behind `Avx2Kernel::spmm_strip`.
///
/// # Safety
/// Same contract as `Kernel::spmm_strip`, plus: the host CPU must
/// support avx2+fma (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn spmm_strip_avx2(
    w: &ColwisePruned,
    a: &PackedMatrix,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::x86_64::*;
    let (sdata, valid, col0) = strip_geometry(a, strip);
    let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
    // SAFETY: one region for the whole strip body. Intrinsics are
    // runnable (avx2+fma per the fn contract); unaligned loads/stores
    // stay inside `arow`/`acc[ti]` because x+8 <= valid and
    // valid <= MAX_STRIP_WIDTH (asserted in strip_geometry); the final
    // copy targets c[off..off+valid] with off+valid <= c_len asserted,
    // and the contract gives exclusive access to those columns.
    unsafe {
        for tile in &w.tiles {
            let t = tile.row_count;
            let nret = tile.indices.len();
            for row in &mut acc[..t] {
                row[..valid].fill(0.0);
            }
            for (j, &idx) in tile.indices.iter().enumerate() {
                let arow = &sdata[idx as usize * a.v..idx as usize * a.v + valid];
                let ap = arow.as_ptr();
                for ti in 0..t {
                    let ws = tile.values[ti * nret + j];
                    let wv = _mm256_set1_ps(ws);
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 8 <= valid {
                        let av = _mm256_loadu_ps(ap.add(x));
                        let cv = _mm256_loadu_ps(accp.add(x));
                        _mm256_storeu_ps(accp.add(x), _mm256_fmadd_ps(wv, av, cv));
                        x += 8;
                    }
                    while x < valid {
                        *accp.add(x) += ws * *ap.add(x);
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let off = (tile.row_start + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid);
            }
        }
    }
}

/// AVX2 dense body behind `Avx2Kernel::dense_strip`.
///
/// # Safety
/// Same contract as `Kernel::dense_strip`, plus: the host CPU must
/// support avx2+fma (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn dense_strip_avx2(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::x86_64::*;
    let (sdata, valid, col0) = strip_geometry(a, strip);
    let k = a.k;
    let mut row = 0;
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_avx2 (feature-gated intrinsics, x+8 <= valid lane
    // bounds, asserted off+valid <= c_len output range).
    unsafe {
        while row < rows {
            let t = tile.min(rows - row);
            let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
            for kk in 0..k {
                let arow = &sdata[kk * a.v..kk * a.v + valid];
                let ap = arow.as_ptr();
                for ti in 0..t {
                    let ws = w[(row + ti) * k + kk];
                    let wv = _mm256_set1_ps(ws);
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 8 <= valid {
                        let av = _mm256_loadu_ps(ap.add(x));
                        let cv = _mm256_loadu_ps(accp.add(x));
                        _mm256_storeu_ps(accp.add(x), _mm256_fmadd_ps(wv, av, cv));
                        x += 8;
                    }
                    while x < valid {
                        *accp.add(x) += ws * *ap.add(x);
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let off = (row + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid);
            }
            row += t;
        }
    }
}

// --------------------------------------------------------- x86_64 AVX-512

/// AVX-512F backend: 16-lane fused multiply-add with a scalar tail.
/// Compiled only when the building rustc stabilises the `_mm512_*`
/// intrinsics (rustc ≥ 1.89; probed by `rust/build.rs`).
#[cfg(all(target_arch = "x86_64", nmprune_avx512))]
pub struct Avx512Kernel;

#[cfg(all(target_arch = "x86_64", nmprune_avx512))]
impl Kernel for Avx512Kernel {
    fn id(&self) -> KernelId {
        KernelId::Avx512
    }

    fn available(&self) -> bool {
        is_x86_feature_detected!("avx512f")
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip` contract.
    unsafe fn spmm_strip(
        &self,
        w: &ColwisePruned,
        a: &PackedMatrix,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so avx512f is present on this CPU.
        unsafe { spmm_strip_avx512(w, a, strip, c, c_len) }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip` contract.
    unsafe fn dense_strip(
        &self,
        w: &[f32],
        rows: usize,
        a: &PackedMatrix,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so avx512f is present on this CPU.
        unsafe { dense_strip_avx512(w, rows, a, tile, strip, c, c_len) }
    }

    fn i8_native(&self) -> bool {
        true
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip_i8` contract.
    unsafe fn spmm_strip_i8(
        &self,
        w: &ColwiseQuant,
        a: &QuantPanel,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // The i8 plane reuses the AVX2 madd bodies: without VNNI there
        // is no profitable 512-bit widening scheme, and bitwise parity
        // across backends matters more than lane count here.
        // SAFETY: same contract forwarded; every avx512f CPU also
        // reports avx2, so the avx2 target-feature body is runnable.
        unsafe { spmm_strip_i8_avx2(w, a, strip, c, c_len) }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip_i8` contract.
    unsafe fn dense_strip_i8(
        &self,
        w: &QuantDense,
        a: &QuantPanel,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; every avx512f CPU also
        // reports avx2, so the avx2 target-feature body is runnable.
        unsafe { dense_strip_i8_avx2(w, a, tile, strip, c, c_len) }
    }
}

/// AVX-512 strip body behind `Avx512Kernel::spmm_strip`.
///
/// # Safety
/// Same contract as `Kernel::spmm_strip`, plus: the host CPU must
/// support avx512f (guaranteed by `available()`-gated dispatch).
#[cfg(all(target_arch = "x86_64", nmprune_avx512))]
#[target_feature(enable = "avx512f")]
unsafe fn spmm_strip_avx512(
    w: &ColwisePruned,
    a: &PackedMatrix,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::x86_64::*;
    let (sdata, valid, col0) = strip_geometry(a, strip);
    let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_avx2 with 16-lane bounds (x+16 <= valid, asserted
    // off+valid <= c_len output range, feature-gated intrinsics).
    unsafe {
        for tile in &w.tiles {
            let t = tile.row_count;
            let nret = tile.indices.len();
            for row in &mut acc[..t] {
                row[..valid].fill(0.0);
            }
            for (j, &idx) in tile.indices.iter().enumerate() {
                let arow = &sdata[idx as usize * a.v..idx as usize * a.v + valid];
                let ap = arow.as_ptr();
                for ti in 0..t {
                    let ws = tile.values[ti * nret + j];
                    let wv = _mm512_set1_ps(ws);
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 16 <= valid {
                        let av = _mm512_loadu_ps(ap.add(x));
                        let cv = _mm512_loadu_ps(accp.add(x));
                        _mm512_storeu_ps(accp.add(x), _mm512_fmadd_ps(wv, av, cv));
                        x += 16;
                    }
                    while x < valid {
                        *accp.add(x) += ws * *ap.add(x);
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let off = (tile.row_start + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid);
            }
        }
    }
}

/// AVX-512 dense body behind `Avx512Kernel::dense_strip`.
///
/// # Safety
/// Same contract as `Kernel::dense_strip`, plus: the host CPU must
/// support avx512f (guaranteed by `available()`-gated dispatch).
#[cfg(all(target_arch = "x86_64", nmprune_avx512))]
#[target_feature(enable = "avx512f")]
#[allow(clippy::too_many_arguments)]
unsafe fn dense_strip_avx512(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::x86_64::*;
    let (sdata, valid, col0) = strip_geometry(a, strip);
    let k = a.k;
    let mut row = 0;
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_avx2 with 16-lane bounds (x+16 <= valid, asserted
    // off+valid <= c_len output range, feature-gated intrinsics).
    unsafe {
        while row < rows {
            let t = tile.min(rows - row);
            let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
            for kk in 0..k {
                let arow = &sdata[kk * a.v..kk * a.v + valid];
                let ap = arow.as_ptr();
                for ti in 0..t {
                    let ws = w[(row + ti) * k + kk];
                    let wv = _mm512_set1_ps(ws);
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 16 <= valid {
                        let av = _mm512_loadu_ps(ap.add(x));
                        let cv = _mm512_loadu_ps(accp.add(x));
                        _mm512_storeu_ps(accp.add(x), _mm512_fmadd_ps(wv, av, cv));
                        x += 16;
                    }
                    while x < valid {
                        *accp.add(x) += ws * *ap.add(x);
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let off = (row + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid);
            }
            row += t;
        }
    }
}

// ------------------------------------------------------------ aarch64 NEON

/// NEON backend: 4-lane fused multiply-add with a scalar tail.
#[cfg(target_arch = "aarch64")]
pub struct NeonKernel;

#[cfg(target_arch = "aarch64")]
impl Kernel for NeonKernel {
    fn id(&self) -> KernelId {
        KernelId::Neon
    }

    fn available(&self) -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip` contract.
    unsafe fn spmm_strip(
        &self,
        w: &ColwisePruned,
        a: &PackedMatrix,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so neon is present on this CPU.
        unsafe { spmm_strip_neon(w, a, strip, c, c_len) }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip` contract.
    unsafe fn dense_strip(
        &self,
        w: &[f32],
        rows: usize,
        a: &PackedMatrix,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so neon is present on this CPU.
        unsafe { dense_strip_neon(w, rows, a, tile, strip, c, c_len) }
    }

    fn i8_native(&self) -> bool {
        true
    }

    // SAFETY: caller upholds the `Kernel::spmm_strip_i8` contract.
    unsafe fn spmm_strip_i8(
        &self,
        w: &ColwiseQuant,
        a: &QuantPanel,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so neon is present on this CPU.
        unsafe { spmm_strip_i8_neon(w, a, strip, c, c_len) }
    }

    // SAFETY: caller upholds the `Kernel::dense_strip_i8` contract.
    unsafe fn dense_strip_i8(
        &self,
        w: &QuantDense,
        a: &QuantPanel,
        tile: usize,
        strip: usize,
        c: *mut f32,
        c_len: usize,
    ) {
        // SAFETY: same contract forwarded; dispatch is gated on
        // `available()`, so neon is present on this CPU.
        unsafe { dense_strip_i8_neon(w, a, tile, strip, c, c_len) }
    }
}

/// NEON quantized spMM strip body: 8 i8 lanes widened to i16
/// (`vmovl_s8`), then widening multiply-accumulate into two i32x4
/// accumulators (`vmlal_n_s16`). Every step is exact integer
/// arithmetic, so the result is bitwise identical to the scalar body.
///
/// # Safety
/// Same contract as `Kernel::spmm_strip_i8`, plus: the host CPU must
/// support neon (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn spmm_strip_i8_neon(
    w: &ColwiseQuant,
    a: &QuantPanel,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::aarch64::*;
    let (sdata, valid, col0) = quant_strip_geometry(a, strip);
    let mut acc = [[0i32; MAX_STRIP_WIDTH]; MAX_TILE];
    // SAFETY: one region for the whole strip body. Intrinsics are
    // runnable (neon per the fn contract); the 8-byte loads stay inside
    // the strip row because x+8 <= valid <= a.v and the row holds a.v
    // bytes; accumulator loads/stores stay inside acc[ti] because
    // x+8 <= valid <= MAX_STRIP_WIDTH; the epilogue writes
    // c[off..off+valid] with off+valid <= c_len asserted, and the
    // contract gives exclusive access to those columns.
    unsafe {
        for tile in &w.tiles {
            let t = tile.row_count;
            let nret = tile.indices.len();
            for row in &mut acc[..t] {
                row[..valid].fill(0);
            }
            for (j, &idx) in tile.indices.iter().enumerate() {
                let p0 = sdata.as_ptr().add(idx as usize * a.v);
                for ti in 0..t {
                    let wq = tile.values[ti * nret + j] as i16;
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 8 <= valid {
                        let a16 = vmovl_s8(vld1_s8(p0.add(x)));
                        let lo = vmlal_n_s16(vld1q_s32(accp.add(x)), vget_low_s16(a16), wq);
                        let hi =
                            vmlal_n_s16(vld1q_s32(accp.add(x + 4)), vget_high_s16(a16), wq);
                        vst1q_s32(accp.add(x), lo);
                        vst1q_s32(accp.add(x + 4), hi);
                        x += 8;
                    }
                    while x < valid {
                        *accp.add(x) += wq as i32 * *p0.add(x) as i32;
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let r = tile.row_start + ti;
                let s = w.scales[r] * a.scale;
                let off = r * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                // Scalar requantize epilogue — identical expression in
                // every backend (bitwise cross-kernel contract).
                for (x, &av) in acc[ti][..valid].iter().enumerate() {
                    *c.add(off + x) = av as f32 * s;
                }
            }
        }
    }
}

/// NEON quantized dense strip body; same scheme and exactness argument
/// as [`spmm_strip_i8_neon`].
///
/// # Safety
/// Same contract as `Kernel::dense_strip_i8`, plus: the host CPU must
/// support neon (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dense_strip_i8_neon(
    w: &QuantDense,
    a: &QuantPanel,
    tile: usize,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::aarch64::*;
    let (sdata, valid, col0) = quant_strip_geometry(a, strip);
    let k = a.k;
    let rows = w.rows;
    let mut row = 0;
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_i8_neon (feature-gated intrinsics, x+8 <= valid lane
    // bounds, asserted off+valid <= c_len output range).
    unsafe {
        while row < rows {
            let t = tile.min(rows - row);
            let mut acc = [[0i32; MAX_STRIP_WIDTH]; MAX_TILE];
            for kk in 0..k {
                let p0 = sdata.as_ptr().add(kk * a.v);
                for ti in 0..t {
                    let wq = w.values[(row + ti) * k + kk] as i16;
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 8 <= valid {
                        let a16 = vmovl_s8(vld1_s8(p0.add(x)));
                        let lo = vmlal_n_s16(vld1q_s32(accp.add(x)), vget_low_s16(a16), wq);
                        let hi =
                            vmlal_n_s16(vld1q_s32(accp.add(x + 4)), vget_high_s16(a16), wq);
                        vst1q_s32(accp.add(x), lo);
                        vst1q_s32(accp.add(x + 4), hi);
                        x += 8;
                    }
                    while x < valid {
                        *accp.add(x) += wq as i32 * *p0.add(x) as i32;
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let s = w.scales[row + ti] * a.scale;
                let off = (row + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                for (x, &av) in acc[ti][..valid].iter().enumerate() {
                    *c.add(off + x) = av as f32 * s;
                }
            }
            row += t;
        }
    }
}

/// NEON strip body behind `NeonKernel::spmm_strip`.
///
/// # Safety
/// Same contract as `Kernel::spmm_strip`, plus: the host CPU must
/// support neon (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn spmm_strip_neon(
    w: &ColwisePruned,
    a: &PackedMatrix,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::aarch64::*;
    let (sdata, valid, col0) = strip_geometry(a, strip);
    let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_avx2 with 4-lane bounds (x+4 <= valid, asserted
    // off+valid <= c_len output range, feature-gated intrinsics).
    unsafe {
        for tile in &w.tiles {
            let t = tile.row_count;
            let nret = tile.indices.len();
            for row in &mut acc[..t] {
                row[..valid].fill(0.0);
            }
            for (j, &idx) in tile.indices.iter().enumerate() {
                let arow = &sdata[idx as usize * a.v..idx as usize * a.v + valid];
                let ap = arow.as_ptr();
                for ti in 0..t {
                    let ws = tile.values[ti * nret + j];
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 4 <= valid {
                        let av = vld1q_f32(ap.add(x));
                        let cv = vld1q_f32(accp.add(x));
                        vst1q_f32(accp.add(x), vfmaq_n_f32(cv, av, ws));
                        x += 4;
                    }
                    while x < valid {
                        *accp.add(x) += ws * *ap.add(x);
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let off = (tile.row_start + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid);
            }
        }
    }
}

/// NEON dense body behind `NeonKernel::dense_strip`.
///
/// # Safety
/// Same contract as `Kernel::dense_strip`, plus: the host CPU must
/// support neon (guaranteed by `available()`-gated dispatch).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn dense_strip_neon(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    strip: usize,
    c: *mut f32,
    c_len: usize,
) {
    use std::arch::aarch64::*;
    let (sdata, valid, col0) = strip_geometry(a, strip);
    let k = a.k;
    let mut row = 0;
    // SAFETY: one region for the whole strip body; same argument as
    // spmm_strip_avx2 with 4-lane bounds (x+4 <= valid, asserted
    // off+valid <= c_len output range, feature-gated intrinsics).
    unsafe {
        while row < rows {
            let t = tile.min(rows - row);
            let mut acc = [[0.0f32; MAX_STRIP_WIDTH]; MAX_TILE];
            for kk in 0..k {
                let arow = &sdata[kk * a.v..kk * a.v + valid];
                let ap = arow.as_ptr();
                for ti in 0..t {
                    let ws = w[(row + ti) * k + kk];
                    let accp = acc[ti].as_mut_ptr();
                    let mut x = 0;
                    while x + 4 <= valid {
                        let av = vld1q_f32(ap.add(x));
                        let cv = vld1q_f32(accp.add(x));
                        vst1q_f32(accp.add(x), vfmaq_n_f32(cv, av, ws));
                        x += 4;
                    }
                    while x < valid {
                        *accp.add(x) += ws * *ap.add(x);
                        x += 1;
                    }
                }
            }
            for ti in 0..t {
                let off = (row + ti) * a.cols + col0;
                assert!(off + valid <= c_len, "output out of bounds");
                std::ptr::copy_nonoverlapping(acc[ti].as_ptr(), c.add(off), valid);
            }
            row += t;
        }
    }
}

// ------------------------------------------------------ registry/dispatch

static SCALAR: ScalarKernel = ScalarKernel;
#[cfg(target_arch = "x86_64")]
static AVX2: Avx2Kernel = Avx2Kernel;
#[cfg(all(target_arch = "x86_64", nmprune_avx512))]
static AVX512: Avx512Kernel = Avx512Kernel;
#[cfg(target_arch = "aarch64")]
static NEON: NeonKernel = NeonKernel;

/// Every backend compiled into this binary (availability still depends
/// on the host CPU — see [`Kernel::available`]). The scalar oracle is
/// always first.
pub fn registry() -> &'static [&'static dyn Kernel] {
    // A static table (not a function-local borrow): references to
    // statics are not promotable inside a function body, but a static
    // initializer may point at other statics freely.
    static REGISTRY: &[&dyn Kernel] = &[
        &SCALAR,
        #[cfg(target_arch = "x86_64")]
        &AVX2,
        #[cfg(all(target_arch = "x86_64", nmprune_avx512))]
        &AVX512,
        #[cfg(target_arch = "aarch64")]
        &NEON,
    ];
    REGISTRY
}

/// Look a compiled-in backend up by id (`Auto` has no backend).
pub fn by_id(id: KernelId) -> Option<&'static dyn Kernel> {
    registry().iter().copied().find(|k| k.id() == id)
}

/// Ids of every backend that is both compiled in and available on this
/// host, scalar first.
pub fn available_ids() -> Vec<KernelId> {
    registry()
        .iter()
        .filter(|k| k.available())
        .map(|k| k.id())
        .collect()
}

/// The fastest available backend: AVX-512 > AVX2 > NEON > scalar.
pub fn best_available() -> KernelId {
    static BEST: OnceLock<KernelId> = OnceLock::new();
    *BEST.get_or_init(|| {
        for id in [KernelId::Avx512, KernelId::Avx2, KernelId::Neon] {
            if by_id(id).is_some_and(|k| k.available()) {
                return id;
            }
        }
        KernelId::Scalar
    })
}

fn known_names() -> String {
    ALL_KERNEL_IDS
        .iter()
        .map(|id| id.name())
        .collect::<Vec<_>>()
        .join(", ")
}

/// Parse an `NMPRUNE_KERNEL` value. `Ok(None)` means no forcing
/// (unset/empty/`auto`); `Err` carries the loud-failure message for an
/// unknown or host-unavailable kernel.
fn parse_forced(raw: &str) -> Result<Option<KernelId>, String> {
    let name = raw.trim().to_ascii_lowercase();
    if name.is_empty() || name == "auto" {
        return Ok(None);
    }
    let id = KernelId::from_name(&name).ok_or_else(|| {
        format!("NMPRUNE_KERNEL={raw}: unknown kernel (known: {})", known_names())
    })?;
    if by_id(id).is_some_and(|k| k.available()) {
        Ok(Some(id))
    } else {
        let avail = available_ids()
            .iter()
            .map(|id| id.name())
            .collect::<Vec<_>>()
            .join(", ");
        Err(format!(
            "NMPRUNE_KERNEL={raw}: kernel not available on this host (available: {avail})"
        ))
    }
}

/// The process-wide forced kernel from `NMPRUNE_KERNEL`, memoised.
/// Panics (once, loudly) if the variable names an unknown or
/// unavailable kernel — forcing must never silently fall back.
pub fn forced() -> Option<KernelId> {
    static FORCED: OnceLock<Option<KernelId>> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var("NMPRUNE_KERNEL") {
        Ok(v) => parse_forced(&v).unwrap_or_else(|e| panic!("{e}")),
        Err(_) => None,
    })
}

/// Resolve an advisory kernel choice to a runnable backend.
///
/// `NMPRUNE_KERNEL` (if set) wins unconditionally. Otherwise `Auto`
/// resolves to [`best_available`], and a concrete choice that is not
/// available on this host (e.g. an artifact tuned elsewhere) gracefully
/// falls back to [`best_available`].
pub fn resolve(requested: KernelId) -> &'static dyn Kernel {
    let id = match forced() {
        Some(f) => f,
        None => match requested {
            KernelId::Auto => best_available(),
            id if by_id(id).is_some_and(|k| k.available()) => id,
            _ => best_available(),
        },
    };
    by_id(id).expect("resolved kernel is always registered")
}

// ------------------------------------------------------------ parity bound

/// Max ULP distance allowed between a native kernel and the scalar
/// oracle for one output element (covers reassociation noise away from
/// cancellation).
pub const PARITY_ULPS: u32 = 256;

/// Fallback absolute-tolerance factor: where accumulation nearly
/// cancels, ULPs of a tiny result overstate the error, so outputs also
/// pass when `|native − scalar| ≤ PARITY_EPS_FACTOR · ε · mag` with
/// `mag = Σ|wᵢ·xᵢ|` accumulated for that element.
pub const PARITY_EPS_FACTOR: f32 = 32.0;

/// Distance in units-in-the-last-place between two f32s (0 for exact
/// equality incl. `-0.0 == 0.0`; `u32::MAX` if either is non-finite).
pub fn ulp_distance(a: f32, b: f32) -> u32 {
    if a == b {
        return 0;
    }
    if !a.is_finite() || !b.is_finite() {
        return u32::MAX;
    }
    fn monotone(x: f32) -> i64 {
        let u = x.to_bits();
        if u & 0x8000_0000 != 0 {
            -((u & 0x7fff_ffff) as i64)
        } else {
            u as i64
        }
    }
    (monotone(a) - monotone(b)).unsigned_abs().min(u64::from(u32::MAX)) as u32
}

/// The documented scalar-vs-native parity gate (see
/// docs/ARCHITECTURE.md "Kernel dispatch"): within [`PARITY_ULPS`]
/// ULPs, or within the magnitude-scaled absolute bound for
/// near-cancelling accumulations.
pub fn within_parity_bound(native: f32, scalar: f32, mag: f32) -> bool {
    ulp_distance(native, scalar) <= PARITY_ULPS
        || (native - scalar).abs() <= PARITY_EPS_FACTOR * f32::EPSILON * mag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{gemm_dense, matmul_ref, spmm_colwise};
    use crate::im2col::pack_data_matrix;
    use crate::pruning::prune_colwise;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn id_name_and_code_round_trip() {
        for id in ALL_KERNEL_IDS {
            assert_eq!(KernelId::from_name(id.name()), Some(id));
            assert_eq!(KernelId::from_code(id.code()), Some(id));
            assert_eq!(format!("{id}"), id.name());
        }
        assert_eq!(KernelId::from_name("vmx"), None);
        assert_eq!(KernelId::from_code(99), None);
        assert_eq!(KernelId::default(), KernelId::Auto);
    }

    #[test]
    fn scalar_is_always_registered_and_available() {
        let k = by_id(KernelId::Scalar).expect("scalar registered");
        assert!(k.available());
        assert_eq!(registry()[0].id(), KernelId::Scalar);
        assert!(available_ids().contains(&KernelId::Scalar));
    }

    #[test]
    fn best_available_is_available_and_auto_is_never_registered() {
        let best = best_available();
        assert!(by_id(best).expect("best registered").available());
        assert!(by_id(KernelId::Auto).is_none());
    }

    #[test]
    fn resolve_auto_and_unavailable_fall_back() {
        // These run without NMPRUNE_KERNEL in the normal test env; when
        // CI forces a kernel, forcing wins by design, so only check the
        // resolved kernel is available either way.
        assert!(resolve(KernelId::Auto).available());
        // Neon is never available on x86_64 and vice versa — an
        // advisory choice from another host must fall back, not panic.
        let foreign = if cfg!(target_arch = "x86_64") {
            KernelId::Neon
        } else {
            KernelId::Avx2
        };
        assert!(resolve(foreign).available());
    }

    #[test]
    fn parse_forced_accepts_auto_and_rejects_junk() {
        assert_eq!(parse_forced("").unwrap(), None);
        assert_eq!(parse_forced("auto").unwrap(), None);
        assert_eq!(parse_forced(" AUTO ").unwrap(), None);
        assert_eq!(parse_forced("scalar").unwrap(), Some(KernelId::Scalar));
        assert!(parse_forced("vmx").is_err());
        let foreign = if cfg!(target_arch = "x86_64") { "neon" } else { "avx2" };
        assert!(parse_forced(foreign).is_err(), "foreign-arch forcing must be loud");
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(1.0, f32::NAN), u32::MAX);
        // Symmetric, and crossing zero counts both sides.
        let a = f32::from_bits(3);
        assert_eq!(ulp_distance(a, -a), 6);
        assert_eq!(ulp_distance(-a, a), 6);
        assert!(within_parity_bound(1.0, 1.0, 1.0));
        assert!(!within_parity_bound(1.0, 2.0, 1.0));
    }

    /// Every compiled-in, host-available backend must agree with the
    /// scalar oracle on both kernels (loose tolerance here; the strict
    /// ULP gate lives in rust/tests/conv_fuzz.rs).
    #[test]
    fn every_available_backend_matches_scalar_oracle() {
        let mut r = XorShiftRng::new(0x517);
        let (rows, k, cols) = (19, 32, 77);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        for v in [8, 16, 64] {
            let p = pack_data_matrix(&a, k, cols, v);
            let want_s = matmul_ref(&cp.decompress(), &a, rows, k, cols);
            let want_d = matmul_ref(&w, &a, rows, k, cols);
            for kern in registry() {
                if !kern.available() {
                    continue;
                }
                let mut got_s = vec![0.0f32; rows * cols];
                let mut got_d = vec![0.0f32; rows * cols];
                for strip in 0..p.strips {
                    // SAFETY: unique buffers sized rows*cols, serial.
                    unsafe {
                        kern.spmm_strip(&cp, &p, strip, got_s.as_mut_ptr(), got_s.len());
                        kern.dense_strip(&w, rows, &p, 7, strip, got_d.as_mut_ptr(), got_d.len());
                    }
                }
                let name = kern.id().name();
                assert!(allclose(&got_s, &want_s, 1e-4, 1e-5), "spmm {name} v={v}");
                assert!(allclose(&got_d, &want_d, 1e-4, 1e-5), "dense {name} v={v}");
            }
        }
    }

    /// Serial entry points and a fixed backend agree bitwise — the
    /// per-kernel bitwise invariant at its smallest.
    #[test]
    fn scalar_backend_is_bitwise_the_reference_entry_points() {
        let mut r = XorShiftRng::new(0x518);
        let (rows, k, cols) = (12, 16, 40);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 4, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 16);
        let via_entry_s = super::super::colwise::spmm_colwise_with(&cp, &p, KernelId::Scalar);
        let via_entry_d = super::super::dense::gemm_dense_with(&w, rows, &p, 5, KernelId::Scalar);
        let kern = by_id(KernelId::Scalar).unwrap();
        let mut got_s = vec![0.0f32; rows * cols];
        let mut got_d = vec![0.0f32; rows * cols];
        for strip in 0..p.strips {
            // SAFETY: unique buffers sized rows*cols, serial.
            unsafe {
                kern.spmm_strip(&cp, &p, strip, got_s.as_mut_ptr(), got_s.len());
                kern.dense_strip(&w, rows, &p, 5, strip, got_d.as_mut_ptr(), got_d.len());
            }
        }
        assert_eq!(got_s, via_entry_s);
        assert_eq!(got_d, via_entry_d);
        // And the default (Auto) entry points match whatever they
        // resolve to exactly — dispatch adds no arithmetic.
        let auto_s = spmm_colwise(&cp, &p);
        let auto_d = gemm_dense(&w, rows, &p, 5);
        assert!(allclose(&auto_s, &got_s, 1e-4, 1e-5));
        assert!(allclose(&auto_d, &got_d, 1e-4, 1e-5));
    }

    // ------------------------------------------------------- i8 plane

    /// Bit-exact naive reference for the quantized spMM: integer dot
    /// product per output, then the identical requantize expression.
    fn naive_spmm_i8(w: &ColwiseQuant, a: &QuantPanel) -> Vec<f32> {
        let mut c = vec![0.0f32; w.rows * a.cols];
        for t in &w.tiles {
            let nret = t.indices.len();
            for ti in 0..t.row_count {
                let r = t.row_start + ti;
                let s = w.scales[r] * a.scale;
                for col in 0..a.cols {
                    let mut acc = 0i32;
                    for (j, &idx) in t.indices.iter().enumerate() {
                        acc += t.values[ti * nret + j] as i32
                            * a.at(col / a.v, idx as usize, col % a.v) as i32;
                    }
                    c[r * a.cols + col] = acc as f32 * s;
                }
            }
        }
        c
    }

    /// Bit-exact naive reference for the quantized dense GEMM.
    fn naive_dense_i8(w: &QuantDense, a: &QuantPanel) -> Vec<f32> {
        let mut c = vec![0.0f32; w.rows * a.cols];
        for r in 0..w.rows {
            let s = w.scales[r] * a.scale;
            for col in 0..a.cols {
                let mut acc = 0i32;
                for kk in 0..w.k {
                    acc += w.values[r * w.k + kk] as i32
                        * a.at(col / a.v, kk, col % a.v) as i32;
                }
                c[r * a.cols + col] = acc as f32 * s;
            }
        }
        c
    }

    fn assert_i8_backends_bitwise(w: &[f32], a: &[f32], rows: usize, k: usize, cols: usize) {
        use crate::im2col::{quantize_panel_into, QuantPanel};
        let cp = prune_colwise(w, rows, k, 8, 2, 4);
        let qw = ColwiseQuant::quantize(&cp);
        let qd = QuantDense::quantize(w, rows, k);
        for v in [8, 16, 64] {
            let p = pack_data_matrix(a, k, cols, v);
            let mut qa = QuantPanel::zeros(1, 1, 1);
            quantize_panel_into(&p, &mut qa);
            let want_s = naive_spmm_i8(&qw, &qa);
            let want_d = naive_dense_i8(&qd, &qa);
            for kern in registry() {
                if !kern.available() {
                    continue;
                }
                let mut got_s = vec![0.0f32; rows * cols];
                let mut got_d = vec![0.0f32; rows * cols];
                for strip in 0..qa.strips {
                    // SAFETY: unique buffers sized rows*cols, serial.
                    unsafe {
                        kern.spmm_strip_i8(&qw, &qa, strip, got_s.as_mut_ptr(), got_s.len());
                        kern.dense_strip_i8(&qd, &qa, 7, strip, got_d.as_mut_ptr(), got_d.len());
                    }
                }
                let name = kern.id().name();
                assert_eq!(got_s, want_s, "spmm i8 {name} v={v}");
                assert_eq!(got_d, want_d, "dense i8 {name} v={v}");
            }
        }
    }

    /// Every backend's i8 path — native or inherited scalar — must be
    /// *bitwise* equal to the naive integer reference (a stronger
    /// contract than the f32 ULP gate: integer accumulation admits no
    /// reassociation noise). cols = 77 exercises the partial tail strip
    /// and odd retained-column pairing in the AVX2 madd scheme.
    #[test]
    fn i8_backends_are_bitwise_identical_to_naive_reference() {
        let mut r = XorShiftRng::new(0x519);
        let (rows, k, cols) = (19, 32, 77);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        assert_i8_backends_bitwise(&w, &a, rows, k, cols);
    }

    /// Saturation fixture: every operand at the ±127 rails. The madd
    /// pair-sum then sits at its extreme |2·127²| = 32258 < i16::MAX —
    /// the overflow case (−128·−128·2) is unreachable because
    /// quantization clamps both sides to ±127.
    #[test]
    fn i8_rail_values_do_not_overflow_the_pairwise_madd() {
        let (rows, k, cols) = (8, 64, 24);
        // Alternating-sign extremes quantize to exactly ±127.
        let w: Vec<f32> = (0..rows * k).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let a: Vec<f32> = (0..k * cols).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
        assert_i8_backends_bitwise(&w, &a, rows, k, cols);
    }

    /// All-zero weights and activations: zero scales, zero outputs, no
    /// NaNs from the 0·0 requant.
    #[test]
    fn i8_all_zero_inputs_yield_exact_zero() {
        let (rows, k, cols) = (6, 16, 20);
        let w = vec![0.0f32; rows * k];
        let a = vec![0.0f32; k * cols];
        assert_i8_backends_bitwise(&w, &a, rows, k, cols);
        let qd = QuantDense::quantize(&w, rows, k);
        let p = pack_data_matrix(&a, k, cols, 8);
        let mut qa = crate::im2col::QuantPanel::zeros(1, 1, 1);
        crate::im2col::quantize_panel_into(&p, &mut qa);
        assert!(naive_dense_i8(&qd, &qa).iter().all(|&x| x == 0.0 && !x.is_nan()));
    }

    /// The scalar oracle never claims a native i8 path; every SIMD
    /// backend compiled in does (it overrides the shared scalar body).
    #[test]
    fn i8_native_flags_match_backend_kind() {
        for kern in registry() {
            let native = kern.i8_native();
            match kern.id() {
                KernelId::Scalar => assert!(!native, "scalar is the shared body"),
                _ => assert!(native, "{} should be i8-native", kern.id().name()),
            }
        }
    }
}
