//! Inner-product row-based N:M SpMM baseline (§3.1).
//!
//! Iterates output rows; each row gathers its own retained data-matrix
//! rows via its index array. Adjacent output rows retain *different*
//! column sets, so the same data row is fetched again and again — the
//! redundant-load behaviour the paper measures against. Numerically
//! correct; the cost shows up in the RVV simulator's L1 counters and in
//! wall-clock on real caches.

use crate::im2col::{PackedMatrix, MAX_STRIP_WIDTH};
use crate::pruning::RowNmPruned;

/// `C[rows, cols] = Wr · A`, Wr row-based N:M compressed, A packed.
/// Inner-product order: per output row, accumulate over its indices.
pub fn spmm_inner_rownm(w: &RowNmPruned, a: &PackedMatrix) -> Vec<f32> {
    assert_eq!(w.cols, a.k, "reduction dim mismatch");
    assert!(
        a.v <= MAX_STRIP_WIDTH,
        "strip width {} exceeds accumulator capacity {MAX_STRIP_WIDTH}",
        a.v
    );
    let mut c = vec![0.0f32; w.rows * a.cols];
    for strip in 0..a.strips {
        let sdata = a.strip(strip);
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        for r in 0..w.rows {
            let mut acc = [0.0f32; MAX_STRIP_WIDTH];
            for j in 0..w.per_row {
                let idx = w.indices[r * w.per_row + j] as usize;
                let wv = w.values[r * w.per_row + j];
                // Data row fetched per output row — no cross-row reuse.
                let arow = &sdata[idx * a.v..idx * a.v + valid];
                let accr = &mut acc[..valid];
                for (aj, xj) in accr.iter_mut().zip(arow) {
                    *aj += wv * xj;
                }
            }
            c[r * a.cols + col0..r * a.cols + col0 + valid]
                .copy_from_slice(&acc[..valid]);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_ref;
    use crate::im2col::pack_data_matrix;
    use crate::pruning::prune_rownm;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn matches_reference() {
        let mut r = XorShiftRng::new(81);
        let (rows, k, cols) = (12, 24, 37);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        for (n, m) in [(1, 4), (2, 4), (3, 4), (2, 8)] {
            let rp = prune_rownm(&w, rows, k, n, m);
            let want = matmul_ref(&rp.decompress(), &a, rows, k, cols);
            for v in [8, 16] {
                let p = pack_data_matrix(&a, k, cols, v);
                let got = spmm_inner_rownm(&rp, &p);
                assert!(allclose(&got, &want, 1e-4, 1e-5), "{n}:{m} v={v}");
            }
        }
    }
}
