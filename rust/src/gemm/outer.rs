//! Outer-product row-based N:M SpMM baseline — the "conventional N:M"
//! configuration of Fig. 5 (§3.1).
//!
//! Iterates the *columns* of the weight matrix so each fetched data row
//! is reused across every output row that retains that column — fixing
//! the inner-product kernel's redundant loads. But because row-based N:M
//! retains irregular per-row column sets, the partial products scatter
//! across output rows: accumulators cannot stay in registers, so partial
//! sums are read-modify-written to the output buffer for every (column,
//! row) hit — the redundant-store pathology that makes this kernel
//! *slower than dense* in the paper (up to 5.4×).

use crate::im2col::PackedMatrix;
use crate::pruning::RowNmPruned;

/// Column-major view of a row-based N:M matrix: for each reduction index
/// k, the (row, value) pairs that retain column k.
#[derive(Clone, Debug)]
pub struct ColumnView {
    /// offsets[k]..offsets[k+1] indexes into `hits`.
    pub offsets: Vec<u32>,
    /// (output row, weight value) pairs grouped by column.
    pub hits: Vec<(u32, f32)>,
}

impl ColumnView {
    /// Build from a row-compressed matrix (done once at weight-pack time,
    /// off the hot path).
    pub fn build(w: &RowNmPruned) -> Self {
        let mut counts = vec![0u32; w.cols + 1];
        for r in 0..w.rows {
            for j in 0..w.per_row {
                let v = w.values[r * w.per_row + j];
                if v != 0.0 {
                    counts[w.indices[r * w.per_row + j] as usize + 1] += 1;
                }
            }
        }
        let mut offsets = counts;
        for k in 0..offsets.len() - 1 {
            offsets[k + 1] += offsets[k];
        }
        let mut cursor = offsets.clone();
        let mut hits = vec![(0u32, 0.0f32); *offsets.last().unwrap() as usize];
        for r in 0..w.rows {
            for j in 0..w.per_row {
                let v = w.values[r * w.per_row + j];
                if v != 0.0 {
                    let k = w.indices[r * w.per_row + j] as usize;
                    hits[cursor[k] as usize] = (r as u32, v);
                    cursor[k] += 1;
                }
            }
        }
        Self { offsets, hits }
    }
}

/// `C[rows, cols] = Wr · A` in outer-product order over a prebuilt
/// [`ColumnView`].
pub fn spmm_outer_rownm_with_view(
    w: &RowNmPruned,
    view: &ColumnView,
    a: &PackedMatrix,
) -> Vec<f32> {
    assert_eq!(w.cols, a.k, "reduction dim mismatch");
    let mut c = vec![0.0f32; w.rows * a.cols];
    for strip in 0..a.strips {
        let sdata = a.strip(strip);
        let valid = a.strip_valid(strip);
        let col0 = strip * a.v;
        for k in 0..w.cols {
            let (lo, hi) = (view.offsets[k] as usize, view.offsets[k + 1] as usize);
            if lo == hi {
                continue;
            }
            // Data row loaded once per column...
            let arow = &sdata[k * a.v..k * a.v + valid];
            for &(r, wv) in &view.hits[lo..hi] {
                // ...but the partial sum goes straight to memory: a
                // read-modify-write of the scattered output row.
                let crow =
                    &mut c[r as usize * a.cols + col0..r as usize * a.cols + col0 + valid];
                for (cj, xj) in crow.iter_mut().zip(arow) {
                    *cj += wv * xj;
                }
            }
        }
    }
    c
}

/// Convenience wrapper building the column view on the fly.
pub fn spmm_outer_rownm(w: &RowNmPruned, a: &PackedMatrix) -> Vec<f32> {
    spmm_outer_rownm_with_view(w, &ColumnView::build(w), a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_ref;
    use crate::im2col::pack_data_matrix;
    use crate::pruning::prune_rownm;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn matches_reference() {
        let mut r = XorShiftRng::new(91);
        let (rows, k, cols) = (10, 20, 29);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        for (n, m) in [(1, 4), (2, 4), (3, 4)] {
            let rp = prune_rownm(&w, rows, k, n, m);
            let want = matmul_ref(&rp.decompress(), &a, rows, k, cols);
            let p = pack_data_matrix(&a, k, cols, 8);
            let got = spmm_outer_rownm(&rp, &p);
            assert!(allclose(&got, &want, 1e-4, 1e-5), "{n}:{m}");
        }
    }

    #[test]
    fn column_view_counts_match_nnz() {
        let mut r = XorShiftRng::new(92);
        let w = r.normal_vec(8 * 16, 1.0);
        let rp = prune_rownm(&w, 8, 16, 2, 4);
        let view = ColumnView::build(&rp);
        let nnz: usize = rp.values.iter().filter(|v| **v != 0.0).count();
        assert_eq!(view.hits.len(), nnz);
        // Every hit's (row, value) must exist in the compressed form.
        let dense = rp.decompress();
        for k in 0..16 {
            for &(row, val) in
                &view.hits[view.offsets[k] as usize..view.offsets[k + 1] as usize]
            {
                assert_eq!(dense[row as usize * 16 + k], val);
            }
        }
    }

    #[test]
    fn agrees_with_inner_product_kernel() {
        let mut r = XorShiftRng::new(93);
        let (rows, k, cols) = (16, 32, 41);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let rp = prune_rownm(&w, rows, k, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 16);
        let got_o = spmm_outer_rownm(&rp, &p);
        let got_i = crate::gemm::spmm_inner_rownm(&rp, &p);
        assert!(allclose(&got_o, &got_i, 1e-4, 1e-5));
    }
}
