//! Multi-threaded GEMM drivers: output tiles (strips) processed in
//! parallel, the default XNNPACK parallelisation the paper uses (§4.1.1).
//!
//! All parallelism runs on a caller-supplied persistent
//! [`ThreadPool`] — nothing here spawns threads, so the per-call cost
//! in a long-lived server is just the pool's chunk dispatch. A pool of
//! size 1 degenerates to the serial kernels with no synchronisation,
//! and the strip-wise arithmetic is identical either way, so results
//! are bit-for-bit equal across pool sizes.
//!
//! Every driver has a `_capped` variant taking a per-call
//! `max_workers`: the per-layer parallelism degree the tuner selects
//! (small layers often lose more to dispatch than they gain from the
//! whole pool). A cap of `None`, or one at least the pool size, is the
//! plain pool-wide dispatch; caps never change which strip computes
//! which output, so capped results stay bit-for-bit equal to serial.

use crate::im2col::{PackedMatrix, QuantPanel};
use crate::pruning::{ColwisePruned, ColwiseQuant, QuantDense};
use crate::util::threadpool::ThreadPool;

use super::dense::MAX_TILE;
use super::kernels::{self, KernelId};

/// Parallel column-wise SpMM: strips are distributed over the pool's
/// workers (plus the calling thread).
pub fn spmm_colwise_parallel(
    w: &ColwisePruned,
    a: &PackedMatrix,
    pool: &ThreadPool,
) -> Vec<f32> {
    spmm_colwise_parallel_capped(w, a, pool, None)
}

/// [`spmm_colwise_parallel`] bounded to at most `max_workers`
/// participants (the tuned per-layer parallelism degree).
pub fn spmm_colwise_parallel_capped(
    w: &ColwisePruned,
    a: &PackedMatrix,
    pool: &ThreadPool,
    max_workers: Option<usize>,
) -> Vec<f32> {
    let mut c = vec![0.0f32; w.rows * a.cols];
    spmm_colwise_parallel_capped_into(w, a, pool, max_workers, &mut c);
    c
}

/// [`spmm_colwise_parallel_capped`] writing into a caller-provided
/// output buffer (zero-alloc hot-path entry): every strip fully
/// overwrites its disjoint column range, so no pre-zeroing is needed.
// nmprune: zero-alloc
pub fn spmm_colwise_parallel_capped_into(
    w: &ColwisePruned,
    a: &PackedMatrix,
    pool: &ThreadPool,
    max_workers: Option<usize>,
    c: &mut [f32],
) {
    spmm_colwise_parallel_capped_into_with(w, a, pool, max_workers, KernelId::Auto, c)
}

/// [`spmm_colwise_parallel_capped_into`] on an explicit micro-kernel
/// backend. The backend is resolved once, before the fan-out, so every
/// strip of one call runs identical arithmetic — the per-kernel bitwise
/// invariant across pool sizes and caps.
// nmprune: zero-alloc
pub fn spmm_colwise_parallel_capped_into_with(
    w: &ColwisePruned,
    a: &PackedMatrix,
    pool: &ThreadPool,
    max_workers: Option<usize>,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.cols, a.k);
    assert!(c.len() >= w.rows * a.cols, "output buffer too small");
    let kern = kernels::resolve(kernel);
    // Each strip writes a disjoint column range of C. Workers write
    // through a shared raw pointer — never through a `&mut [f32]` over
    // the whole buffer, which would create overlapping exclusive
    // references across threads (UB even with disjoint writes).
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    pool.parallel_for_capped(a.strips, max_workers, |s0, s1| {
        for strip in s0..s1 {
            // SAFETY: strip output ranges are disjoint by construction,
            // and `c` outlives the parallel_for barrier.
            unsafe { kern.spmm_strip(w, a, strip, c_ptr.get(), c_len) };
        }
    });
}

/// Parallel dense GEMM over strips.
pub fn gemm_dense_parallel(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    pool: &ThreadPool,
) -> Vec<f32> {
    gemm_dense_parallel_capped(w, rows, a, tile, pool, None)
}

/// [`gemm_dense_parallel`] bounded to at most `max_workers` participants.
pub fn gemm_dense_parallel_capped(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    pool: &ThreadPool,
    max_workers: Option<usize>,
) -> Vec<f32> {
    let mut c = vec![0.0f32; rows * a.cols];
    gemm_dense_parallel_capped_into(w, rows, a, tile, pool, max_workers, &mut c);
    c
}

/// [`gemm_dense_parallel_capped`] writing into a caller-provided output
/// buffer (zero-alloc hot-path entry).
// nmprune: zero-alloc
pub fn gemm_dense_parallel_capped_into(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    pool: &ThreadPool,
    max_workers: Option<usize>,
    c: &mut [f32],
) {
    gemm_dense_parallel_capped_into_with(w, rows, a, tile, pool, max_workers, KernelId::Auto, c)
}

/// [`gemm_dense_parallel_capped_into`] on an explicit micro-kernel
/// backend (resolved once before the fan-out — see
/// [`spmm_colwise_parallel_capped_into_with`]).
// nmprune: zero-alloc
#[allow(clippy::too_many_arguments)]
pub fn gemm_dense_parallel_capped_into_with(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    pool: &ThreadPool,
    max_workers: Option<usize>,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.len(), rows * a.k);
    assert!((1..=MAX_TILE).contains(&tile));
    assert!(c.len() >= rows * a.cols, "output buffer too small");
    let kern = kernels::resolve(kernel);
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    pool.parallel_for_capped(a.strips, max_workers, |s0, s1| {
        for strip in s0..s1 {
            // SAFETY: as above — disjoint strip ranges, caller blocks
            // until all workers finish.
            unsafe { kern.dense_strip(w, rows, a, tile, strip, c_ptr.get(), c_len) };
        }
    });
}

/// Quantized twin of [`spmm_colwise_parallel_capped_into_with`]: the
/// i8 strip kernels write requantized f32 outputs into the same
/// disjoint column ranges, so the fan-out scheme (and the bitwise-
/// equal-to-serial contract) carries over unchanged — strengthened,
/// even: i8 results are bitwise identical across *backends* too.
// nmprune: zero-alloc
pub fn spmm_colwise_i8_parallel_capped_into_with(
    w: &ColwiseQuant,
    a: &QuantPanel,
    pool: &ThreadPool,
    max_workers: Option<usize>,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.cols, a.k);
    assert!(c.len() >= w.rows * a.cols, "output buffer too small");
    let kern = kernels::resolve(kernel);
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    pool.parallel_for_capped(a.strips, max_workers, |s0, s1| {
        for strip in s0..s1 {
            // SAFETY: strip output ranges are disjoint by construction,
            // and `c` outlives the parallel_for barrier.
            unsafe { kern.spmm_strip_i8(w, a, strip, c_ptr.get(), c_len) };
        }
    });
}

/// Quantized twin of [`gemm_dense_parallel_capped_into_with`].
// nmprune: zero-alloc
#[allow(clippy::too_many_arguments)]
pub fn gemm_dense_i8_parallel_capped_into_with(
    w: &QuantDense,
    a: &QuantPanel,
    tile: usize,
    pool: &ThreadPool,
    max_workers: Option<usize>,
    kernel: KernelId,
    c: &mut [f32],
) {
    assert_eq!(w.k, a.k);
    assert!((1..=MAX_TILE).contains(&tile));
    assert!(c.len() >= w.rows * a.cols, "output buffer too small");
    let kern = kernels::resolve(kernel);
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    pool.parallel_for_capped(a.strips, max_workers, |s0, s1| {
        for strip in s0..s1 {
            // SAFETY: as above — disjoint strip ranges, caller blocks
            // until all workers finish.
            unsafe { kern.dense_strip_i8(w, a, tile, strip, c_ptr.get(), c_len) };
        }
    });
}

/// Shareable raw pointer for disjoint-range writes across pool workers.
struct SendPtr(*mut f32);
// SAFETY: the wrapped pointer is only dereferenced inside kernel strip
// calls whose output column ranges are disjoint per strip, and the
// spawning call blocks on the pool barrier until all workers finish —
// no use-after-free, no overlapping writes.
unsafe impl Send for SendPtr {}
// SAFETY: as above — shared access is only ever disjoint-range writes
// bounded by the parallel_for barrier.
unsafe impl Sync for SendPtr {}
impl SendPtr {
    fn get(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{gemm_dense, matmul_ref, spmm_colwise};
    use crate::im2col::pack_data_matrix;
    use crate::pruning::prune_colwise;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn parallel_colwise_equals_serial() {
        let mut r = XorShiftRng::new(101);
        let (rows, k, cols) = (24, 36, 200);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 16);
        let serial = spmm_colwise(&cp, &p);
        for threads in [1, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let par = spmm_colwise_parallel(&cp, &p, &pool);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn parallel_dense_equals_serial_and_reference() {
        let mut r = XorShiftRng::new(102);
        let (rows, k, cols) = (17, 20, 130);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let p = pack_data_matrix(&a, k, cols, 8);
        let want = matmul_ref(&w, &a, rows, k, cols);
        let serial = gemm_dense(&w, rows, &p, 4);
        let pool = ThreadPool::new(4);
        let par = gemm_dense_parallel(&w, rows, &p, 4, &pool);
        assert!(allclose(&serial, &want, 1e-4, 1e-5));
        assert_eq!(par, serial);
    }

    #[test]
    fn single_strip_single_thread_degenerate() {
        let mut r = XorShiftRng::new(103);
        let (rows, k, cols) = (4, 8, 3);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 2, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 8);
        assert_eq!(p.strips, 1);
        let pool = ThreadPool::new(8);
        assert_eq!(
            spmm_colwise_parallel(&cp, &p, &pool),
            spmm_colwise(&cp, &p)
        );
    }

    #[test]
    fn capped_kernels_match_serial_bitwise() {
        let mut r = XorShiftRng::new(105);
        let (rows, k, cols) = (24, 36, 200);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 16);
        let serial_sparse = spmm_colwise(&cp, &p);
        let serial_dense = gemm_dense(&w, rows, &p, 8);
        let pool = ThreadPool::new(4);
        for cap in [Some(1), Some(2), Some(3), Some(4), Some(5), None] {
            assert_eq!(
                spmm_colwise_parallel_capped(&cp, &p, &pool, cap),
                serial_sparse,
                "sparse cap={cap:?}"
            );
            assert_eq!(
                gemm_dense_parallel_capped(&w, rows, &p, 8, &pool, cap),
                serial_dense,
                "dense cap={cap:?}"
            );
        }
    }

    #[test]
    fn i8_parallel_and_capped_match_serial_bitwise() {
        use crate::gemm::{gemm_dense_i8, spmm_colwise_i8};
        use crate::im2col::{quantize_panel_into, QuantPanel};
        use crate::pruning::{ColwiseQuant, QuantDense};
        let mut r = XorShiftRng::new(106);
        let (rows, k, cols) = (24, 36, 200);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let qw = ColwiseQuant::quantize(&cp);
        let qd = QuantDense::quantize(&w, rows, k);
        let p = pack_data_matrix(&a, k, cols, 16);
        let mut qa = QuantPanel::zeros(1, 1, 1);
        quantize_panel_into(&p, &mut qa);
        let serial_sparse = spmm_colwise_i8(&qw, &qa);
        let serial_dense = gemm_dense_i8(&qd, &qa, 8);
        let pool = ThreadPool::new(4);
        let mut got = vec![0.0f32; rows * cols];
        for cap in [Some(1), Some(2), Some(4), Some(7), None] {
            spmm_colwise_i8_parallel_capped_into_with(
                &qw, &qa, &pool, cap, KernelId::Auto, &mut got,
            );
            assert_eq!(got, serial_sparse, "sparse i8 cap={cap:?}");
            gemm_dense_i8_parallel_capped_into_with(
                &qd, &qa, 8, &pool, cap, KernelId::Auto, &mut got,
            );
            assert_eq!(got, serial_dense, "dense i8 cap={cap:?}");
        }
    }

    #[test]
    fn repeated_calls_reuse_one_pool() {
        // The serving pattern in miniature: one persistent pool, many
        // sequential GEMMs, no per-call thread spawns (the pool has no
        // way to grow — `size()` is fixed at construction).
        let mut r = XorShiftRng::new(104);
        let (rows, k, cols) = (16, 24, 150);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 4, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 16);
        let serial = spmm_colwise(&cp, &p);
        let pool = ThreadPool::new(4);
        for i in 0..50 {
            assert_eq!(spmm_colwise_parallel(&cp, &p, &pool), serial, "call {i}");
        }
        assert_eq!(pool.size(), 4);
    }
}
