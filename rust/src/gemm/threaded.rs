//! Multi-threaded GEMM drivers: output tiles (strips) processed in
//! parallel, the default XNNPACK parallelisation the paper uses (§4.1.1).

use crate::im2col::PackedMatrix;
use crate::pruning::ColwisePruned;
use crate::util::threadpool::scope_chunks;

use super::colwise::spmm_colwise_strip;
use super::dense::MAX_TILE;

/// Parallel column-wise SpMM: strips are distributed over `threads`.
pub fn spmm_colwise_parallel(
    w: &ColwisePruned,
    a: &PackedMatrix,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(w.cols, a.k);
    let mut c = vec![0.0f32; w.rows * a.cols];
    // Each strip writes a disjoint column range of C; hand each thread a
    // raw pointer and keep ranges disjoint by construction.
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    scope_chunks(threads, a.strips, |s0, s1| {
        let c_slice = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), c_len) };
        for strip in s0..s1 {
            spmm_colwise_strip(w, a, strip, c_slice);
        }
    });
    c
}

/// Parallel dense GEMM over strips.
pub fn gemm_dense_parallel(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(w.len(), rows * a.k);
    assert!((1..=MAX_TILE).contains(&tile));
    let mut c = vec![0.0f32; rows * a.cols];
    let c_ptr = SendPtr(c.as_mut_ptr());
    let c_len = c.len();
    scope_chunks(threads, a.strips, |s0, s1| {
        let c_slice = unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), c_len) };
        for strip in s0..s1 {
            dense_strip(w, rows, a, tile, strip, c_slice);
        }
    });
    c
}

fn dense_strip(
    w: &[f32],
    rows: usize,
    a: &PackedMatrix,
    tile: usize,
    strip: usize,
    c: &mut [f32],
) {
    let sdata = a.strip(strip);
    let valid = a.strip_valid(strip);
    let col0 = strip * a.v;
    let k = a.k;
    let mut row = 0;
    while row < rows {
        let t = tile.min(rows - row);
        let mut acc = [[0.0f32; 64]; MAX_TILE];
        for kk in 0..k {
            let arow = &sdata[kk * a.v..kk * a.v + valid];
            for ti in 0..t {
                let wv = w[(row + ti) * k + kk];
                for (aj, xj) in acc[ti][..valid].iter_mut().zip(arow) {
                    *aj += wv * xj;
                }
            }
        }
        for ti in 0..t {
            let r = row + ti;
            c[r * a.cols + col0..r * a.cols + col0 + valid]
                .copy_from_slice(&acc[ti][..valid]);
        }
        row += t;
    }
}

/// Shareable raw pointer for disjoint-range writes across scoped threads.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}
impl SendPtr {
    fn get(&self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{gemm_dense, matmul_ref, spmm_colwise};
    use crate::im2col::pack_data_matrix;
    use crate::pruning::prune_colwise;
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn parallel_colwise_equals_serial() {
        let mut r = XorShiftRng::new(101);
        let (rows, k, cols) = (24, 36, 200);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 16);
        let serial = spmm_colwise(&cp, &p);
        for threads in [1, 2, 4, 8] {
            let par = spmm_colwise_parallel(&cp, &p, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn parallel_dense_equals_serial_and_reference() {
        let mut r = XorShiftRng::new(102);
        let (rows, k, cols) = (17, 20, 130);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let p = pack_data_matrix(&a, k, cols, 8);
        let want = matmul_ref(&w, &a, rows, k, cols);
        let serial = gemm_dense(&w, rows, &p, 4);
        let par = gemm_dense_parallel(&w, rows, &p, 4, 4);
        assert!(allclose(&serial, &want, 1e-4, 1e-5));
        assert_eq!(par, serial);
    }

    #[test]
    fn single_strip_single_thread_degenerate() {
        let mut r = XorShiftRng::new(103);
        let (rows, k, cols) = (4, 8, 3);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 2, 2, 4);
        let p = pack_data_matrix(&a, k, cols, 8);
        assert_eq!(p.strips, 1);
        assert_eq!(
            spmm_colwise_parallel(&cp, &p, 8),
            spmm_colwise(&cp, &p)
        );
    }
}
