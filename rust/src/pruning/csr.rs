//! Unstructured magnitude pruning in CSR — the flexibility upper bound
//! (§2.1) against which the structured formats are compared.

/// Compressed Sparse Row matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, len rows+1.
    pub indptr: Vec<u32>,
    /// Column index of each stored value.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a dense matrix, storing all non-zeros.
    pub fn from_dense(w: &[f32], rows: usize, cols: usize) -> Self {
        assert_eq!(w.len(), rows * cols);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = w[r * cols + c];
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(values.len() as u32);
        }
        Self {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Reconstruct the dense matrix.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                out[r * self.cols + self.indices[k] as usize] = self.values[k];
            }
        }
        out
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// CSR·dense SpMM: `C[rows, v] = self · B[cols, v]` (reference only;
    /// the paper's kernels never materialise CSR on the hot path).
    pub fn spmm(&self, b: &[f32], v: usize) -> Vec<f32> {
        assert_eq!(b.len(), self.cols * v);
        let mut c = vec![0.0f32; self.rows * v];
        for r in 0..self.rows {
            for k in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                let col = self.indices[k] as usize;
                let w = self.values[k];
                let brow = &b[col * v..(col + 1) * v];
                let crow = &mut c[r * v..(r + 1) * v];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += w * bj;
                }
            }
        }
        c
    }
}

/// Global unstructured magnitude pruning to a target sparsity: zero the
/// smallest-|w| elements across the whole matrix.
pub fn prune_unstructured(w: &[f32], sparsity: f64) -> Vec<f32> {
    assert!((0.0..=1.0).contains(&sparsity));
    let mut order: Vec<usize> = (0..w.len()).collect();
    order.sort_by(|&a, &b| w[a].abs().total_cmp(&w[b].abs()));
    let drop = (w.len() as f64 * sparsity).round() as usize;
    let mut out = w.to_vec();
    for &i in &order[..drop] {
        out[i] = 0.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask::sparsity_of;
    use crate::util::XorShiftRng;

    #[test]
    fn csr_roundtrip() {
        let w = [0.0, 1.0, 0.0, 2.0, 0.0, 0.0, 3.0, 0.0, 4.0];
        let c = Csr::from_dense(&w, 3, 3);
        assert_eq!(c.nnz(), 4);
        assert_eq!(c.to_dense(), w.to_vec());
        assert_eq!(c.indptr, vec![0, 1, 2, 4]);
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let mut r = XorShiftRng::new(5);
        let (m, k, v) = (7, 9, 5);
        let w = prune_unstructured(&r.normal_vec(m * k, 1.0), 0.6);
        let b = r.normal_vec(k * v, 1.0);
        let csr = Csr::from_dense(&w, m, k);
        let got = csr.spmm(&b, v);
        let mut want = vec![0.0f32; m * v];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..v {
                    want[i * v + j] += w[i * k + kk] * b[kk * v + j];
                }
            }
        }
        assert!(crate::util::allclose(&got, &want, 1e-5, 1e-6));
    }

    #[test]
    fn unstructured_hits_exact_sparsity() {
        let mut r = XorShiftRng::new(6);
        let w = r.normal_vec(1000, 1.0);
        for s in [0.25, 0.5, 0.75, 0.9] {
            let p = prune_unstructured(&w, s);
            assert!((sparsity_of(&p) - s).abs() < 2e-3, "s={s}");
        }
    }

    #[test]
    fn unstructured_keeps_largest() {
        let w = [0.1, -5.0, 0.2, 3.0];
        let p = prune_unstructured(&w, 0.5);
        assert_eq!(p, vec![0.0, -5.0, 0.0, 3.0]);
    }
}
