//! Pruning-mask utilities shared by every format.

/// Apply a boolean mask to a weight slice (element-wise zeroing).
pub fn apply_mask(w: &mut [f32], mask: &[bool]) {
    assert_eq!(w.len(), mask.len());
    for (x, &keep) in w.iter_mut().zip(mask) {
        if !keep {
            *x = 0.0;
        }
    }
}

/// Fraction of exactly-zero elements.
pub fn sparsity_of(w: &[f32]) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    w.iter().filter(|x| **x == 0.0).count() as f64 / w.len() as f64
}

/// Indices of the `n` largest values in `scores` (ties broken by lower
/// index), returned in ascending index order. O(len·n) selection — group
/// sizes are small (M ≤ a few thousand).
pub fn top_n_indices(scores: &[f32], n: usize) -> Vec<usize> {
    let n = n.min(scores.len());
    let mut picked = vec![false; scores.len()];
    for _ in 0..n {
        let mut best: Option<usize> = None;
        for (i, &s) in scores.iter().enumerate() {
            if picked[i] {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) if s > scores[b] => best = Some(i),
                _ => {}
            }
        }
        picked[best.unwrap()] = true;
    }
    (0..scores.len()).filter(|&i| picked[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_mask_zeroes() {
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        apply_mask(&mut w, &[true, false, true, false]);
        assert_eq!(w, vec![1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        assert_eq!(sparsity_of(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(sparsity_of(&[]), 0.0);
        assert_eq!(sparsity_of(&[0.0; 4]), 1.0);
    }

    #[test]
    fn top_n_picks_largest_sorted() {
        let s = [0.5, 3.0, 1.0, 2.0];
        assert_eq!(top_n_indices(&s, 2), vec![1, 3]);
        assert_eq!(top_n_indices(&s, 0), Vec::<usize>::new());
        assert_eq!(top_n_indices(&s, 10), vec![0, 1, 2, 3]);
    }

    #[test]
    fn top_n_tie_break_lower_index() {
        let s = [1.0, 1.0, 1.0];
        assert_eq!(top_n_indices(&s, 2), vec![0, 1]);
    }
}
