//! Symmetric int8 weight quantization for the quantized GEMM plane.
//!
//! Weights are quantized **per output channel** (per row of the filter
//! matrix): row `r` gets scale `s_r = max|w_r| / 127` and stores
//! `q = round(w / s_r)` clamped to `[-127, 127]`. Keeping the range
//! symmetric and excluding `-128` guarantees every i16 product pair
//! `|a·w0 + a·w1| <= 2·127·127 = 32258 < 32767`, so the AVX2
//! `_mm256_madd_epi16` reduction is exact — integer accumulation is
//! therefore order-independent and **all** i8 backends are bitwise
//! identical (a stronger contract than the f32 kernels' ULP bound).
//!
//! The f32 master weights stay the source of truth everywhere
//! (artifacts store f32; quantization is a deterministic function of
//! them, so re-quantizing on load reproduces identical i8 values and
//! scales, keeping artifact roundtrips bitwise).

use super::colwise::ColwisePruned;

/// Quantize one value with a per-row scale. `scale == 0` (an all-zero
/// row) maps everything to 0.
#[inline]
pub fn quantize_value(v: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// One T-row tile of a quantized column-wise pruned matrix — the i8
/// twin of [`super::ColTile`], sharing its retained-column index set.
#[derive(Clone, Debug)]
pub struct QuantTile {
    /// First row of this tile in the original matrix.
    pub row_start: usize,
    /// Rows in this tile (== T except possibly the last tile).
    pub row_count: usize,
    /// Retained column indices, ascending (same set as the f32 tile).
    pub indices: Vec<u32>,
    /// Quantized values, row-major `[row_count, indices.len()]`.
    pub values: Vec<i8>,
}

/// Column-wise N:M compressed weights on the int8 plane: i8 tile
/// values plus one f32 scale per output row.
#[derive(Clone, Debug)]
pub struct ColwiseQuant {
    pub rows: usize,
    pub cols: usize,
    pub tile: usize,
    pub n: usize,
    pub m: usize,
    pub tiles: Vec<QuantTile>,
    /// Per-output-row dequantization scales, `len == rows`.
    pub scales: Vec<f32>,
}

impl ColwiseQuant {
    /// Quantize a column-wise pruned matrix per output row. Purely a
    /// function of the f32 values — deterministic, so artifact reload
    /// reproduces identical i8 weights.
    pub fn quantize(w: &ColwisePruned) -> Self {
        let mut maxabs = vec![0.0f32; w.rows];
        for t in &w.tiles {
            let nret = t.indices.len();
            for ti in 0..t.row_count {
                let m = &mut maxabs[t.row_start + ti];
                for j in 0..nret {
                    *m = m.max(t.values[ti * nret + j].abs());
                }
            }
        }
        let scales: Vec<f32> = maxabs.iter().map(|&m| m / 127.0).collect();
        let tiles = w
            .tiles
            .iter()
            .map(|t| {
                let nret = t.indices.len();
                let mut values = Vec::with_capacity(t.values.len());
                for ti in 0..t.row_count {
                    let s = scales[t.row_start + ti];
                    for j in 0..nret {
                        values.push(quantize_value(t.values[ti * nret + j], s));
                    }
                }
                QuantTile {
                    row_start: t.row_start,
                    row_count: t.row_count,
                    indices: t.indices.clone(),
                    values,
                }
            })
            .collect();
        Self {
            rows: w.rows,
            cols: w.cols,
            tile: w.tile,
            n: w.n,
            m: w.m,
            tiles,
            scales,
        }
    }

    /// Reconstruct the dense dequantized matrix (testing / error-bound
    /// derivation only).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for t in &self.tiles {
            let nret = t.indices.len();
            for ti in 0..t.row_count {
                let r = t.row_start + ti;
                let s = self.scales[r];
                for (j, &c) in t.indices.iter().enumerate() {
                    out[r * self.cols + c as usize] = t.values[ti * nret + j] as f32 * s;
                }
            }
        }
        out
    }
}

/// Dense filter matrix on the int8 plane: `[rows, k]` i8 values plus
/// one f32 scale per output row — the quantized twin of the dense
/// `[C_out, K]` filter.
#[derive(Clone, Debug)]
pub struct QuantDense {
    pub rows: usize,
    pub k: usize,
    /// Row-major `[rows, k]` quantized values.
    pub values: Vec<i8>,
    /// Per-output-row dequantization scales, `len == rows`.
    pub scales: Vec<f32>,
}

impl QuantDense {
    /// Quantize a dense `[rows, k]` f32 filter matrix per output row.
    pub fn quantize(w: &[f32], rows: usize, k: usize) -> Self {
        assert_eq!(w.len(), rows * k, "filter matrix shape");
        let mut values = Vec::with_capacity(w.len());
        let mut scales = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &w[r * k..(r + 1) * k];
            let maxabs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let s = maxabs / 127.0;
            scales.push(s);
            for &v in row {
                values.push(quantize_value(v, s));
            }
        }
        Self {
            rows,
            k,
            values,
            scales,
        }
    }

    /// Reconstruct the dequantized matrix (testing only).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.values.len());
        for r in 0..self.rows {
            let s = self.scales[r];
            for kk in 0..self.k {
                out.push(self.values[r * self.k + kk] as f32 * s);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::prune_colwise;
    use crate::util::XorShiftRng;

    #[test]
    fn quantize_value_is_symmetric_and_clamped() {
        assert_eq!(quantize_value(0.0, 1.0), 0);
        assert_eq!(quantize_value(1.0, 1.0 / 127.0), 127);
        assert_eq!(quantize_value(-1.0, 1.0 / 127.0), -127);
        // Values beyond the scale range clamp at ±127, never -128.
        assert_eq!(quantize_value(10.0, 1.0 / 127.0), 127);
        assert_eq!(quantize_value(-10.0, 1.0 / 127.0), -127);
        // All-zero rows get scale 0 and quantize to 0.
        assert_eq!(quantize_value(0.5, 0.0), 0);
    }

    #[test]
    fn colwise_roundtrip_error_within_half_step() {
        let mut r = XorShiftRng::new(0x1A01);
        let (rows, cols) = (16, 32);
        let w = r.normal_vec(rows * cols, 1.0);
        let p = prune_colwise(&w, rows, cols, 4, 2, 4);
        let q = ColwiseQuant::quantize(&p);
        assert_eq!(q.scales.len(), rows);
        let dense = p.decompress();
        let deq = q.dequantize();
        for r_ in 0..rows {
            let half_step = q.scales[r_] * 0.5 + 1e-6;
            for c in 0..cols {
                let d = (dense[r_ * cols + c] - deq[r_ * cols + c]).abs();
                assert!(d <= half_step, "row {r_} col {c}: err {d} > {half_step}");
            }
        }
        // The retained-column index sets are shared verbatim.
        for (a, b) in p.tiles.iter().zip(&q.tiles) {
            assert_eq!(a.indices, b.indices);
            assert!(b.values.iter().all(|&v| v >= -127));
        }
    }

    #[test]
    fn quantization_is_deterministic() {
        let mut r = XorShiftRng::new(0x1A02);
        let w = r.normal_vec(8 * 16, 1.0);
        let p = prune_colwise(&w, 8, 16, 4, 2, 4);
        let q1 = ColwiseQuant::quantize(&p);
        let q2 = ColwiseQuant::quantize(&p);
        assert_eq!(
            q1.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            q2.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
        for (a, b) in q1.tiles.iter().zip(&q2.tiles) {
            assert_eq!(a.values, b.values);
        }
    }

    #[test]
    fn dense_roundtrip_error_within_half_step() {
        let mut r = XorShiftRng::new(0x1A03);
        let (rows, k) = (9, 24);
        let w = r.normal_vec(rows * k, 1.0);
        let q = QuantDense::quantize(&w, rows, k);
        let deq = q.dequantize();
        for r_ in 0..rows {
            let half_step = q.scales[r_] * 0.5 + 1e-6;
            for kk in 0..k {
                let d = (w[r_ * k + kk] - deq[r_ * k + kk]).abs();
                assert!(d <= half_step, "row {r_} k {kk}");
            }
        }
    }

    #[test]
    fn all_zero_rows_quantize_to_zero() {
        let w = vec![0.0f32; 4 * 8];
        let q = QuantDense::quantize(&w, 4, 8);
        assert!(q.scales.iter().all(|&s| s == 0.0));
        assert!(q.values.iter().all(|&v| v == 0));
        assert!(q.dequantize().iter().all(|&v| v == 0.0));
    }
}
