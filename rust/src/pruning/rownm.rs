//! Conventional row-based N:M pruning (Fig. 1, Fig. 3b).
//!
//! Within each row of `W[rows, cols]`, every aligned group of `M`
//! consecutive elements keeps the `N` largest-magnitude values. The
//! compressed form stores, per row, the retained values plus a parallel
//! index array of their column positions — the format GPU sparse tensor
//! cores (and the paper's inner/outer-product CPU baselines) consume.

use super::mask::top_n_indices;

/// Row-based N:M compressed weight matrix.
#[derive(Clone, Debug)]
pub struct RowNmPruned {
    pub rows: usize,
    pub cols: usize,
    pub n: usize,
    pub m: usize,
    /// Retained values, row-major `[rows, retained_per_row]`.
    pub values: Vec<f32>,
    /// Column index of each retained value, same shape as `values`.
    pub indices: Vec<u32>,
    /// Retained elements per row (= #groups·N, tail group may keep fewer
    /// slots but is padded with explicit zeros at valid indices).
    pub per_row: usize,
}

impl RowNmPruned {
    /// Reconstruct the dense (masked) matrix.
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for j in 0..self.per_row {
                let v = self.values[r * self.per_row + j];
                // Zero-valued pad slots may alias a retained index (tail
                // groups); never let them overwrite a real value.
                if v != 0.0 {
                    let c = self.indices[r * self.per_row + j] as usize;
                    out[r * self.cols + c] = v;
                }
            }
        }
        out
    }

    /// Fraction of weights removed.
    pub fn sparsity(&self) -> f64 {
        1.0 - (self.per_row as f64 / self.cols as f64)
    }
}

/// Prune `w[rows, cols]` with row-based N:M magnitude pruning.
///
/// Groups are aligned: columns `[g*M, (g+1)*M)`. A tail group narrower
/// than `M` keeps `min(N, width)` elements so the compressed row stays
/// rectangular only when `cols % M == 0`; otherwise the tail keeps
/// proportionally fewer and the row is padded with zero-valued entries
/// pointing at the first tail column (harmless to GEMM).
pub fn prune_rownm(w: &[f32], rows: usize, cols: usize, n: usize, m: usize) -> RowNmPruned {
    assert_eq!(w.len(), rows * cols);
    assert!(n <= m && m >= 1, "invalid N:M = {n}:{m}");
    let groups = cols.div_ceil(m);
    let per_row = groups * n;
    let mut values = vec![0.0f32; rows * per_row];
    let mut indices = vec![0u32; rows * per_row];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let mut slot = 0usize;
        for g in 0..groups {
            let start = g * m;
            let width = m.min(cols - start);
            let scores: Vec<f32> = row[start..start + width].iter().map(|x| x.abs()).collect();
            let keep = top_n_indices(&scores, n.min(width));
            for &k in &keep {
                values[r * per_row + slot] = row[start + k];
                indices[r * per_row + slot] = (start + k) as u32;
                slot += 1;
            }
            // Pad any unfilled slots (tail group narrower than N).
            for _ in keep.len()..n {
                values[r * per_row + slot] = 0.0;
                indices[r * per_row + slot] = start as u32;
                slot += 1;
            }
        }
    }
    RowNmPruned {
        rows,
        cols,
        n,
        m,
        values,
        indices,
        per_row,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask::sparsity_of;
    use crate::util::{prop, XorShiftRng};

    #[test]
    fn keeps_largest_in_each_group() {
        // One row, two groups of 4, 2:4.
        let w = [1.0, -5.0, 2.0, 0.5, 0.1, 0.2, -0.3, 0.4];
        let p = prune_rownm(&w, 1, 8, 2, 4);
        let d = p.decompress();
        assert_eq!(d, vec![0.0, -5.0, 2.0, 0.0, 0.0, 0.0, -0.3, 0.4]);
        assert_eq!(p.sparsity(), 0.5);
    }

    #[test]
    fn group_alignment_is_per_m_columns() {
        // 1:2 over 4 cols: groups [0,1] and [2,3].
        let w = [3.0, 1.0, 1.0, 3.0];
        let p = prune_rownm(&w, 1, 4, 1, 2);
        assert_eq!(p.decompress(), vec![3.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn tail_group_handled() {
        // cols=5, M=4: tail group has width 1, keeps min(2,1)=1.
        let w = [1.0, 2.0, 3.0, 4.0, 9.0];
        let p = prune_rownm(&w, 1, 5, 2, 4);
        let d = p.decompress();
        assert_eq!(d, vec![0.0, 0.0, 3.0, 4.0, 9.0]);
    }

    #[test]
    fn multi_row_independent() {
        let w = [5.0, 1.0, 1.0, 5.0];
        let p = prune_rownm(&w, 2, 2, 1, 2);
        assert_eq!(p.decompress(), vec![5.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn prop_decompress_zero_pattern_and_magnitude() {
        // Property: (a) sparsity ≈ 1 - N/M, (b) every retained element
        // appears unchanged at its original position, (c) within each
        // aligned group, every dropped |w| <= every kept |w|.
        prop::check_seeded(
            0xA11CE,
            |r, size| {
                let rows = 1 + size % 7;
                let cols = 4 * (1 + size % 9);
                let w = r.normal_vec(rows * cols, 1.0);
                (w, rows, cols)
            },
            |(w, rows, cols)| {
                let p = prune_rownm(w, *rows, *cols, 2, 4);
                let d = p.decompress();
                if sparsity_of(&d) < 0.49 {
                    return false;
                }
                for r in 0..*rows {
                    for g in 0..cols / 4 {
                        let orig = &w[r * cols + g * 4..r * cols + g * 4 + 4];
                        let got = &d[r * cols + g * 4..r * cols + g * 4 + 4];
                        let kept_min = orig
                            .iter()
                            .zip(got)
                            .filter(|(_, &y)| y != 0.0)
                            .map(|(&x, _)| x.abs())
                            .fold(f32::INFINITY, f32::min);
                        let drop_max = orig
                            .iter()
                            .zip(got)
                            .filter(|(_, &y)| y == 0.0)
                            .map(|(&x, _)| x.abs())
                            .fold(0.0f32, f32::max);
                        if drop_max > kept_min {
                            return false;
                        }
                        if !orig.iter().zip(got).all(|(&x, &y)| y == 0.0 || y == x) {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn randomized_sparsity_exact_for_aligned() {
        let mut r = XorShiftRng::new(77);
        for _ in 0..20 {
            for (n, m) in [(1, 4), (2, 4), (3, 4), (4, 8)] {
                let rows = 1 + r.below(16);
                let cols = m * (1 + r.below(16)); // aligned: m divides cols
                let w = r.normal_vec(rows * cols, 1.0);
                let p = prune_rownm(&w, rows, cols, n, m);
                assert!((p.sparsity() - (1.0 - n as f64 / m as f64)).abs() < 1e-9);
            }
        }
    }
}
