//! Column-wise N:M pruning — the paper's contribution (§3.1, Fig. 3c).
//!
//! The weight matrix `W[rows, cols]` is split into tiles of `T` rows.
//! Within a tile, each *column* (T elements) is a pruning unit scored by
//! its L1 norm. Inside every aligned group of `M` consecutive columns the
//! `N` highest-scoring columns are retained; the rest are zeroed. All
//! rows of the tile therefore share one retained-column index set, so the
//! micro-kernel can load a data-matrix row once and reuse it across all T
//! accumulators (Algorithm 1).
//!
//! `M` may span the whole reduction dimension ("adaptive M", §3.1/§4.5
//! configs 3–4), which approaches unstructured pruning accuracy while
//! keeping the structured execution pattern.

use super::mask::top_n_indices;
use super::retained_for_sparsity;

/// One T-row tile of a column-wise pruned matrix.
#[derive(Clone, Debug)]
pub struct ColTile {
    /// First row of this tile in the original matrix.
    pub row_start: usize,
    /// Rows in this tile (== T except possibly the last tile).
    pub row_count: usize,
    /// Retained column indices, ascending. Shared by every row of the tile.
    pub indices: Vec<u32>,
    /// Retained values, row-major `[row_count, indices.len()]`.
    pub values: Vec<f32>,
}

impl ColTile {
    /// Value of retained column slot `j` in tile-local row `t`.
    #[inline]
    pub fn value(&self, t: usize, j: usize) -> f32 {
        self.values[t * self.indices.len() + j]
    }
}

/// Column-wise N:M compressed weight matrix (tile size T).
#[derive(Clone, Debug)]
pub struct ColwisePruned {
    pub rows: usize,
    pub cols: usize,
    pub tile: usize,
    pub n: usize,
    pub m: usize,
    pub tiles: Vec<ColTile>,
}

impl ColwisePruned {
    /// Reconstruct the dense (masked) matrix.
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for tile in &self.tiles {
            for t in 0..tile.row_count {
                let r = tile.row_start + t;
                for (j, &c) in tile.indices.iter().enumerate() {
                    out[r * self.cols + c as usize] = tile.value(t, j);
                }
            }
        }
        out
    }

    /// Fraction of weights removed.
    pub fn sparsity(&self) -> f64 {
        let kept: usize = self
            .tiles
            .iter()
            .map(|t| t.indices.len() * t.row_count)
            .sum();
        1.0 - kept as f64 / (self.rows * self.cols) as f64
    }

    /// Retained columns per tile (uniform across tiles for aligned M).
    pub fn retained_per_tile(&self) -> usize {
        self.tiles.first().map(|t| t.indices.len()).unwrap_or(0)
    }

    /// FLOPs of the sparse GEMM against a `[cols, v]` data matrix:
    /// 2·(retained columns)·rows·v.
    pub fn gemm_flops(&self, v: usize) -> usize {
        self.tiles
            .iter()
            .map(|t| 2 * t.indices.len() * t.row_count * v)
            .sum()
    }

    /// Exact byte length of [`Self::encode_into`]'s output — lets a
    /// caller reserve aligned storage ahead of the write.
    pub fn encoded_len(&self) -> usize {
        6 * 4
            + self
                .tiles
                .iter()
                .map(|t| 3 * 4 + 4 * t.indices.len() + 4 * t.values.len())
                .sum::<usize>()
    }

    /// Serialize into caller-provided storage (little-endian, the
    /// packed-weight artifact's per-layer payload): the six header words
    /// `rows cols tile n m n_tiles`, then per tile `row_start row_count
    /// idx_count`, the `u32` retained indices, and the `f32` values.
    /// Appends exactly [`Self::encoded_len`] bytes to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let w32 = |out: &mut Vec<u8>, v: usize| out.extend_from_slice(&(v as u32).to_le_bytes());
        w32(out, self.rows);
        w32(out, self.cols);
        w32(out, self.tile);
        w32(out, self.n);
        w32(out, self.m);
        w32(out, self.tiles.len());
        for t in &self.tiles {
            w32(out, t.row_start);
            w32(out, t.row_count);
            w32(out, t.indices.len());
            for &i in &t.indices {
                out.extend_from_slice(&i.to_le_bytes());
            }
            for &v in &t.values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    /// Decode an [`Self::encode_into`] payload from `bytes`, returning
    /// the matrix and the number of bytes consumed. Every structural
    /// invariant is revalidated with hard (release-mode) checks —
    /// truncated payloads, out-of-range indices, unsorted index sets,
    /// or tiles that don't cover the rows exactly all error instead of
    /// producing a matrix the kernels would mis-execute.
    pub fn decode(bytes: &[u8]) -> std::result::Result<(Self, usize), String> {
        fn r32(bytes: &[u8], pos: &mut usize) -> std::result::Result<usize, String> {
            let end = pos
                .checked_add(4)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| format!("colwise payload truncated at byte {pos}"))?;
            let v = u32::from_le_bytes(bytes[*pos..end].try_into().unwrap());
            *pos = end;
            Ok(v as usize)
        }
        let mut pos = 0usize;
        let rows = r32(bytes, &mut pos)?;
        let cols = r32(bytes, &mut pos)?;
        let tile = r32(bytes, &mut pos)?;
        let n = r32(bytes, &mut pos)?;
        let m = r32(bytes, &mut pos)?;
        let n_tiles = r32(bytes, &mut pos)?;
        if rows == 0 || cols == 0 || tile == 0 {
            return Err(format!("colwise payload: zero dims {rows}x{cols} tile {tile}"));
        }
        if n == 0 || m == 0 || n > m || cols % m != 0 {
            return Err(format!("colwise payload: invalid N:M = {n}:{m} for {cols} cols"));
        }
        if n_tiles != rows.div_ceil(tile) {
            return Err(format!(
                "colwise payload: {n_tiles} tiles but {rows} rows / tile {tile} needs {}",
                rows.div_ceil(tile)
            ));
        }
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut expect_row = 0usize;
        for ti in 0..n_tiles {
            let row_start = r32(bytes, &mut pos)?;
            let row_count = r32(bytes, &mut pos)?;
            let idx_count = r32(bytes, &mut pos)?;
            if row_start != expect_row
                || row_count != tile.min(rows - row_start.min(rows))
                || row_start + row_count > rows
            {
                return Err(format!(
                    "colwise payload: tile {ti} covers rows {row_start}+{row_count}, \
                     expected start {expect_row}"
                ));
            }
            if idx_count > cols {
                return Err(format!(
                    "colwise payload: tile {ti} retains {idx_count} of {cols} columns"
                ));
            }
            let mut indices = Vec::with_capacity(idx_count);
            for _ in 0..idx_count {
                let c = r32(bytes, &mut pos)?;
                if c >= cols {
                    return Err(format!("colwise payload: column index {c} >= {cols}"));
                }
                if let Some(&prev) = indices.last() {
                    if c as u32 <= prev {
                        return Err(format!(
                            "colwise payload: tile {ti} indices not strictly ascending"
                        ));
                    }
                }
                indices.push(c as u32);
            }
            let n_vals = row_count * idx_count;
            let end = pos
                .checked_add(4 * n_vals)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| format!("colwise payload truncated in tile {ti} values"))?;
            let mut values = Vec::with_capacity(n_vals);
            for off in (pos..end).step_by(4) {
                values.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            }
            pos = end;
            expect_row = row_start + row_count;
            tiles.push(ColTile {
                row_start,
                row_count,
                indices,
                values,
            });
        }
        if expect_row != rows {
            return Err(format!(
                "colwise payload: tiles cover {expect_row} of {rows} rows"
            ));
        }
        Ok((
            Self {
                rows,
                cols,
                tile,
                n,
                m,
                tiles,
            },
            pos,
        ))
    }
}

/// Prune `w[rows, cols]` column-wise with groups of `M` consecutive
/// columns keeping `N` per group, scored by the tile-local column L1
/// norm.
///
/// Parameter contract (violations panic — release builds included):
/// `1 <= N <= M` and `M` must divide `cols`, so every tile's column
/// range decomposes into whole aligned groups. `N = 0` would retain
/// nothing (use [`prune_colwise_adaptive`] with a sparsity target
/// instead); a ragged tail group would silently change the effective
/// sparsity and mis-align the kernel's shared index set.
pub fn prune_colwise(
    w: &[f32],
    rows: usize,
    cols: usize,
    tile: usize,
    n: usize,
    m: usize,
) -> ColwisePruned {
    assert_eq!(w.len(), rows * cols);
    assert!(
        n >= 1,
        "invalid N:M = {n}:{m}: N must be >= 1 (N = 0 retains nothing)"
    );
    assert!(m >= 1 && n <= m, "invalid N:M = {n}:{m}");
    assert!(
        cols % m == 0,
        "invalid N:M = {n}:{m}: M must divide the reduction dimension \
         ({cols} columns) so groups stay aligned"
    );
    assert!(tile >= 1);
    let mut tiles = Vec::with_capacity(rows.div_ceil(tile));
    let groups = cols / m;
    for row_start in (0..rows).step_by(tile) {
        let row_count = tile.min(rows - row_start);
        // Column L1 norms over this tile's rows.
        let mut keep_cols: Vec<u32> = Vec::with_capacity(groups * n);
        for g in 0..groups {
            let start = g * m;
            let scores: Vec<f32> = (start..start + m)
                .map(|c| {
                    (0..row_count)
                        .map(|t| w[(row_start + t) * cols + c].abs())
                        .sum()
                })
                .collect();
            for k in top_n_indices(&scores, n) {
                keep_cols.push((start + k) as u32);
            }
        }
        let mut values = Vec::with_capacity(row_count * keep_cols.len());
        for t in 0..row_count {
            for &c in &keep_cols {
                values.push(w[(row_start + t) * cols + c as usize]);
            }
        }
        tiles.push(ColTile {
            row_start,
            row_count,
            indices: keep_cols,
            values,
        });
    }
    ColwisePruned {
        rows,
        cols,
        tile,
        n,
        m,
        tiles,
    }
}

/// Adaptive-M column-wise pruning: `M = cols` (the whole reduction
/// dimension) and `N = round((1-sparsity)·M)` — configs 3/4 in §4.5.
pub fn prune_colwise_adaptive(
    w: &[f32],
    rows: usize,
    cols: usize,
    tile: usize,
    sparsity: f64,
) -> ColwisePruned {
    let n = retained_for_sparsity(cols, sparsity).max(1);
    prune_colwise(w, rows, cols, tile, n, cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, XorShiftRng};

    #[test]
    fn whole_columns_pruned_within_tile() {
        // 2 rows, 4 cols, tile=2, 1:2 → within each column pair, the pair
        // with larger L1 survives whole.
        #[rustfmt::skip]
        let w = [
            1.0, 9.0, 2.0, 0.1,
            1.0, 9.0, 2.0, 0.1,
        ];
        let p = prune_colwise(&w, 2, 4, 2, 1, 2);
        let d = p.decompress();
        #[rustfmt::skip]
        assert_eq!(d, vec![
            0.0, 9.0, 2.0, 0.0,
            0.0, 9.0, 2.0, 0.0,
        ]);
        assert_eq!(p.tiles.len(), 1);
        assert_eq!(p.tiles[0].indices, vec![1, 2]);
    }

    #[test]
    fn l1_scoring_sums_over_tile_rows() {
        // Column 0 has small values in both rows (L1=2), column 1 has one
        // big value (L1=10) → column 1 wins even though row 1's entry is 0.
        #[rustfmt::skip]
        let w = [
            1.0, 10.0,
            1.0,  0.0,
        ];
        let p = prune_colwise(&w, 2, 2, 2, 1, 2);
        assert_eq!(p.tiles[0].indices, vec![1]);
        assert_eq!(p.decompress(), vec![0.0, 10.0, 0.0, 0.0]);
    }

    #[test]
    fn tiles_prune_independently() {
        // tile=1 reduces to per-row N:M with L1 = |w| (row-based special
        // case, as §4.5 config 1 notes: "equivalent to ... tile size of 1").
        #[rustfmt::skip]
        let w = [
            5.0, 1.0,
            1.0, 5.0,
        ];
        let p = prune_colwise(&w, 2, 2, 1, 1, 2);
        assert_eq!(p.decompress(), vec![5.0, 0.0, 0.0, 5.0]);
        assert_eq!(p.tiles.len(), 2);
    }

    #[test]
    fn tail_tile_retains_exactly() {
        let mut r = XorShiftRng::new(4);
        // rows=5 with tile=2 → tiles of 2,2,1 (row tails are fine; only
        // column groups must be aligned). cols=6 with M=3 → 2 groups.
        let w = r.normal_vec(5 * 6, 1.0);
        let p = prune_colwise(&w, 5, 6, 2, 2, 3);
        assert_eq!(p.tiles.len(), 3);
        assert_eq!(p.tiles[2].row_count, 1);
        // Each of the 2 groups keeps 2 of 3 → 4 indices.
        assert_eq!(p.retained_per_tile(), 4);
        let d = p.decompress();
        // Retained values must match original exactly.
        for tile in &p.tiles {
            for t in 0..tile.row_count {
                for (j, &c) in tile.indices.iter().enumerate() {
                    let r_ = tile.row_start + t;
                    assert_eq!(tile.value(t, j), w[r_ * 6 + c as usize]);
                    assert_eq!(d[r_ * 6 + c as usize], w[r_ * 6 + c as usize]);
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "N must be >= 1")]
    fn rejects_n_zero() {
        // The seed accepted n = 0 and produced tiles that retained
        // nothing — downstream kernels then emitted silent zeros.
        prune_colwise(&[1.0; 8], 2, 4, 2, 0, 4);
    }

    #[test]
    #[should_panic(expected = "invalid N:M = 5:4")]
    fn rejects_n_greater_than_m() {
        prune_colwise(&[1.0; 8], 2, 4, 2, 5, 4);
    }

    #[test]
    #[should_panic(expected = "must divide the reduction dimension")]
    fn rejects_m_not_dividing_cols() {
        // cols = 6 with M = 4 would leave a ragged tail group.
        prune_colwise(&[1.0; 12], 2, 6, 2, 2, 4);
    }

    #[test]
    #[should_panic(expected = "invalid N:M")]
    fn rejects_m_zero() {
        prune_colwise(&[1.0; 8], 2, 4, 2, 1, 0);
    }

    #[test]
    fn adaptive_m_hits_target_sparsity() {
        let mut r = XorShiftRng::new(9);
        let (rows, cols) = (16, 64);
        let w = r.normal_vec(rows * cols, 1.0);
        for s in [0.25, 0.5, 0.75] {
            let p = prune_colwise_adaptive(&w, rows, cols, 8, s);
            assert!(
                (p.sparsity() - s).abs() < 0.02,
                "target {s}, got {}",
                p.sparsity()
            );
        }
    }

    #[test]
    fn prop_indices_sorted_unique_and_l1_optimal_per_group() {
        prop::check_seeded(
            0xC01,
            |r, size| {
                let rows = 1 + size % 9;
                let cols = 4 * (1 + size % 8);
                let tile = 1 + size % 5;
                let w = r.normal_vec(rows * cols, 1.0);
                (w, rows, cols, tile)
            },
            |(w, rows, cols, tile)| {
                let p = prune_colwise(w, *rows, *cols, *tile, 2, 4);
                for t in &p.tiles {
                    // sorted + unique indices
                    if !t.indices.windows(2).all(|p| p[0] < p[1]) {
                        return false;
                    }
                    // within each group, kept column L1 >= dropped column L1
                    for g in 0..cols / 4 {
                        let l1 = |c: usize| -> f32 {
                            (0..t.row_count)
                                .map(|tr| w[(t.row_start + tr) * cols + c].abs())
                                .sum()
                        };
                        let kept: Vec<usize> = t
                            .indices
                            .iter()
                            .map(|&c| c as usize)
                            .filter(|&c| c / 4 == g)
                            .collect();
                        if kept.len() != 2 {
                            return false;
                        }
                        let kept_min = kept.iter().map(|&c| l1(c)).fold(f32::INFINITY, f32::min);
                        let drop_max = (g * 4..g * 4 + 4)
                            .filter(|c| !kept.contains(c))
                            .map(l1)
                            .fold(0.0f32, f32::max);
                        if drop_max > kept_min + 1e-5 {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn prop_decompress_recompress_fixpoint() {
        // Pruning an already-pruned matrix with the same params must be
        // the identity (idempotence).
        prop::check_seeded(
            0xC02,
            |r, size| {
                let rows = 2 + size % 10;
                let cols = 8 * (1 + size % 4);
                let w = r.normal_vec(rows * cols, 1.0);
                (w, rows, cols)
            },
            |(w, rows, cols)| {
                let p1 = prune_colwise(w, *rows, *cols, 4, 2, 8);
                let d1 = p1.decompress();
                let p2 = prune_colwise(&d1, *rows, *cols, 4, 2, 8);
                p2.decompress() == d1
            },
        );
    }

    #[test]
    fn encode_decode_roundtrip_is_bitwise() {
        let mut r = XorShiftRng::new(0xA07);
        for (rows, cols, tile, n, m) in
            [(5, 8, 2, 2, 4), (16, 64, 8, 4, 64), (1, 4, 3, 1, 2), (7, 12, 7, 3, 12)]
        {
            let w = r.normal_vec(rows * cols, 1.0);
            let p = prune_colwise(&w, rows, cols, tile, n, m);
            let mut bytes = Vec::new();
            p.encode_into(&mut bytes);
            assert_eq!(bytes.len(), p.encoded_len());
            let (q, used) = ColwisePruned::decode(&bytes).unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!((q.rows, q.cols, q.tile, q.n, q.m), (rows, cols, tile, n, m));
            assert_eq!(q.tiles.len(), p.tiles.len());
            for (a, b) in p.tiles.iter().zip(&q.tiles) {
                assert_eq!(a.indices, b.indices);
                // bit-for-bit, not approximate: to_bits comparison.
                assert_eq!(
                    a.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn decode_rejects_corrupted_payloads() {
        let mut r = XorShiftRng::new(0xA08);
        let w = r.normal_vec(8 * 16, 1.0);
        let p = prune_colwise(&w, 8, 16, 4, 2, 4);
        let mut good = Vec::new();
        p.encode_into(&mut good);
        assert!(ColwisePruned::decode(&good).is_ok());
        // Truncation at every prefix length must error, never panic.
        for len in 0..good.len() {
            assert!(ColwisePruned::decode(&good[..len]).is_err(), "prefix {len}");
        }
        // Out-of-range retained index.
        let mut bad = good.clone();
        bad[6 * 4 + 3 * 4..6 * 4 + 4 * 4].copy_from_slice(&999u32.to_le_bytes());
        assert!(ColwisePruned::decode(&bad).is_err());
        // Tile-count / row-coverage mismatch.
        let mut bad = good.clone();
        bad[5 * 4..6 * 4].copy_from_slice(&7u32.to_le_bytes());
        assert!(ColwisePruned::decode(&bad).is_err());
        // Invalid N:M header.
        let mut bad = good;
        bad[3 * 4..4 * 4].copy_from_slice(&0u32.to_le_bytes());
        assert!(ColwisePruned::decode(&bad).is_err());
    }

    #[test]
    fn flops_scale_with_sparsity() {
        let mut r = XorShiftRng::new(10);
        let w = r.normal_vec(32 * 64, 1.0);
        let dense_flops = 2 * 32 * 64 * 16;
        let p = prune_colwise(&w, 32, 64, 8, 2, 4);
        assert_eq!(p.gemm_flops(16), dense_flops / 2);
    }
}
