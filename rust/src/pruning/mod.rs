//! N:M weight pruning: formats, scoring and compression.
//!
//! The GEMM view of a conv layer multiplies a weight (filter) matrix
//! `W[C_out, K]` (K = K_h·K_w·C_in) with the im2col'd data matrix. The
//! paper compares three sparsity formats over that weight matrix:
//!
//! * [`rownm`] — conventional row-based N:M: within each row, every group
//!   of M consecutive elements keeps at most N (Fig. 1/3b).
//! * [`colwise`] — the paper's contribution: at the tile level (T rows),
//!   whole *columns* are grouped and pruned/retained as a unit, scored by
//!   L1 norm (Fig. 3c). All rows of a tile then share a single retained
//!   column index set, which is what enables the register-resident
//!   outer-product micro-kernel (Algorithm 1).
//! * [`csr`] — unstructured magnitude pruning in CSR, the format used by
//!   the related-work discussion, included as a baseline.

pub mod mask;
pub mod rownm;
pub mod colwise;
pub mod csr;
pub mod quant;

pub use colwise::{prune_colwise, prune_colwise_adaptive, ColTile, ColwisePruned};
pub use mask::{apply_mask, sparsity_of};
pub use rownm::{prune_rownm, RowNmPruned};
pub use csr::{prune_unstructured, Csr};
pub use quant::{ColwiseQuant, QuantDense, QuantTile};

/// Number of retained elements per group for a target sparsity ratio:
/// `N = round((1 - sparsity) * M)`, clamped to [0, M] (§3.1).
pub fn retained_for_sparsity(m: usize, sparsity: f64) -> usize {
    assert!((0.0..=1.0).contains(&sparsity), "sparsity {sparsity}");
    (((1.0 - sparsity) * m as f64).round() as usize).min(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retained_matches_paper_configs() {
        // 2:4 = 50%, 1:4 = 75%, 3:4 = 25% (Table 1).
        assert_eq!(retained_for_sparsity(4, 0.50), 2);
        assert_eq!(retained_for_sparsity(4, 0.75), 1);
        assert_eq!(retained_for_sparsity(4, 0.25), 3);
        // Adaptive-M example: C_in*Kh*Kw = 576 at 75%.
        assert_eq!(retained_for_sparsity(576, 0.75), 144);
        assert_eq!(retained_for_sparsity(8, 1.0), 0);
        assert_eq!(retained_for_sparsity(8, 0.0), 8);
    }
}
