//! General-purpose substrates hand-rolled for the offline environment:
//! PRNG, statistics, thread pool, CLI parsing, JSON, and a small
//! property-test driver (the vendored crate set has no
//! rand/rayon/clap/serde/proptest).

pub mod allocwatch;
pub mod env;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod cli;
pub mod prop;
pub mod json;

pub use rng::XorShiftRng;
pub use stats::Summary;
pub use threadpool::ThreadPool;

/// Numerical comparison with combined absolute + relative tolerance,
/// mirroring `numpy.allclose` so Rust- and Python-side checks agree.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Maximum absolute elementwise difference (0 for empty slices).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_basic() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6));
    }

    #[test]
    fn ceil_div_and_round_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(round_up(10, 8), 16);
        assert_eq!(round_up(16, 8), 16);
        assert_eq!(round_up(0, 8), 0);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
