//! Deterministic xorshift128+ PRNG.
//!
//! Used everywhere randomness is needed (weight init, workload generation,
//! property tests) so every experiment in EXPERIMENTS.md is reproducible
//! from a seed. No external `rand` crate is available offline.

/// xorshift128+ generator (Vigna, 2017). Fast, passes BigCrush except
/// the lowest bits — more than adequate for test-data generation.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    s0: u64,
    s1: u64,
}

impl XorShiftRng {
    /// Create a generator from a seed. Seed 0 is remapped (all-zero state
    /// is a fixed point of xorshift).
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed over the 128-bit state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let s1 = next();
        Self {
            s0: if s0 == 0 && s1 == 0 { 1 } else { s0 },
            s1,
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of the high word.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-9 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of uniform values in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }

    /// Vector of N(0, scale) values.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = XorShiftRng::new(9);
        for _ in 0..1000 {
            let x = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = XorShiftRng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_plausible() {
        let mut r = XorShiftRng::new(11);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShiftRng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = XorShiftRng::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }
}
