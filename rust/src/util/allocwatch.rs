//! Opt-in allocation counting for the zero-alloc serving guarantee.
//!
//! [`CountingAlloc`] wraps the system allocator and counts heap
//! allocations made while the *current thread* is inside a [`scoped`]
//! region. It observes nothing unless a binary registers it:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: nmprune::util::allocwatch::CountingAlloc = CountingAlloc;
//! ```
//!
//! The zero-alloc integration tests (`rust/tests/zero_alloc.rs`) do
//! exactly that. Production binaries don't, so the `scoped` wrappers on
//! the serving hot path cost two thread-local stores per batch and
//! count nothing — the instrumentation is structurally inert outside
//! the test harness.
//!
//! Counting is deliberately per-thread, not process-global: `cargo
//! test` runs tests on concurrent threads, and a global counter would
//! pick up every other test's allocations. The serving layer therefore
//! scopes *inside* each dispatcher thread (the thread doing the
//! compute) and aggregates the deltas into its stats, where the test
//! can read them.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Allocation totals observed inside one [`scoped`] region.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScopeStats {
    /// Number of heap allocations (malloc + growing realloc).
    pub allocs: u64,
    /// Total bytes requested by those allocations.
    pub bytes: u64,
}

fn note(bytes: usize) {
    // try_with, not with: the global allocator can be re-entered during
    // TLS teardown, when `with` would panic.
    let _ = ACTIVE.try_with(|a| {
        if a.get() {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            let _ = BYTES.try_with(|c| c.set(c.get() + bytes as u64));
        }
    });
}

/// System-allocator wrapper that attributes allocations to the current
/// thread's open [`scoped`] region. Frees are not counted — the
/// zero-alloc property under test is "no allocation traffic in steady
/// state", and any steady-state free implies a matching allocation.
pub struct CountingAlloc;

// SAFETY: a pure pass-through to `System` — layout handling, alignment
// and the GlobalAlloc protocol are exactly the system allocator's; the
// only addition is thread-local bookkeeping that never allocates.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds the `GlobalAlloc::alloc` contract.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        // SAFETY: same layout forwarded unchanged to `System`.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: caller upholds the `GlobalAlloc::alloc_zeroed` contract.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        // SAFETY: same layout forwarded unchanged to `System`.
        unsafe { System.alloc_zeroed(layout) }
    }

    // SAFETY: caller upholds the `GlobalAlloc::realloc` contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A shrinking realloc releases memory; only growth is traffic.
        if new_size > layout.size() {
            note(new_size);
        }
        // SAFETY: ptr/layout/new_size forwarded unchanged to `System`.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    // SAFETY: caller upholds the `GlobalAlloc::dealloc` contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: ptr/layout forwarded unchanged to `System`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

/// Run `f` with allocation counting enabled on this thread; returns its
/// result plus the totals observed while it ran. Regions nest — an
/// inner region's traffic is included in the outer region's totals.
/// Without a registered [`CountingAlloc`] the totals are always zero.
pub fn scoped<T>(f: impl FnOnce() -> T) -> (T, ScopeStats) {
    let (a0, b0) = (ALLOCS.with(Cell::get), BYTES.with(Cell::get));
    let was = ACTIVE.with(|a| a.replace(true));
    let out = f();
    ACTIVE.with(|a| a.set(was));
    let stats = ScopeStats {
        allocs: ALLOCS.with(Cell::get) - a0,
        bytes: BYTES.with(Cell::get) - b0,
    };
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The lib test binary registers no global allocator, so scoped
    /// regions must pass values through and report zero traffic — the
    /// inert-in-production contract.
    #[test]
    fn inert_without_registered_allocator() {
        let (v, stats) = scoped(|| vec![1u8; 4096].len());
        assert_eq!(v, 4096);
        assert_eq!(stats, ScopeStats::default());
    }

    #[test]
    fn scoped_regions_nest_and_restore_the_flag() {
        let ((inner, s_inner), s_outer) = scoped(|| scoped(|| 7));
        assert_eq!(inner, 7);
        assert_eq!(s_inner, ScopeStats::default());
        assert_eq!(s_outer, ScopeStats::default());
        assert!(!ACTIVE.with(Cell::get), "flag must be restored");
    }
}
