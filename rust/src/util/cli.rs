//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line: subcommand-style positionals plus `--key` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option value; panics with a clear message on parse failure.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {s:?}")),
        }
    }

    /// Was `--flag` given (with no value)?
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// First positional argument (usually the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--port", "8080", "--model=resnet50", "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("model"), Some("resnet50"));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--threads", "8"]);
        assert_eq!(a.get_parsed::<usize>("threads", 1), 8);
        assert_eq!(a.get_parsed::<usize>("batch", 4), 4);
        assert_eq!(a.get_or("name", "x"), "x");
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--fast"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_typed_value_panics() {
        let a = parse(&["--threads", "eight"]);
        a.get_parsed::<usize>("threads", 1);
    }
}
