//! Sample statistics for the bench harness and the tuner.

/// Summary statistics over a sample of measurements (e.g. nanoseconds per
/// iteration). Percentiles use linear interpolation on the sorted sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p5: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary. Panics on an empty sample. NaN samples are
    /// filtered out rather than poisoning the sort (a single NaN used
    /// to panic the whole stats path through `partial_cmp().unwrap()`);
    /// if every sample is NaN the summary is [`Summary::empty`].
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
        if sorted.is_empty() {
            return Self::empty();
        }
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        sorted.sort_by(f64::total_cmp);
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p5: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// An explicitly empty summary: `n == 0` and every moment zero.
    /// What a server that served no requests reports — fabricating a
    /// `Summary::of(&[0.0])` sample would claim one request took 0 ns.
    pub fn empty() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            stddev: 0.0,
            min: 0.0,
            max: 0.0,
            median: 0.0,
            p5: 0.0,
            p95: 0.0,
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Interpolated percentile of an ascending-sorted slice, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Trim `frac` of the sample from each tail (by value), for outlier-robust
/// timing estimates. Returns at least one element. NaN-tolerant: the
/// total order sorts NaN to the tails, where trimming drops it first.
pub fn trimmed(samples: &[f64], frac: f64) -> Vec<f64> {
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let k = ((samples.len() as f64) * frac).floor() as usize;
    let end = sorted.len().saturating_sub(k).max(k + 1);
    sorted[k..end].to_vec()
}

/// Format a nanosecond quantity with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 5.0);
        assert_eq!((s.min, s.max), (5.0, 5.0));
    }

    #[test]
    fn empty_summary_is_zeroed_and_nan_free() {
        let s = Summary::empty();
        assert_eq!(s.n, 0);
        for v in [s.mean, s.stddev, s.min, s.max, s.median, s.p5, s.p95] {
            assert_eq!(v, 0.0);
        }
        assert_eq!(s.rsd(), 0.0);
    }

    #[test]
    fn summary_of_ramp() {
        let xs: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 9.0);
        assert!((s.stddev - 2.7386).abs() < 1e-3);
    }

    /// Regression (satellite): a NaN sample used to panic `Summary::of`
    /// via `partial_cmp().unwrap()` in the sort. NaNs are filtered; the
    /// remaining samples summarise as if the NaN never existed, and an
    /// all-NaN sample degrades to the explicit empty summary.
    #[test]
    fn nan_samples_are_filtered_not_panicking() {
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        let clean = Summary::of(&[1.0, 3.0]);
        assert_eq!(s.n, 2);
        assert_eq!(s, clean);
        for v in [s.mean, s.stddev, s.min, s.max, s.median, s.p5, s.p95] {
            assert!(v.is_finite(), "NaN leaked into the summary");
        }
        let all_nan = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(all_nan, Summary::empty());
        // `trimmed` shares the sort: NaN lands in the trimmed tail.
        let t = trimmed(&[1.0, 2.0, 3.0, 4.0, f64::NAN], 0.2);
        assert!(t.iter().all(|v| v.is_finite()));
    }

    /// Percentile edge cases (satellite): n = 1 returns the sample for
    /// every p, n = 2 interpolates linearly, and p5/p95 match
    /// hand-computed interpolation on a small sorted sample.
    #[test]
    fn percentile_edge_cases_hand_computed() {
        // n = 1: every percentile is the lone sample.
        let one = Summary::of(&[7.0]);
        assert_eq!((one.median, one.p5, one.p95), (7.0, 7.0, 7.0));
        assert_eq!(one.stddev, 0.0);
        // n = 2 over [10, 20]: rank = p/100 * 1.
        let two = Summary::of(&[20.0, 10.0]);
        assert_eq!(two.median, 15.0);
        assert!((two.p5 - 10.5).abs() < 1e-12, "p5 {}", two.p5);
        assert!((two.p95 - 19.5).abs() < 1e-12, "p95 {}", two.p95);
        // n = 4 over [1, 2, 3, 4]: p95 rank = 2.85 → 3·0.15 + 4·0.85.
        let four = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert!((four.p95 - 3.85).abs() < 1e-12, "p95 {}", four.p95);
        // p5 rank = 0.15 → 1·0.85 + 2·0.15.
        assert!((four.p5 - 1.15).abs() < 1e-12, "p5 {}", four.p5);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
        assert_eq!(percentile_sorted(&xs, 25.0), 2.5);
    }

    #[test]
    fn trimmed_drops_tails() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        let t = trimmed(&xs, 0.2);
        assert_eq!(t, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
