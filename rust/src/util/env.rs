//! Environment-variable parsing conventions shared by every
//! `NMPRUNE_*` switch.
//!
//! Before this module each call site rolled its own parse and they
//! disagreed: `NMPRUNE_TRACE=0` *enabled* tracing (the site tested
//! `is_ok()`), `NMPRUNE_BENCH_QUICK=0` *triggered* quick mode (any
//! non-empty value counted), while `NMPRUNE_PIN` and
//! `NMPRUNE_SERVE_TRACE` required exactly `"1"`. [`flag`] is the single
//! boolean convention now: unset, `""`, `"0"` and `"false"`
//! (case-insensitive) are **off**; any other value is **on**.
//!
//! Numeric switches follow the `NMPRUNE_KERNEL` fail-loud convention:
//! a value that is set but unparseable is a configuration typo, and
//! [`parse_usize`] panics with the offending value rather than
//! silently falling back ([`crate::util::threadpool::ThreadPool::default_size`]
//! used to `unwrap_or` its way past `NMPRUNE_THREADS=two`).

/// Boolean environment flag. Off when the variable is unset, empty,
/// `"0"`, or `"false"` (ASCII case-insensitive); on for any other
/// value. Every `NMPRUNE_*` on/off switch must go through this so
/// `FLAG=0` means the same thing everywhere.
pub fn flag(name: &str) -> bool {
    match std::env::var(name) {
        Ok(v) => !matches!(v.trim(), "" | "0") && !v.trim().eq_ignore_ascii_case("false"),
        Err(_) => false,
    }
}

/// Numeric environment switch, fail-loud: `None` when unset or empty
/// (empty means "off", consistent with [`flag`]); panics with a
/// descriptive message when the value is set but not a valid integer.
/// A typo'd `NMPRUNE_THREADS=sixteen` must stop the process, not
/// silently run on the hardware default.
pub fn parse_usize(name: &str) -> Option<usize> {
    let v = std::env::var(name).ok()?;
    let t = v.trim();
    if t.is_empty() {
        return None;
    }
    match t.parse::<usize>() {
        Ok(n) => Some(n),
        Err(_) => panic!("{name}={v:?} is not a valid non-negative integer"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Each test owns a unique variable name: env mutation is process
    // global and the test harness runs threads concurrently.

    #[test]
    fn flag_off_values() {
        let k = "NMPRUNE_TEST_FLAG_OFF";
        std::env::remove_var(k);
        assert!(!flag(k), "unset is off");
        for v in ["", "0", "false", "FALSE", "False", " 0 ", ""] {
            std::env::set_var(k, v);
            assert!(!flag(k), "{v:?} must be off");
        }
        std::env::remove_var(k);
    }

    #[test]
    fn flag_on_values() {
        let k = "NMPRUNE_TEST_FLAG_ON";
        for v in ["1", "true", "yes", "2", "on"] {
            std::env::set_var(k, v);
            assert!(flag(k), "{v:?} must be on");
        }
        std::env::remove_var(k);
    }

    #[test]
    fn parse_usize_accepts_numbers_and_treats_empty_as_unset() {
        let k = "NMPRUNE_TEST_USIZE_OK";
        std::env::remove_var(k);
        assert_eq!(parse_usize(k), None);
        std::env::set_var(k, "12");
        assert_eq!(parse_usize(k), Some(12));
        std::env::set_var(k, " 3 ");
        assert_eq!(parse_usize(k), Some(3));
        std::env::set_var(k, "");
        assert_eq!(parse_usize(k), None);
        std::env::remove_var(k);
    }

    #[test]
    #[should_panic(expected = "not a valid non-negative integer")]
    fn parse_usize_fails_loudly_on_garbage() {
        let k = "NMPRUNE_TEST_USIZE_BAD";
        std::env::set_var(k, "two");
        let _ = parse_usize(k);
    }
}
