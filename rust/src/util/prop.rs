//! Miniature property-testing driver (no proptest offline).
//!
//! `check` runs a property over `cases` randomly generated inputs and, on
//! failure, performs a simple halving shrink over the generator's size
//! parameter to report a smaller counterexample.

use super::rng::XorShiftRng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Upper bound for the size hint handed to the generator.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
            max_size: 64,
        }
    }
}

/// Case count for a property run: `NMPRUNE_PROP_CASES` when set to a
/// positive integer, else `default`. The extended-fuzz CI job uses this
/// to scale the same seeded suites to hundreds of cases without
/// touching the test code; garbage values fall back to `default`.
pub fn cases_from_env(default: usize) -> usize {
    std::env::var("NMPRUNE_PROP_CASES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Run `prop` against `cases` inputs drawn by `gen`. `gen` receives the
/// RNG and a size hint that ramps from 1 to `max_size` across cases, so
/// early cases are small. On failure the size is halved repeatedly to
/// look for a smaller failing input; panics with both the original and
/// the shrunk counterexample context.
pub fn check<T: std::fmt::Debug, G, P>(cfg: Config, mut gen: G, prop: P)
where
    G: FnMut(&mut XorShiftRng, usize) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = XorShiftRng::new(cfg.seed);
    for case in 0..cfg.cases {
        let size = 1 + case * cfg.max_size / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // Shrink: try smaller sizes with fresh draws.
            let mut shrunk: Option<T> = None;
            let mut s = size / 2;
            while s >= 1 {
                let mut found = false;
                for _ in 0..16 {
                    let cand = gen(&mut rng, s);
                    if !prop(&cand) {
                        shrunk = Some(cand);
                        found = true;
                        break;
                    }
                }
                if !found {
                    break;
                }
                s /= 2;
            }
            match shrunk {
                Some(small) => panic!(
                    "property failed at case {case} (size {size}).\n  original: {input:?}\n  shrunk:   {small:?}"
                ),
                None => panic!("property failed at case {case} (size {size}): {input:?}"),
            }
        }
    }
}

/// Shorthand with default config but explicit seed (each property should
/// use a distinct seed so failures are independent).
pub fn check_seeded<T: std::fmt::Debug, G, P>(seed: u64, gen: G, prop: P)
where
    G: FnMut(&mut XorShiftRng, usize) -> T,
    P: Fn(&T) -> bool,
{
    check(
        Config {
            seed,
            ..Config::default()
        },
        gen,
        prop,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            Config::default(),
            |r, size| {
                count += 1;
                r.uniform_vec(size, -1.0, 1.0)
            },
            |v| v.iter().all(|x| x.abs() <= 1.0),
        );
        assert_eq!(count, Config::default().cases);
    }

    /// The only test touching NMPRUNE_PROP_CASES (process env is
    /// shared, but lib tests run in a different process from the
    /// integration suites that read it for real).
    #[test]
    fn cases_from_env_overrides_and_rejects_garbage() {
        std::env::remove_var("NMPRUNE_PROP_CASES");
        assert_eq!(cases_from_env(64), 64);
        std::env::set_var("NMPRUNE_PROP_CASES", "512");
        assert_eq!(cases_from_env(64), 512);
        std::env::set_var("NMPRUNE_PROP_CASES", "0");
        assert_eq!(cases_from_env(64), 64, "zero cases would skip the suite");
        std::env::set_var("NMPRUNE_PROP_CASES", "lots");
        assert_eq!(cases_from_env(64), 64);
        std::env::remove_var("NMPRUNE_PROP_CASES");
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check_seeded(
            1,
            |r, size| r.uniform_vec(size.max(8), 0.0, 1.0),
            |v| v.len() < 4, // fails once size >= 4
        );
    }
}
