//! Minimal hand-rolled JSON (the offline crate set has no serde): a
//! dynamically-typed [`Json`] value with a recursive-descent parser and
//! a deterministic pretty writer — exactly enough for the versioned
//! bench-record schema in `benchlib::report`, kept in `util` so other
//! subsystems can reuse it the way they reuse the TSV plumbing.
//!
//! Deliberate scope cuts, documented rather than discovered:
//! * numbers are `f64` (like JavaScript itself); integers round-trip
//!   exactly up to 2^53;
//! * non-finite numbers serialise as `null` (JSON has no NaN/Inf) —
//!   the bench schema never produces them, but a writer must not emit
//!   invalid documents no matter what it is fed;
//! * object keys keep insertion order (a `Vec` of pairs, not a map), so
//!   emitted files are stable and diffable line-by-line in review.

use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string (escapes decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

/// Parse failure: byte offset into the input plus a short description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset where parsing failed.
    pub pos: usize,
    /// What was expected or found.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is a whole number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Render as pretty-printed JSON (2-space indent, stable key order,
    /// trailing newline) — the format the `BENCH_*.json` trajectory
    /// files are committed in, chosen to diff cleanly in review.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, s: &mut String, indent: usize) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(true) => s.push_str("true"),
            Json::Bool(false) => s.push_str("false"),
            Json::Num(x) => write_num(s, *x),
            Json::Str(v) => write_str(s, v),
            Json::Arr(items) => {
                if items.is_empty() {
                    s.push_str("[]");
                } else if items.iter().all(|i| i.is_scalar()) {
                    // Scalar arrays inline: `[1, 2, 3]`.
                    s.push('[');
                    for (i, item) in items.iter().enumerate() {
                        if i > 0 {
                            s.push_str(", ");
                        }
                        item.write(s, indent);
                    }
                    s.push(']');
                } else {
                    s.push_str("[\n");
                    for (i, item) in items.iter().enumerate() {
                        push_indent(s, indent + 1);
                        item.write(s, indent + 1);
                        if i + 1 < items.len() {
                            s.push(',');
                        }
                        s.push('\n');
                    }
                    push_indent(s, indent);
                    s.push(']');
                }
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    s.push_str("{}");
                } else {
                    s.push_str("{\n");
                    for (i, (k, v)) in pairs.iter().enumerate() {
                        push_indent(s, indent + 1);
                        write_str(s, k);
                        s.push_str(": ");
                        v.write(s, indent + 1);
                        if i + 1 < pairs.len() {
                            s.push(',');
                        }
                        s.push('\n');
                    }
                    push_indent(s, indent);
                    s.push('}');
                }
            }
        }
    }

    fn is_scalar(&self) -> bool {
        !matches!(self, Json::Arr(_) | Json::Obj(_))
    }
}

fn push_indent(s: &mut String, indent: usize) {
    for _ in 0..indent {
        s.push_str("  ");
    }
}

fn write_num(s: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Infinity; degrade to null rather than emit an
        // unparsable document.
        s.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        s.push_str(&format!("{}", x as i64));
    } else {
        // `{:?}` is the shortest representation that round-trips.
        s.push_str(&format!("{x:?}"));
    }
}

fn write_str(s: &mut String, v: &str) {
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
            c => s.push(c),
        }
    }
    s.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("expected a JSON value")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii number bytes");
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => {
                self.pos = start;
                Err(self.err(&format!("malformed number {text:?}")))
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            // Fast path: copy a run of plain (non-escape, non-quote)
            // bytes; str content is valid UTF-8 by construction.
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.b[start..self.pos]).expect("input str is UTF-8"),
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.escape(&mut out)?;
                }
                Some(_) => return Err(self.err("unescaped control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn escape(&mut self, out: &mut String) -> Result<(), JsonError> {
        let c = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
        self.pos += 1;
        match c {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{0008}'),
            b'f' => out.push('\u{000C}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair: expect `\uXXXX` low half.
                    self.expect(b'\\')?;
                    self.expect(b'u')?;
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(self.err("invalid low surrogate"));
                    }
                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                } else {
                    hi
                };
                match char::from_u32(code) {
                    Some(ch) => out.push(ch),
                    None => return Err(self.err("invalid unicode escape")),
                }
            }
            _ => return Err(self.err("unknown escape")),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a' + 10) as u32,
                b'A'..=b'F' => (c - b'A' + 10) as u32,
                _ => return Err(self.err("non-hex digit in \\u escape")),
            };
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#" { "a": [1, 2, {"b": null}], "c": {"d": "e"} } "#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Null)
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("e"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("quote \" slash \\ newline \n tab \t unicode µ".into());
        let rendered = original.render();
        assert_eq!(Json::parse(&rendered).unwrap(), original);
        // Escape sequences, including surrogate pairs, decode.
        let v = Json::parse(r#""µ 😀 \/""#).unwrap();
        assert_eq!(v.as_str(), Some("µ 😀 /"));
    }

    #[test]
    fn render_parse_roundtrip_preserves_structure() {
        let v = Json::Obj(vec![
            ("n".into(), Json::Num(3.25)),
            ("big".into(), Json::Num(1.0e18)),
            ("int".into(), Json::Num(1234567.0)),
            ("list".into(), Json::Arr(vec![Json::Num(1.0), Json::Bool(false)])),
            ("empty_obj".into(), Json::Obj(vec![])),
            ("empty_arr".into(), Json::Arr(vec![])),
        ]);
        let r = v.render();
        assert_eq!(Json::parse(&r).unwrap(), v);
        // Writer is deterministic: rendering twice is identical.
        assert_eq!(r, Json::parse(&r).unwrap().render());
    }

    #[test]
    fn non_finite_numbers_serialise_as_null() {
        assert_eq!(Json::Num(f64::NAN).render().trim(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render().trim(), "null");
    }

    #[test]
    fn integers_render_without_exponent() {
        assert_eq!(Json::Num(1e6).render().trim(), "1000000");
        assert_eq!(Json::Num(-3.0).render().trim(), "-3");
        assert_eq!(Json::Num(0.5).render().trim(), "0.5");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\" 1}",
            "tru",
            "\"unterminated",
            "1 2",
            "{\"a\": 1,}",
            "[1 2]",
            "nul",
            "--5",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn error_reports_position() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.pos, 4);
        assert!(e.to_string().contains("byte 4"));
    }

    #[test]
    fn as_usize_guards_fractions_and_negatives() {
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
        assert_eq!(Json::Num(7.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Str("7".into()).as_usize(), None);
    }
}
