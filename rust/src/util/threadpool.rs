//! Persistent fixed-size thread pool with a pool-backed scoped
//! parallel-for.
//!
//! XNNPACK parallelises GEMM over output tiles with a static chunking
//! scheme; we mirror that here. No rayon/tokio offline, so the pool is a
//! classic channel-of-boxed-closures design. The hot-path primitive is
//! [`ThreadPool::parallel_for`]: a scoped parallel-for that runs on the
//! pool's *persistent* workers — steady-state serving spawns zero
//! threads per GEMM call (the seed tree used `std::thread::scope` and
//! paid thread-creation syscalls on every conv layer).
//!
//! # Chunking policy (affinity-aware)
//!
//! `parallel_for` no longer walks one flat atomic cursor. The range
//! `0..n` is partitioned into one *contiguous home range per
//! participant*; each participant drains its own home range in
//! grain-sized chunks first (so a given worker touches a contiguous,
//! cache-friendly span of strips) and only then scans the other ranges,
//! round-robin from its own index, to steal leftover chunks from
//! stragglers. The grain is sized from the strip count — roughly
//! `n / (participants × 4)`, floor 1 — so a straggler's remaining home
//! range is still splittable.
//!
//! # Per-call parallelism caps
//!
//! [`ThreadPool::parallel_for_capped`] bounds how many participants one
//! call may occupy. A capped call enqueues only `cap − 1` worker jobs —
//! it wakes only the workers it needs — which is what makes per-layer
//! parallelism degrees (tuned by `tuner`) and several concurrent batch
//! executors on one shared pool cheap: a small conv capped at 2 leaves
//! the remaining workers free for the next layer or the next batch.
//! Caps larger than the pool (or than the iteration count) clamp; a cap
//! of 1 degenerates to a serial call on the calling thread with no
//! synchronisation at all, and `n == 0` returns before touching any
//! queue or barrier.
//!
//! Panic safety: a panicking job decrements the pending count through a
//! drop guard (so [`ThreadPool::wait`] can never hang) and is contained
//! with `catch_unwind` (so the worker survives); `parallel_for`
//! re-raises the panic on the calling thread once every outstanding
//! chunk has finished.
//!
//! # Core pinning
//!
//! The affinity-aware home ranges only pay off if a worker actually
//! stays on the core whose cache it warmed. [`ThreadPool::new_pinned`]
//! pins workers round-robin over the CPUs the process is *allowed* to
//! run on (`sched_getaffinity`, so cpuset-restricted containers pin to
//! real ids, not `0..n`) through `sched_setaffinity` (raw glibc FFI on
//! Linux — no crates offline; a graceful no-op on every other OS).
//! `NMPRUNE_PIN=1` makes
//! [`ThreadPool::global`] and [`ThreadPool::shared`] build pinned
//! pools. Pinning is pure placement: it never changes chunk arithmetic
//! or numerics, and a failed `sched_setaffinity` (restricted cgroup
//! mask, exotic libc) degrades silently to the unpinned behaviour —
//! [`ThreadPool::pinned_workers`] reports how many workers actually
//! landed.

use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// OS-level thread→core pinning. Linux-only: `sched_setaffinity` is
/// declared directly against the system libc (the offline environment
/// vendors no `libc` crate); with `pid == 0` glibc applies the mask to
/// the calling thread. Everywhere else this is a no-op returning
/// `false` — pinning must degrade, never fail.
pub mod affinity {
    /// A fixed 1024-bit cpu_set_t, matching glibc's default width.
    #[cfg(target_os = "linux")]
    const WORDS: usize = 1024 / 64;

    /// Pin the calling thread to `core` (a kernel CPU id, modulo the
    /// CPU-set width). Returns whether the kernel accepted the mask.
    #[cfg(target_os = "linux")]
    pub fn pin_current_thread(core: usize) -> bool {
        extern "C" {
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        }
        let mut mask = [0u64; WORDS];
        let bit = core % (WORDS * 64);
        mask[bit / 64] |= 1u64 << (bit % 64);
        // SAFETY: the mask outlives the call and cpusetsize (WORDS*8
        // bytes) matches its allocation exactly; pid 0 targets only the
        // calling thread, so no other thread's state is touched.
        unsafe { sched_setaffinity(0, WORDS * 8, mask.as_ptr()) == 0 }
    }

    #[cfg(not(target_os = "linux"))]
    pub fn pin_current_thread(_core: usize) -> bool {
        false
    }

    /// The CPU ids this process may run on, from `sched_getaffinity`.
    /// Under a cpuset/affinity restriction (container pinned to CPUs
    /// {4..7}, taskset, k8s cpuset cgroup) these are *not* simply
    /// `0..available_parallelism()` — pinning must target ids from this
    /// set or the kernel rejects the mask with EINVAL. Falls back to
    /// `0..available_parallelism()` if the syscall fails.
    #[cfg(target_os = "linux")]
    pub fn allowed_cpus() -> Vec<usize> {
        extern "C" {
            fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
        }
        let mut mask = [0u64; WORDS];
        let mut cpus = Vec::new();
        // SAFETY: the kernel writes at most cpusetsize (WORDS*8) bytes
        // into `mask`, which is exactly the buffer's size; pid 0 reads
        // the calling thread's own mask.
        if unsafe { sched_getaffinity(0, WORDS * 8, mask.as_mut_ptr()) == 0 } {
            for (w, &bits) in mask.iter().enumerate() {
                for b in 0..64 {
                    if bits & (1u64 << b) != 0 {
                        cpus.push(w * 64 + b);
                    }
                }
            }
        }
        if cpus.is_empty() {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            cpus.extend(0..n);
        }
        cpus
    }

    /// Off Linux there is nothing to enumerate: pinning is a no-op.
    #[cfg(not(target_os = "linux"))]
    pub fn allowed_cpus() -> Vec<usize> {
        Vec::new()
    }

    /// Is pinning requested via the environment (`NMPRUNE_PIN`)?
    /// Parsed by [`crate::util::env::flag`]: `""`/`"0"`/`"false"` are
    /// off, anything else is on.
    pub fn env_pin() -> bool {
        crate::util::env::flag("NMPRUNE_PIN")
    }
}

/// Pending-job bookkeeping. The hot path touches only the atomic: the
/// mutex/condvar pair exists solely so `wait()` can park, and is locked
/// by a decrementer only at the zero-crossing (quiescence) — keeping
/// per-job dispatch free of cross-core lock traffic.
struct Pending {
    count: AtomicUsize,
    lock: Mutex<()>,
    cvar: Condvar,
}

/// Decrements the pool's pending-job count when dropped — including
/// during unwinding — so a panicking job cannot strand `wait()`.
struct PendingGuard<'a>(&'a Pending);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        if self.0.count.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Take the lock before notifying so a waiter between its
            // count check and its `wait()` cannot miss the wake-up.
            drop(self.0.lock.lock().unwrap());
            self.0.cvar.notify_all();
        }
    }
}

/// Fixed-size worker pool. Jobs are `FnOnce() + Send`. Dropping the pool
/// joins all workers after draining the queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<Pending>,
    size: usize,
    /// Workers that successfully pinned themselves to a core (0 on
    /// unpinned pools and on OSes without affinity support).
    pinned: Arc<AtomicUsize>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("size", &self.size).finish()
    }
}

static GLOBAL_POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
static SIZED_POOLS: OnceLock<Mutex<HashMap<usize, Arc<ThreadPool>>>> = OnceLock::new();

impl ThreadPool {
    /// Create a pool of `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        Self::with_pinning(size, false)
    }

    /// Create a pool whose workers are pinned round-robin over the
    /// process's allowed CPU set (worker `i` → `allowed[i mod count]`,
    /// from `sched_getaffinity`). On non-Linux targets (or when the
    /// kernel rejects the mask) the pool behaves exactly like
    /// [`ThreadPool::new`] — pinning is best-effort placement, never a
    /// construction failure.
    pub fn new_pinned(size: usize) -> Self {
        Self::with_pinning(size, true)
    }

    fn with_pinning(size: usize, pin: bool) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(Pending {
            count: AtomicUsize::new(0),
            lock: Mutex::new(()),
            cvar: Condvar::new(),
        });
        let pinned = Arc::new(AtomicUsize::new(0));
        // Round-robin over the CPUs this process is actually allowed to
        // run on (cpuset-aware) — pinning to `0..ncpu` would EINVAL in
        // any container restricted to a CPU set not starting at 0.
        let cpus = if pin { affinity::allowed_cpus() } else { Vec::new() };
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let pinned = Arc::clone(&pinned);
                let cpu = if cpus.is_empty() { None } else { Some(cpus[i % cpus.len()]) };
                std::thread::spawn(move || {
                    if cpu.is_some_and(affinity::pin_current_thread) {
                        pinned.fetch_add(1, Ordering::SeqCst);
                    }
                    loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // Guard first: even if the job panics, the
                                // pending count is decremented on unwind.
                                let _pending = PendingGuard(&pending);
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break,
                        }
                    }
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            pending,
            size,
            pinned,
        }
    }

    /// The default worker count for process-wide pools: `NMPRUNE_THREADS`
    /// if set (≥ 1), else one worker per available hardware thread. The
    /// single sizing rule shared by [`ThreadPool::global`] and every
    /// CLI path that builds its own pool — placement flags like `--pin`
    /// must never change the count, only where workers land.
    ///
    /// Fail-loud (the `NMPRUNE_KERNEL` convention): a value that is set
    /// but not a positive integer panics with the offending value; it
    /// used to be silently ignored, so `NMPRUNE_THREADS=sixteen` ran on
    /// the hardware default without a word.
    pub fn default_size() -> usize {
        match crate::util::env::parse_usize("NMPRUNE_THREADS") {
            Some(0) => panic!("NMPRUNE_THREADS=0: worker count must be >= 1"),
            Some(n) => n,
            None => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        }
    }

    /// The process-wide default pool: sized by [`ThreadPool::default_size`];
    /// core-pinned when `NMPRUNE_PIN=1`. Created on first use and reused
    /// by every caller for the lifetime of the process — the "one pool
    /// serves the whole process" handle.
    pub fn global() -> Arc<ThreadPool> {
        Arc::clone(GLOBAL_POOL.get_or_init(|| {
            Arc::new(ThreadPool::with_pinning(Self::default_size(), affinity::env_pin()))
        }))
    }

    /// A process-shared pool of exactly `size` workers, memoised per
    /// size (core-pinned when `NMPRUNE_PIN=1` — the env is read at
    /// first construction of each size, consistent with it being a
    /// process-constant deployment switch). Tests and benches that
    /// sweep thread counts go through this so repeated configuration
    /// never re-spawns workers.
    pub fn shared(size: usize) -> Arc<ThreadPool> {
        let pools = SIZED_POOLS.get_or_init(|| Mutex::new(HashMap::new()));
        let mut pools = pools.lock().unwrap();
        Arc::clone(
            pools
                .entry(size.max(1))
                .or_insert_with(|| Arc::new(ThreadPool::with_pinning(size, affinity::env_pin()))),
        )
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// How many workers successfully pinned themselves to a core. 0 on
    /// unpinned pools and wherever affinity is unsupported; may lag the
    /// constructor briefly (workers pin from inside their own thread).
    pub fn pinned_workers(&self) -> usize {
        self.pinned.load(Ordering::SeqCst)
    }

    /// Submit a job (fire and forget; use [`ThreadPool::wait`] to sync).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.pending.count.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished (or panicked — the
    /// drop guard in the worker loop decrements `pending` either way).
    pub fn wait(&self) {
        let mut guard = self.pending.lock.lock().unwrap();
        while self.pending.count.load(Ordering::SeqCst) > 0 {
            guard = self.pending.cvar.wait(guard).unwrap();
        }
        drop(guard);
    }

    /// Scoped parallel-for over `0..n` on the pool's persistent workers
    /// with affinity-aware chunking (see the module docs): each
    /// participant owns a contiguous home range and steals leftover
    /// chunks from stragglers. `f(start, end)` handles `[start, end)`
    /// and may borrow from the caller's stack; it must be safe to call
    /// concurrently on disjoint ranges.
    ///
    /// The calling thread participates in the loop, so the range always
    /// completes even when every worker is busy with other tasks, and a
    /// pool of size 1 degenerates to a plain serial call with no
    /// synchronisation. Blocks until all chunks are done; a panic in any
    /// chunk is re-raised here after the barrier.
    ///
    /// Must be called from *outside* the pool: invoking it from within a
    /// job running on this same pool can deadlock the completion barrier
    /// (all workers parked waiting on jobs only they could run). Kernel
    /// bodies passed to `parallel_for` must therefore never re-enter the
    /// pool — none in this crate do. (`n == 0` is exempt: it returns
    /// before touching the queue or barrier, so it is safe anywhere.)
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        self.parallel_for_capped(n, None, f);
    }

    /// [`ThreadPool::parallel_for`] with an optional per-call cap on the
    /// number of participants (calling thread included). `Some(k)`
    /// occupies at most `min(k, pool size, n)` participants and enqueues
    /// only that many − 1 worker jobs; `None` (or any cap ≥ pool size)
    /// is the uncapped pool-wide dispatch. `Some(0)` clamps to 1. The
    /// chunk arithmetic is identical across caps, so results of
    /// disjoint-range kernels are bit-for-bit equal to the serial call.
    pub fn parallel_for_capped<F>(&self, n: usize, max_workers: Option<usize>, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            // Early return: no queue traffic, no barrier fence (a capped
            // zero-length loop must never wake a worker).
            return;
        }
        let cap = max_workers.unwrap_or(self.size).max(1);
        let workers = self.size.min(cap).min(n);
        if workers <= 1 {
            f(0, n);
            return;
        }
        // One contiguous home range per participant; grain sized from
        // the strip count so each range splits into ~4 stealable chunks.
        let grain = (n / (workers * 4)).max(1);
        let per = n.div_ceil(workers);
        let ranges: Vec<RangeCursor> = (0..workers)
            .map(|i| RangeCursor {
                cursor: AtomicUsize::new(i * per),
                end: ((i + 1) * per).min(n),
            })
            .collect();
        let state = Arc::new(ForState {
            ranges,
            grain,
            outstanding: Mutex::new(workers - 1),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let f_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        // SAFETY: lifetime erasure only — the pointee type is unchanged.
        // Pool jobs require 'static, but `f` borrows the caller's
        // stack. Sound because this function blocks (the `wait_workers`
        // barrier below) until every submitted job has finished
        // touching `f` and `state`, and panics on either side are
        // contained until after that barrier, so the erased reference
        // never outlives the borrow.
        let f_static: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        for home in 1..workers {
            let st = Arc::clone(&state);
            self.execute(move || st.run_chunks(home, f_static));
        }
        let caller = catch_unwind(AssertUnwindSafe(|| drain_chunks(&state, 0, f_ref)));
        state.wait_workers();
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if state.panicked.load(Ordering::Relaxed) {
            panic!("ThreadPool::parallel_for: a worker chunk panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One participant's contiguous home range `[cursor, end)`. The cursor
/// is shared: the owner claims grain-sized chunks from the front, and
/// thieves claim through the same `fetch_add`, so a chunk is handed out
/// exactly once no matter who takes it. Overshoot past `end` is benign
/// (claims land beyond the range and are discarded).
struct RangeCursor {
    cursor: AtomicUsize,
    end: usize,
}

/// Shared state of one `parallel_for` invocation.
struct ForState {
    /// One home range per participant (caller = index 0).
    ranges: Vec<RangeCursor>,
    grain: usize,
    /// Pool jobs still holding a reference into the caller's stack.
    outstanding: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl ForState {
    /// Worker-side entry: drain chunks, record panics, then release the
    /// caller. The decrement must be last — it is the caller's licence
    /// to return (and invalidate the borrowed closure).
    fn run_chunks(&self, home: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if catch_unwind(AssertUnwindSafe(|| drain_chunks(self, home, f))).is_err() {
            self.panicked.store(true, Ordering::Relaxed);
        }
        let mut left = self.outstanding.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait_workers(&self) {
        let mut left = self.outstanding.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Drain the home range `ranges[home]` first, then sweep the other
/// ranges round-robin (stealing from stragglers) until every range is
/// exhausted.
fn drain_chunks(st: &ForState, home: usize, f: &(dyn Fn(usize, usize) + Sync)) {
    let r = st.ranges.len();
    for visit in 0..r {
        let range = &st.ranges[(home + visit) % r];
        loop {
            let start = range.cursor.fetch_add(st.grain, Ordering::Relaxed);
            if start >= range.end {
                break;
            }
            f(start, (start + st.grain).min(range.end));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait();
    }

    /// Regression: a panicking job used to leave `pending` incremented
    /// forever, deadlocking `wait()`. The drop guard decrements on
    /// unwind and `catch_unwind` keeps the worker alive.
    #[test]
    fn wait_returns_after_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.execute(|| panic!("job panic (expected in this test)"));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait(); // must not hang
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        // The pool stays fully usable afterwards.
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn parallel_for_covers_range_exactly_once() {
        let pool = ThreadPool::new(8);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_zero_and_one() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(0, |_, _| panic!("must not be called"));
        let hit = AtomicU64::new(0);
        pool.parallel_for(1, |s, e| {
            assert_eq!((s, e), (0, 1));
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_for_borrows_stack() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..512).collect();
        let sum = AtomicU64::new(0);
        pool.parallel_for(data.len(), |s, e| {
            let part: u64 = data[s..e].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 512 * 511 / 2);
    }

    #[test]
    fn parallel_for_reuses_workers_across_many_calls() {
        // The serving pattern: many GEMM-sized parallel-fors against one
        // pool. Every call must complete with full coverage.
        let pool = ThreadPool::new(4);
        for round in 0..100u64 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(64, |s, e| {
                sum.fetch_add((e - s) as u64, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), 64, "round {round}");
        }
    }

    #[test]
    #[should_panic(expected = "chunk panicked")]
    fn parallel_for_propagates_worker_panic() {
        let pool = ThreadPool::new(4);
        // Every chunk panics, so whichever side (caller-resumed payload
        // or the worker-flag message) surfaces, the shared "chunk
        // panicked" suffix matches.
        pool.parallel_for(1000, |_s, _e| panic!("injected chunk panicked"));
    }

    #[test]
    fn pool_usable_after_parallel_for_panic() {
        let pool = ThreadPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(100, |_, _| panic!("boom (expected in this test)"));
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // Workers survived; the next parallel-for runs normally.
        let sum = AtomicU64::new(0);
        pool.parallel_for(256, |s, e| {
            sum.fetch_add((e - s) as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 256);
    }

    /// Regression (satellite): `parallel_for` with `n == 0` must return
    /// before touching the job queue or the completion barrier. Run it
    /// from *inside* the only worker of a size-1 pool — if the empty
    /// loop enqueued jobs or fenced through the barrier, nobody could
    /// run them and this test would deadlock.
    #[test]
    fn parallel_for_empty_range_skips_barrier_even_inside_pool() {
        let pool = Arc::new(ThreadPool::new(1));
        let hit = Arc::new(AtomicU64::new(0));
        let (p2, h2) = (Arc::clone(&pool), Arc::clone(&hit));
        pool.execute(move || {
            p2.parallel_for(0, |_, _| panic!("must not be called"));
            p2.parallel_for_capped(0, Some(3), |_, _| panic!("must not be called"));
            h2.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait(); // deadlocks here if n == 0 reaches the barrier path
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn capped_parallel_for_covers_range_exactly_once() {
        let pool = ThreadPool::new(8);
        // Caps below, at, and above the pool size; n above and below cap.
        for cap in [1usize, 2, 3, 8, 9, 100] {
            for n in [1usize, 2, 7, 500] {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                pool.parallel_for_capped(n, Some(cap), |s, e| {
                    for h in &hits[s..e] {
                        h.fetch_add(1, Ordering::SeqCst);
                    }
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                    "cap={cap} n={n}"
                );
            }
        }
    }

    #[test]
    fn capped_parallel_for_bounds_concurrency() {
        let pool = ThreadPool::new(8);
        for cap in [1usize, 2, 4] {
            let current = AtomicU64::new(0);
            let peak = AtomicU64::new(0);
            pool.parallel_for_capped(256, Some(cap), |_s, _e| {
                let now = current.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                // Hold the slot long enough for overlap to be observable.
                std::thread::sleep(std::time::Duration::from_micros(200));
                current.fetch_sub(1, Ordering::SeqCst);
            });
            assert!(
                peak.load(Ordering::SeqCst) <= cap as u64,
                "cap={cap} exceeded: peak {}",
                peak.load(Ordering::SeqCst)
            );
        }
    }

    #[test]
    fn cap_of_zero_and_uncapped_both_complete() {
        let pool = ThreadPool::new(4);
        for cap in [Some(0), None] {
            let sum = AtomicU64::new(0);
            pool.parallel_for_capped(100, cap, |s, e| {
                sum.fetch_add((e - s) as u64, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), 100, "cap={cap:?}");
        }
    }

    #[test]
    fn shared_pools_are_memoised_per_size() {
        let a = ThreadPool::shared(3);
        let b = ThreadPool::shared(3);
        assert!(Arc::ptr_eq(&a, &b), "same size must reuse one pool");
        assert_eq!(a.size(), 3);
        let c = ThreadPool::shared(5);
        assert!(!Arc::ptr_eq(&a, &c));
        // Size 0 clamps to 1 and shares the size-1 pool.
        assert_eq!(ThreadPool::shared(0).size(), 1);
        assert!(Arc::ptr_eq(&ThreadPool::shared(0), &ThreadPool::shared(1)));
    }

    /// Pinning is placement only: a pinned pool runs the same jobs to
    /// the same results, and on non-Linux targets `new_pinned` is a
    /// silent no-op (`pinned_workers() == 0`), never a failure.
    #[test]
    fn pinned_pool_executes_like_unpinned() {
        let pinned = ThreadPool::new_pinned(3);
        let plain = ThreadPool::new(3);
        for pool in [&pinned, &plain] {
            let sum = AtomicU64::new(0);
            pool.parallel_for(777, |s, e| {
                sum.fetch_add((e - s) as u64, Ordering::SeqCst);
            });
            assert_eq!(sum.load(Ordering::SeqCst), 777);
        }
        // parallel_for's completion barrier means worker jobs ran, and
        // workers attempt their pin before entering the job loop — so on
        // Linux at least one worker has pinned by now (pins target the
        // process's own allowed CPU set, so they succeed). One worker
        // can drain several jobs, hence ≥ 1, not = 3.
        if cfg!(target_os = "linux") {
            let p = pinned.pinned_workers();
            assert!(
                (1..=3).contains(&p),
                "pool must actually pin its workers on Linux (got {p})"
            );
        } else {
            assert_eq!(pinned.pinned_workers(), 0, "no-op off Linux");
        }
        assert_eq!(plain.pinned_workers(), 0);
    }

    /// On Linux the syscall path itself must work: a CPU taken from the
    /// process's own allowed set (cpuset-aware — plain core 0 may be
    /// outside the mask in restricted containers) is always legal to
    /// pin to. Test threads are per-test, so the pin dies with it.
    #[cfg(target_os = "linux")]
    #[test]
    fn pin_current_thread_to_an_allowed_core_succeeds() {
        let cpus = affinity::allowed_cpus();
        assert!(!cpus.is_empty(), "allowed set never empty (fallback)");
        assert!(
            affinity::pin_current_thread(cpus[0]),
            "pinning to a CPU from our own affinity mask must succeed"
        );
    }

    /// Satellite (fail-loud env): `NMPRUNE_THREADS` with a valid count
    /// is honoured; a non-numeric or zero value panics instead of being
    /// silently ignored. The variable is restored before asserting so
    /// the garbage window stays as short as possible.
    #[test]
    fn default_size_honours_and_validates_nmprune_threads() {
        let saved = std::env::var("NMPRUNE_THREADS").ok();
        std::env::set_var("NMPRUNE_THREADS", "3");
        let ok = ThreadPool::default_size();
        let garbage = catch_unwind(|| {
            std::env::set_var("NMPRUNE_THREADS", "sixteen");
            ThreadPool::default_size()
        });
        let zero = catch_unwind(|| {
            std::env::set_var("NMPRUNE_THREADS", "0");
            ThreadPool::default_size()
        });
        match saved {
            Some(v) => std::env::set_var("NMPRUNE_THREADS", v),
            None => std::env::remove_var("NMPRUNE_THREADS"),
        }
        assert_eq!(ok, 3);
        assert!(garbage.is_err(), "non-numeric NMPRUNE_THREADS must panic");
        assert!(zero.is_err(), "NMPRUNE_THREADS=0 must panic");
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = ThreadPool::global();
        let b = ThreadPool::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.size() >= 1);
    }
}
