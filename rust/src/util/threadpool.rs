//! Minimal fixed-size thread pool with scoped parallel-for.
//!
//! XNNPACK parallelises GEMM over output tiles with a static chunking
//! scheme; we mirror that here. No rayon/tokio offline, so the pool is a
//! classic channel-of-boxed-closures design plus a `scope_chunks` helper
//! that parallelises index ranges without requiring 'static captures.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are `FnOnce() + Send`. Dropping the pool
/// joins all workers after draining the queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool of `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..size)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            let (lock, cvar) = &*pending;
                            let mut p = lock.lock().unwrap();
                            *p -= 1;
                            if *p == 0 {
                                cvar.notify_all();
                            }
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            pending,
            size,
        }
    }

    /// Pool with one worker per available hardware thread.
    pub fn with_default_size() -> Self {
        Self::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        )
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job (fire and forget; use [`ThreadPool::wait`] to sync).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }

    /// Parallel-for over `0..n` in contiguous chunks, using scoped threads
    /// so `f` may borrow from the caller. `f(start, end)` handles
    /// `[start, end)`. Uses its own scoped threads (not pool workers) so a
    /// stack-borrowing body is safe; the pool's size sets the parallelism.
    pub fn scope_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        scope_chunks(self.size, n, f)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Free-standing parallel-for over `0..n` split into `threads` contiguous
/// chunks, with dynamic work stealing on a shared atomic cursor at `grain`
/// granularity. `f(start, end)` must be safe to call concurrently on
/// disjoint ranges.
pub fn scope_chunks<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        f(0, n);
        return;
    }
    // Grain: aim for ~4 chunks per thread so stragglers rebalance.
    let grain = (n / (threads * 4)).max(1);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = cursor.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                f(start, end);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait();
    }

    #[test]
    fn scope_chunks_covers_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        scope_chunks(8, 1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_chunks_zero_and_one() {
        scope_chunks(4, 0, |_, _| panic!("must not be called"));
        let hit = AtomicU64::new(0);
        scope_chunks(4, 1, |s, e| {
            assert_eq!((s, e), (0, 1));
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn pool_scope_chunks_borrows_stack() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..512).collect();
        let sum = AtomicU64::new(0);
        pool.scope_chunks(data.len(), |s, e| {
            let part: u64 = data[s..e].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 512 * 511 / 2);
    }
}
