//! Versioned binary packed-weight artifacts (`nmprune pack`).
//!
//! An artifact freezes everything the executor otherwise derives at
//! load time: the pruned column-wise N:M conv weights (compressed form,
//! verbatim), dense filter matrices for unpruned layers, the tuner's
//! per-layer micro-kernel choices, and the shape/seed metadata needed
//! to validate that the artifact matches the graph it is loaded into.
//! Loading becomes a validation pass — no re-pruning, no re-packing —
//! so logits from an AOT-packed artifact are bitwise identical to the
//! online-packed path (`rust/tests/zero_alloc.rs` proves it).
//!
//! Layout (little-endian throughout):
//!
//! ```text
//! magic "NMPK" | version u32 | arch str | batch u32 | res u32
//! path u8 | sparsity f64-bits u64 | seed u64 | default choice 5×u32
//! n_layers u32
//! per layer:
//!   name str | kind u8 (0 dense, 1 sparse) | choice 5×u32
//!   conv shape 9×u32 | payload_len u64
//!   zero padding to a 64-byte-aligned payload offset | payload
//! fnv1a-64 checksum u64 over all preceding bytes
//! ```
//!
//! Choices are `v, tile, threads, kernel, dtype` (the kernel backend
//! code [`KernelId::code`] and the compute-dtype code
//! [`Dtype::code`]). Legacy artifacts still load: version 1 — written
//! before the kernel dimension existed — carries 3×u32 choices
//! (`kernel = auto`, `dtype = f32`); version 2 — written before the
//! dtype dimension existed — carries 4×u32 choices (`dtype = f32`).
//! Weights are always stored as f32 masters, dtype included: i8 layers
//! re-quantize deterministically on load, so logits stay bitwise across
//! the roundtrip without freezing a second weight payload format.
//!
//! Strings are `u32` length + UTF-8 bytes. Dense payloads are the
//! `[C_out, K]` filter matrix as raw f32; sparse payloads are
//! [`ColwisePruned::encode_into`] bytes. Payload 64-byte alignment lets
//! a future mmap-based loader hand vector kernels aligned weight
//! pointers without copying.
//!
//! Every decode failure — truncation, bad magic/version, checksum
//! mismatch, misaligned or short payloads, invalid shapes — returns a
//! [`RuntimeError`](super::RuntimeError); the loader never panics on
//! file bytes and none of its validation is `debug_assert`-only.

use std::path::Path;

use super::{err, Result};
use crate::conv::{ConvPath, ConvShape};
use crate::engine::LayerChoice;
use crate::gemm::KernelId;
use crate::pruning::ColwisePruned;
use crate::tensor::Dtype;

/// File magic: "NMPK" (N:M packed weights).
pub const MAGIC: [u8; 4] = *b"NMPK";
/// Current schema version (3: 5-field choices with a dtype code).
pub const VERSION: u32 = 3;
/// Oldest schema version this build still reads.
pub const MIN_VERSION: u32 = 1;
/// Payload alignment in bytes.
pub const PAYLOAD_ALIGN: usize = 64;

/// Weights of one conv layer, in execution-ready form.
#[derive(Clone, Debug)]
pub enum LayerWeights {
    /// Unpruned `[C_out, K]` filter matrix (row-major).
    Dense(Vec<f32>),
    /// Column-wise N:M compressed weights, stored verbatim.
    Sparse(ColwisePruned),
}

/// One conv layer of a packed artifact.
#[derive(Clone, Debug)]
pub struct ArtifactLayer {
    /// Layer name (must match the graph node name on load).
    pub name: String,
    /// Tuned micro-kernel parameters for this layer.
    pub choice: LayerChoice,
    /// Conv geometry (validated against the graph on load).
    pub shape: ConvShape,
    pub weights: LayerWeights,
}

/// A packed-weight artifact: per-layer conv weights + tuner choices +
/// enough metadata to validate compatibility with a graph at load time.
#[derive(Clone, Debug)]
pub struct PackedArtifact {
    /// Architecture name (e.g. "resnet18").
    pub arch: String,
    /// Batch size the graph was built for.
    pub batch: usize,
    /// Input resolution the graph was built for.
    pub res: usize,
    /// Execution path the weights were prepared for.
    pub path: ConvPath,
    /// Column-wise adaptive sparsity ratio (SparseCnhw path).
    pub sparsity: f64,
    /// Weight-generation seed (regenerates depthwise/FC params, which
    /// the artifact deliberately omits — they are seed-derived and
    /// path-independent).
    pub seed: u64,
    /// Fallback micro-kernel parameters.
    pub default_choice: LayerChoice,
    /// Conv layers in graph (topological) order.
    pub layers: Vec<ArtifactLayer>,
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn path_code(p: ConvPath) -> u8 {
    match p {
        ConvPath::DenseNhwc => 0,
        ConvPath::DenseCnhw => 1,
        ConvPath::SparseCnhw => 2,
    }
}

fn path_from_code(b: u8) -> Result<ConvPath> {
    match b {
        0 => Ok(ConvPath::DenseNhwc),
        1 => Ok(ConvPath::DenseCnhw),
        2 => Ok(ConvPath::SparseCnhw),
        _ => Err(err(format!("artifact: unknown path code {b}"))),
    }
}

fn w32(out: &mut Vec<u8>, v: usize) {
    out.extend_from_slice(&(v as u32).to_le_bytes());
}

fn w64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn wstr(out: &mut Vec<u8>, s: &str) {
    w32(out, s.len());
    out.extend_from_slice(s.as_bytes());
}

fn wchoice(out: &mut Vec<u8>, c: LayerChoice) {
    w32(out, c.v);
    w32(out, c.tile);
    w32(out, c.threads);
    w32(out, c.kernel.code() as usize);
    w32(out, c.dtype.code() as usize);
}

/// Bounds-checked read cursor: every read that would run past the end
/// of the buffer is a hard [`RuntimeError`], never a panic.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                err(format!("artifact truncated at byte {} reading {what}", self.pos))
            })?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<usize> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes(b.try_into().unwrap()) as usize)
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)?;
        let b = self.take(len, what)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| err(format!("artifact: {what} is not valid UTF-8")))
    }

    /// Version-aware choice read: v1 carried 3×u32 (no kernel field →
    /// Auto); v2 carries 4×u32 with a validated kernel code; v3 adds a
    /// fifth u32 with a validated dtype code (older versions → f32).
    fn choice(&mut self, version: usize, what: &str) -> Result<LayerChoice> {
        let v = self.u32(what)?;
        let tile = self.u32(what)?;
        let threads = self.u32(what)?;
        let kernel = if version >= 2 {
            let code = self.u32(what)?;
            KernelId::from_code(code as u32)
                .ok_or_else(|| err(format!("artifact: {what} has unknown kernel code {code}")))?
        } else {
            KernelId::Auto
        };
        let dtype = if version >= 3 {
            let code = self.u32(what)?;
            Dtype::from_code(code as u32)
                .ok_or_else(|| err(format!("artifact: {what} has unknown dtype code {code}")))?
        } else {
            Dtype::F32
        };
        Ok(LayerChoice {
            v,
            tile,
            threads,
            kernel,
            dtype,
        })
    }
}

/// Reconstruct and sanity-check a conv shape from file bytes. Zero
/// dims, zero stride, or kernels exceeding the padded input (which
/// would underflow `h_out()`) are all load errors, not panics.
fn validated_shape(cur: &mut Cur<'_>, layer: &str) -> Result<ConvShape> {
    let mut f = [0usize; 9];
    for v in &mut f {
        *v = cur.u32("conv shape")?;
    }
    let [n, c_in, h_in, w_in, c_out, kh, kw, stride, pad] = f;
    if [n, c_in, h_in, w_in, c_out, kh, kw, stride].contains(&0) {
        return Err(err(format!("artifact: layer {layer:?} has a zero conv dimension")));
    }
    if h_in + 2 * pad < kh || w_in + 2 * pad < kw {
        return Err(err(format!(
            "artifact: layer {layer:?} kernel {kh}x{kw} exceeds padded input \
             {h_in}x{w_in}+{pad}"
        )));
    }
    Ok(ConvShape {
        n,
        c_in,
        h_in,
        w_in,
        c_out,
        kh,
        kw,
        stride,
        pad,
    })
}

impl PackedArtifact {
    /// Serialize to the versioned binary format (checksum included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        w32(&mut out, VERSION as usize);
        wstr(&mut out, &self.arch);
        w32(&mut out, self.batch);
        w32(&mut out, self.res);
        out.push(path_code(self.path));
        w64(&mut out, self.sparsity.to_bits());
        w64(&mut out, self.seed);
        wchoice(&mut out, self.default_choice);
        w32(&mut out, self.layers.len());
        let mut payload = Vec::new();
        for layer in &self.layers {
            wstr(&mut out, &layer.name);
            payload.clear();
            let kind = match &layer.weights {
                LayerWeights::Dense(f) => {
                    for v in f {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                    0u8
                }
                LayerWeights::Sparse(p) => {
                    p.encode_into(&mut payload);
                    1u8
                }
            };
            out.push(kind);
            wchoice(&mut out, layer.choice);
            let s = &layer.shape;
            for v in [s.n, s.c_in, s.h_in, s.w_in, s.c_out, s.kh, s.kw, s.stride, s.pad] {
                w32(&mut out, v);
            }
            w64(&mut out, payload.len() as u64);
            while out.len() % PAYLOAD_ALIGN != 0 {
                out.push(0);
            }
            out.extend_from_slice(&payload);
        }
        let sum = fnv1a64(&out);
        w64(&mut out, sum);
        out
    }

    /// Parse and fully validate an encoded artifact. Checksum first
    /// (whole-file integrity), then structure: any corruption yields a
    /// descriptive [`RuntimeError`](super::RuntimeError).
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 {
            return Err(err(format!("artifact truncated: {} bytes", bytes.len())));
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        let computed = fnv1a64(body);
        if stored != computed {
            return Err(err(format!(
                "artifact checksum mismatch: stored {stored:016x}, computed {computed:016x}"
            )));
        }
        let mut cur = Cur { b: body, pos: 0 };
        let magic = cur.take(4, "magic")?;
        if magic != MAGIC {
            return Err(err(format!("artifact: bad magic {magic:02x?}, expected \"NMPK\"")));
        }
        let version = cur.u32("version")?;
        if !(MIN_VERSION as usize..=VERSION as usize).contains(&version) {
            return Err(err(format!(
                "artifact: unsupported schema version {version} \
                 (this build reads {MIN_VERSION}..={VERSION})"
            )));
        }
        let arch = cur.str("arch name")?;
        let batch = cur.u32("batch")?;
        let res = cur.u32("resolution")?;
        let path = path_from_code(cur.u8("path")?)?;
        let sparsity = f64::from_bits(cur.u64("sparsity")?);
        let seed = cur.u64("seed")?;
        let default_choice = cur.choice(version, "default choice")?;
        let n_layers = cur.u32("layer count")?;
        // Not with_capacity(n_layers): the count is untrusted file data
        // and must not size an allocation before the layers parse.
        let mut layers = Vec::new();
        for li in 0..n_layers {
            let name = cur.str("layer name")?;
            let kind = cur.u8("layer kind")?;
            let choice = cur.choice(version, "layer choice")?;
            let shape = validated_shape(&mut cur, &name)?;
            let payload_len = cur.u64("payload length")? as usize;
            let pad = (PAYLOAD_ALIGN - cur.pos % PAYLOAD_ALIGN) % PAYLOAD_ALIGN;
            cur.take(pad, "payload alignment padding")?;
            if cur.pos % PAYLOAD_ALIGN != 0 {
                return Err(err(format!("artifact: layer {li} payload misaligned")));
            }
            let payload = cur.take(payload_len, "layer payload")?;
            // K = Kh·Kw·C_in in u128: the fields are untrusted u32s and
            // the product must not overflow before it is checked.
            let k = shape.kh as u128 * shape.kw as u128 * shape.c_in as u128;
            let weights = match kind {
                0 => {
                    let expect = 4 * shape.c_out as u128 * k;
                    if payload_len as u128 != expect {
                        return Err(err(format!(
                            "artifact: layer {name:?} dense payload is {payload_len} \
                             bytes, shape needs {expect}"
                        )));
                    }
                    let f = payload
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    LayerWeights::Dense(f)
                }
                1 => {
                    let (p, used) = ColwisePruned::decode(payload)
                        .map_err(|e| err(format!("artifact: layer {name:?}: {e}")))?;
                    if used != payload_len {
                        return Err(err(format!(
                            "artifact: layer {name:?} sparse payload has {} trailing bytes",
                            payload_len - used
                        )));
                    }
                    if p.rows as u128 != shape.c_out as u128 || p.cols as u128 != k {
                        return Err(err(format!(
                            "artifact: layer {name:?} sparse weights are {}x{}, shape \
                             needs {}x{k}",
                            p.rows, p.cols, shape.c_out
                        )));
                    }
                    LayerWeights::Sparse(p)
                }
                _ => {
                    return Err(err(format!(
                        "artifact: layer {name:?} has unknown weight kind {kind}"
                    )))
                }
            };
            layers.push(ArtifactLayer {
                name,
                choice,
                shape,
                weights,
            });
        }
        if cur.pos != body.len() {
            return Err(err(format!(
                "artifact: {} trailing bytes after last layer",
                body.len() - cur.pos
            )));
        }
        Ok(Self {
            arch,
            batch,
            res,
            path,
            sparsity,
            seed,
            default_choice,
            layers,
        })
    }

    /// Write the encoded artifact to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.encode())
            .map_err(|e| err(format!("writing artifact {path:?}: {e}")))
    }

    /// Read and validate an artifact file.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| err(format!("reading artifact {path:?}: {e}")))?;
        Self::decode(&bytes).map_err(|e| err(format!("artifact {path:?}: {e}")))
    }

    /// Total payload bytes across layers (weight footprint on disk,
    /// excluding headers/padding).
    pub fn weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match &l.weights {
                LayerWeights::Dense(f) => 4 * f.len(),
                LayerWeights::Sparse(p) => p.encoded_len(),
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::prune_colwise;
    use crate::util::XorShiftRng;

    fn sample() -> PackedArtifact {
        let mut r = XorShiftRng::new(0xA5);
        let s1 = ConvShape::square(1, 3, 8, 16, 3, 1, 1);
        let dense: Vec<f32> = r.normal_vec(s1.c_out * s1.k(), 1.0);
        let s2 = ConvShape::square(1, 16, 8, 8, 3, 1, 1);
        let w2 = r.normal_vec(s2.c_out * s2.k(), 1.0);
        let sparse = prune_colwise(&w2, s2.c_out, s2.k(), 4, 2, 4);
        PackedArtifact {
            arch: "resnet18".into(),
            batch: 1,
            res: 8,
            path: ConvPath::SparseCnhw,
            sparsity: 0.5,
            seed: 42,
            default_choice: LayerChoice::default(),
            layers: vec![
                ArtifactLayer {
                    name: "stem".into(),
                    choice: LayerChoice {
                        v: 16,
                        tile: 4,
                        threads: 2,
                        kernel: KernelId::Scalar,
                        dtype: Dtype::I8,
                    },
                    shape: s1,
                    weights: LayerWeights::Dense(dense),
                },
                ArtifactLayer {
                    name: "s1b0-conv1".into(),
                    choice: LayerChoice::default(),
                    shape: s2,
                    weights: LayerWeights::Sparse(sparse),
                },
            ],
        }
    }

    /// Re-sign a tampered body so structural validation (not the
    /// checksum) is what rejects it.
    fn resign(bytes: &mut Vec<u8>) {
        let n = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..n]);
        bytes[n..].copy_from_slice(&sum.to_le_bytes());
    }

    #[test]
    fn encode_decode_roundtrip_is_bitwise() {
        let a = sample();
        let bytes = a.encode();
        let b = PackedArtifact::decode(&bytes).unwrap();
        assert_eq!(b.arch, "resnet18");
        assert_eq!((b.batch, b.res, b.seed), (1, 8, 42));
        assert_eq!(b.path, ConvPath::SparseCnhw);
        assert_eq!(b.sparsity.to_bits(), 0.5f64.to_bits());
        assert_eq!(b.layers.len(), 2);
        assert_eq!(b.layers[0].name, "stem");
        assert_eq!(b.layers[0].choice.dtype, Dtype::I8);
        assert_eq!(b.layers[1].choice, LayerChoice::default());
        // Bitwise: re-encoding the decoded artifact reproduces the file.
        assert_eq!(b.encode(), bytes);
        assert_eq!(a.weight_bytes(), b.weight_bytes());
    }

    #[test]
    fn payloads_are_64_byte_aligned() {
        let bytes = sample().encode();
        // Find each payload by re-walking the header structure: the
        // padding loop in encode() must have landed every payload on a
        // PAYLOAD_ALIGN boundary. Cheap proxy: the file contains at
        // least one run of padding and decode (which checks pos %
        // PAYLOAD_ALIGN == 0 after skipping) accepts it.
        assert!(PackedArtifact::decode(&bytes).is_ok());
        assert!(bytes.len() > PAYLOAD_ALIGN);
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        let bytes = sample().encode();
        for len in 0..bytes.len() {
            assert!(
                PackedArtifact::decode(&bytes[..len]).is_err(),
                "prefix of {len} bytes decoded"
            );
        }
    }

    #[test]
    fn every_single_byte_flip_is_rejected() {
        let good = sample().encode();
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x40;
            assert!(
                PackedArtifact::decode(&bad).is_err(),
                "flip at byte {i} decoded"
            );
        }
    }

    #[test]
    fn structural_corruption_yields_descriptive_errors() {
        let good = sample().encode();
        // (offset, corrupt bytes, expected error fragment) — each case
        // is re-signed so the checksum passes and the structural check
        // itself must fire.
        let cases: Vec<(usize, Vec<u8>, &str)> = vec![
            (0, b"JUNK".to_vec(), "bad magic"),
            (4, 9u32.to_le_bytes().to_vec(), "unsupported schema version"),
            // path byte sits after magic+version+arch str+batch+res.
            (4 + 4 + 4 + 8 + 4 + 4, vec![9], "unknown path code"),
        ];
        for (off, bad_bytes, want) in cases {
            let mut bad = good.clone();
            bad[off..off + bad_bytes.len()].copy_from_slice(&bad_bytes);
            resign(&mut bad);
            let e = PackedArtifact::decode(&bad).unwrap_err().to_string();
            assert!(e.contains(want), "offset {off}: got {e:?}, want {want:?}");
        }
    }

    #[test]
    fn checksum_mismatch_is_reported_as_such() {
        let mut bad = sample().encode();
        let n = bad.len();
        bad[n - 1] ^= 0xFF;
        let e = PackedArtifact::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("checksum mismatch"), "{e}");
    }

    #[test]
    fn unknown_layer_kind_is_rejected() {
        let a = sample();
        let bytes = a.encode();
        // Locate layer 0's kind byte: it follows the fixed header and
        // the layer-0 name string (default choice is 5×u32 = 20 bytes).
        let header = 4 + 4 + (4 + a.arch.len()) + 4 + 4 + 1 + 8 + 8 + 20 + 4;
        let kind_off = header + 4 + a.layers[0].name.len();
        assert_eq!(bytes[kind_off], 0, "expected dense kind byte");
        let mut bad = bytes.clone();
        bad[kind_off] = 7;
        resign(&mut bad);
        let e = PackedArtifact::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("unknown weight kind"), "{e}");
    }

    /// Encode `a` in the legacy v1 layout (3-field choices) — the exact
    /// byte stream a pre-kernel build wrote. Kernel choices are dropped.
    fn encode_v1(a: &PackedArtifact) -> Vec<u8> {
        fn wchoice3(out: &mut Vec<u8>, c: LayerChoice) {
            w32(out, c.v);
            w32(out, c.tile);
            w32(out, c.threads);
        }
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        w32(&mut out, 1);
        wstr(&mut out, &a.arch);
        w32(&mut out, a.batch);
        w32(&mut out, a.res);
        out.push(path_code(a.path));
        w64(&mut out, a.sparsity.to_bits());
        w64(&mut out, a.seed);
        wchoice3(&mut out, a.default_choice);
        w32(&mut out, a.layers.len());
        let mut payload = Vec::new();
        for layer in &a.layers {
            wstr(&mut out, &layer.name);
            payload.clear();
            let kind = match &layer.weights {
                LayerWeights::Dense(f) => {
                    for v in f {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                    0u8
                }
                LayerWeights::Sparse(p) => {
                    p.encode_into(&mut payload);
                    1u8
                }
            };
            out.push(kind);
            wchoice3(&mut out, layer.choice);
            let s = &layer.shape;
            for v in [s.n, s.c_in, s.h_in, s.w_in, s.c_out, s.kh, s.kw, s.stride, s.pad] {
                w32(&mut out, v);
            }
            w64(&mut out, payload.len() as u64);
            while out.len() % PAYLOAD_ALIGN != 0 {
                out.push(0);
            }
            out.extend_from_slice(&payload);
        }
        let sum = fnv1a64(&out);
        w64(&mut out, sum);
        out
    }

    /// Satellite: artifacts written before the kernel dimension existed
    /// (schema v1, 3-field choices) still load; every choice gets
    /// `kernel = auto` and all other fields survive intact.
    #[test]
    fn version1_artifact_still_loads_with_auto_kernel() {
        let a = sample();
        let bytes = encode_v1(&a);
        let b = PackedArtifact::decode(&bytes).unwrap();
        assert_eq!(b.arch, a.arch);
        assert_eq!((b.batch, b.res, b.seed), (a.batch, a.res, a.seed));
        assert_eq!(b.layers.len(), a.layers.len());
        assert_eq!(
            b.default_choice,
            LayerChoice {
                kernel: KernelId::Auto,
                dtype: Dtype::F32,
                ..a.default_choice
            }
        );
        for (got, want) in b.layers.iter().zip(&a.layers) {
            assert_eq!(got.name, want.name);
            assert_eq!(
                got.choice,
                LayerChoice {
                    kernel: KernelId::Auto,
                    dtype: Dtype::F32,
                    ..want.choice
                }
            );
        }
    }

    /// Encode `a` in the legacy v2 layout (4-field choices) — the exact
    /// byte stream a pre-dtype build wrote. Dtype choices are dropped.
    fn encode_v2(a: &PackedArtifact) -> Vec<u8> {
        fn wchoice4(out: &mut Vec<u8>, c: LayerChoice) {
            w32(out, c.v);
            w32(out, c.tile);
            w32(out, c.threads);
            w32(out, c.kernel.code() as usize);
        }
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC);
        w32(&mut out, 2);
        wstr(&mut out, &a.arch);
        w32(&mut out, a.batch);
        w32(&mut out, a.res);
        out.push(path_code(a.path));
        w64(&mut out, a.sparsity.to_bits());
        w64(&mut out, a.seed);
        wchoice4(&mut out, a.default_choice);
        w32(&mut out, a.layers.len());
        let mut payload = Vec::new();
        for layer in &a.layers {
            wstr(&mut out, &layer.name);
            payload.clear();
            let kind = match &layer.weights {
                LayerWeights::Dense(f) => {
                    for v in f {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                    0u8
                }
                LayerWeights::Sparse(p) => {
                    p.encode_into(&mut payload);
                    1u8
                }
            };
            out.push(kind);
            wchoice4(&mut out, layer.choice);
            let s = &layer.shape;
            for v in [s.n, s.c_in, s.h_in, s.w_in, s.c_out, s.kh, s.kw, s.stride, s.pad] {
                w32(&mut out, v);
            }
            w64(&mut out, payload.len() as u64);
            while out.len() % PAYLOAD_ALIGN != 0 {
                out.push(0);
            }
            out.extend_from_slice(&payload);
        }
        let sum = fnv1a64(&out);
        w64(&mut out, sum);
        out
    }

    /// Artifacts written before the dtype dimension existed (schema v2,
    /// 4-field choices) still load; every choice gets `dtype = f32` and
    /// the kernel field survives intact.
    #[test]
    fn version2_artifact_still_loads_with_f32_dtype() {
        let a = sample();
        let bytes = encode_v2(&a);
        let b = PackedArtifact::decode(&bytes).unwrap();
        assert_eq!(b.arch, a.arch);
        assert_eq!((b.batch, b.res, b.seed), (a.batch, a.res, a.seed));
        assert_eq!(b.layers.len(), a.layers.len());
        assert_eq!(
            b.default_choice,
            LayerChoice {
                dtype: Dtype::F32,
                ..a.default_choice
            }
        );
        for (got, want) in b.layers.iter().zip(&a.layers) {
            assert_eq!(got.name, want.name);
            assert_eq!(
                got.choice,
                LayerChoice {
                    dtype: Dtype::F32,
                    ..want.choice
                }
            );
        }
    }

    /// A v3 choice carrying an unknown dtype code is a load error with
    /// a descriptive message, not a panic or a silent f32.
    #[test]
    fn unknown_dtype_code_is_rejected() {
        let a = sample();
        let bytes = a.encode();
        // The dtype code is the last u32 of the default choice's
        // 20-byte block in the fixed header.
        let dtype_off = 4 + 4 + (4 + a.arch.len()) + 4 + 4 + 1 + 8 + 8 + 16;
        let mut bad = bytes.clone();
        bad[dtype_off..dtype_off + 4].copy_from_slice(&99u32.to_le_bytes());
        resign(&mut bad);
        let e = PackedArtifact::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("unknown dtype code"), "{e}");
    }

    /// A v2 choice carrying an unknown kernel code is a load error with
    /// a descriptive message, not a panic or a silent Auto.
    #[test]
    fn unknown_kernel_code_is_rejected() {
        let a = sample();
        let bytes = a.encode();
        // The kernel code is the last u32 of the default choice's
        // 16-byte block in the fixed header.
        let kernel_off = 4 + 4 + (4 + a.arch.len()) + 4 + 4 + 1 + 8 + 8 + 12;
        let mut bad = bytes.clone();
        bad[kernel_off..kernel_off + 4].copy_from_slice(&99u32.to_le_bytes());
        resign(&mut bad);
        let e = PackedArtifact::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("unknown kernel code"), "{e}");
    }

    #[test]
    fn save_load_roundtrip_and_missing_file_error() {
        let dir = std::env::temp_dir().join("nmprune_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.nmpk");
        let a = sample();
        a.save(&p).unwrap();
        let b = PackedArtifact::load(&p).unwrap();
        assert_eq!(b.encode(), a.encode());
        assert!(PackedArtifact::load(&dir.join("missing.nmpk")).is_err());
        // A truncated file on disk errors with the file path included.
        let bytes = a.encode();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        let e = PackedArtifact::load(&p).unwrap_err().to_string();
        assert!(e.contains("m.nmpk"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
