//! PJRT runtime facade: manifest parsing and artifact I/O for the
//! AOT-compiled JAX/Pallas layer, plus a backend seam.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `python/compile/aot.py`).
//!
//! The offline build vendors no external crates, so the `xla` (PJRT)
//! bindings and `anyhow` are unavailable: errors use a local
//! [`RuntimeError`], and the execution backend is a stub — artifact
//! registration succeeds (file validation + bookkeeping) while
//! `execute_f32` reports a clear backend-unavailable error. Manifest
//! and flat-tensor parsing — the pieces the Rust side owns — are fully
//! implemented and tested; swapping the stub for real PJRT bindings is
//! confined to [`PjrtRuntime`]'s backend methods.

pub mod artifact;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

pub use artifact::{ArtifactLayer, LayerWeights, PackedArtifact};

/// Runtime-layer error (the offline stand-in for `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// One artifact entry from `artifacts/manifest.tsv`:
/// `name \t file \t input_arity \t description`.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub input_arity: usize,
    pub description: String,
}

/// Parse a manifest file.
pub fn read_manifest(path: &Path) -> Result<Vec<ArtifactEntry>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| err(format!("reading manifest {path:?}: {e}")))?;
    let dir = path.parent().unwrap_or(Path::new("."));
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split('\t');
        let name = parts
            .next()
            .ok_or_else(|| err(format!("manifest line {i}: missing name")))?;
        let file = parts
            .next()
            .ok_or_else(|| err(format!("manifest line {i}: missing file")))?;
        let arity: usize = parts
            .next()
            .ok_or_else(|| err(format!("manifest line {i}: missing arity")))?
            .parse()
            .map_err(|e| err(format!("manifest line {i}: bad arity: {e}")))?;
        let description = parts.next().unwrap_or("").to_string();
        out.push(ArtifactEntry {
            name: name.to_string(),
            file: dir.join(file),
            input_arity: arity,
            description,
        });
    }
    Ok(out)
}

/// Parse the flat-f32 text format written by `aot.py`'s `save_flat`:
/// dims (space-separated) on line 1, then one value per line. Returns
/// `(dims, data)`.
pub fn load_flat_f32(path: &Path) -> Result<(Vec<usize>, Vec<f32>)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| err(format!("reading flat f32 {path:?}: {e}")))?;
    let mut lines = text.lines();
    let dims: Vec<usize> = lines
        .next()
        .ok_or_else(|| err(format!("{path:?}: empty file")))?
        .split_whitespace()
        .map(|t| {
            t.parse()
                .map_err(|e| err(format!("{path:?}: bad dim: {e}")))
        })
        .collect::<Result<_>>()?;
    let data: Vec<f32> = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            l.trim()
                .parse()
                .map_err(|e| err(format!("{path:?}: bad f32: {e}")))
        })
        .collect::<Result<_>>()?;
    if dims.iter().product::<usize>() != data.len() {
        return Err(err(format!(
            "{path:?}: dims {:?} disagree with {} values",
            dims,
            data.len()
        )));
    }
    Ok((dims, data))
}

/// Registered-but-not-compiled artifact metadata (stub backend).
struct Registered {
    arity: usize,
}

/// The PJRT runtime facade. `cpu()` always succeeds and artifact
/// registration works end-to-end (file validation + bookkeeping);
/// `execute_f32` reports that the PJRT backend is not compiled into
/// this offline build.
pub struct PjrtRuntime {
    loaded: Mutex<HashMap<String, Registered>>,
}

impl PjrtRuntime {
    /// Create the (stub) CPU runtime.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            loaded: Mutex::new(HashMap::new()),
        })
    }

    /// Backend platform name.
    pub fn platform(&self) -> String {
        "cpu-stub (xla/pjrt bindings unavailable in this build)".to_string()
    }

    /// Load one HLO-text file under `name`: the stub validates that the
    /// artifact file exists and is readable text, then records the
    /// registration. Compilation is deferred to the execution backend,
    /// which the stub reports as unavailable in [`Self::execute_f32`] —
    /// so registration state and the returned `Result` always agree.
    pub fn load_hlo_text(&self, name: &str, path: &Path, arity: usize) -> Result<()> {
        std::fs::read_to_string(path)
            .map_err(|e| err(format!("reading HLO text {path:?}: {e}")))?;
        self.loaded
            .lock()
            .unwrap()
            .insert(name.to_string(), Registered { arity });
        Ok(())
    }

    /// Load every artifact in a manifest.
    pub fn load_manifest(&self, manifest: &Path) -> Result<Vec<String>> {
        let entries = read_manifest(manifest)?;
        let mut names = Vec::new();
        for e in &entries {
            self.load_hlo_text(&e.name, &e.file, e.input_arity)?;
            names.push(e.name.clone());
        }
        Ok(names)
    }

    /// Is an executable loaded?
    pub fn has(&self, name: &str) -> bool {
        self.loaded.lock().unwrap().contains_key(name)
    }

    /// Execute `name` with f32 inputs (data, dims). Returns the
    /// flattened f32 outputs of the (tuple) result, in order.
    pub fn execute_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let guard = self.loaded.lock().unwrap();
        let loaded = guard
            .get(name)
            .ok_or_else(|| err(format!("executable {name:?} not loaded")))?;
        if loaded.arity != inputs.len() {
            return Err(err(format!(
                "{name}: expected {} inputs, got {}",
                loaded.arity,
                inputs.len()
            )));
        }
        Err(err(format!(
            "cannot execute {name}: the PJRT backend (xla_extension) is not \
             available in this offline build"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn manifest_parses_and_skips_comments() {
        let dir = std::env::temp_dir().join("nmprune_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.tsv");
        let mut f = std::fs::File::create(&p).unwrap();
        writeln!(f, "# comment").unwrap();
        writeln!(f, "conv_s1\tconv_s1.hlo.txt\t2\tstage1 conv").unwrap();
        writeln!(f).unwrap();
        writeln!(f, "model\tmodel.hlo.txt\t1\tfull fwd").unwrap();
        let entries = read_manifest(&p).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "conv_s1");
        assert_eq!(entries[0].input_arity, 2);
        assert!(entries[1].file.ends_with("model.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(read_manifest(Path::new("/nonexistent/manifest.tsv")).is_err());
    }

    #[test]
    fn flat_f32_roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("nmprune_flat_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.txt");
        std::fs::write(&p, "2 3\n1\n2\n3\n4\n5\n6\n").unwrap();
        let (dims, data) = load_flat_f32(&p).unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // Mismatched element count must error.
        std::fs::write(&p, "2 3\n1\n2\n").unwrap();
        assert!(load_flat_f32(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn execute_unknown_name_errors() {
        let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
        assert!(rt.execute_f32("nope", &[]).is_err());
        assert!(!rt.has("nope"));
    }

    #[test]
    fn stub_backend_registers_but_reports_unavailable_execute() {
        let dir = std::env::temp_dir().join("nmprune_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let hlo = dir.join("m.hlo.txt");
        std::fs::write(&hlo, "HloModule m\n").unwrap();
        let rt = PjrtRuntime::cpu().unwrap();
        rt.load_hlo_text("m", &hlo, 1).unwrap();
        assert!(rt.has("m"));
        // Arity is checked before the backend error.
        assert!(rt.execute_f32("m", &[]).unwrap_err().to_string().contains("1 inputs"));
        let data = [0.0f32];
        let dims = [1usize];
        let e = rt.execute_f32("m", &[(&data[..], &dims[..])]).unwrap_err();
        assert!(e.to_string().contains("not available"), "{e}");
        // A missing artifact file still fails at load time.
        assert!(rt.load_hlo_text("g", &dir.join("gone.hlo.txt"), 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
