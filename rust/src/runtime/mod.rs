//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and
//! execute them from the Rust request path.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `python/compile/aot.py`).
//! Python runs once at build time (`make artifacts`); this module is the
//! only place the compiled graphs are touched at runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// One artifact entry from `artifacts/manifest.tsv`:
/// `name \t file \t input_arity \t description`.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub input_arity: usize,
    pub description: String,
}

/// Parse a manifest file.
pub fn read_manifest(path: &Path) -> Result<Vec<ArtifactEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading manifest {path:?}"))?;
    let dir = path.parent().unwrap_or(Path::new("."));
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split('\t');
        let name = parts
            .next()
            .ok_or_else(|| anyhow!("manifest line {i}: missing name"))?;
        let file = parts
            .next()
            .ok_or_else(|| anyhow!("manifest line {i}: missing file"))?;
        let arity: usize = parts
            .next()
            .ok_or_else(|| anyhow!("manifest line {i}: missing arity"))?
            .parse()
            .with_context(|| format!("manifest line {i}: bad arity"))?;
        let description = parts.next().unwrap_or("").to_string();
        out.push(ArtifactEntry {
            name: name.to_string(),
            file: dir.join(file),
            input_arity: arity,
            description,
        });
    }
    Ok(out)
}

/// Parse the flat-f32 text format written by `aot.py`'s `save_flat`:
/// dims (space-separated) on line 1, then one value per line. Returns
/// `(dims, data)`.
pub fn load_flat_f32(path: &Path) -> Result<(Vec<usize>, Vec<f32>)> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading flat f32 {path:?}"))?;
    let mut lines = text.lines();
    let dims: Vec<usize> = lines
        .next()
        .ok_or_else(|| anyhow!("{path:?}: empty file"))?
        .split_whitespace()
        .map(|t| t.parse().map_err(|e| anyhow!("{path:?}: bad dim: {e}")))
        .collect::<Result<_>>()?;
    let data: Vec<f32> = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            l.trim()
                .parse()
                .map_err(|e| anyhow!("{path:?}: bad f32: {e}"))
        })
        .collect::<Result<_>>()?;
    if dims.iter().product::<usize>() != data.len() {
        return Err(anyhow!(
            "{path:?}: dims {:?} disagree with {} values",
            dims,
            data.len()
        ));
    }
    Ok((dims, data))
}

/// A loaded-and-compiled executable plus its metadata.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    arity: usize,
}

/// The PJRT CPU runtime: compiles HLO-text artifacts once, caches the
/// executables, and runs them with f32 inputs.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    loaded: Mutex<HashMap<String, Loaded>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self {
            client,
            loaded: Mutex::new(HashMap::new()),
        })
    }

    /// Backend platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text file under `name`.
    pub fn load_hlo_text(&self, name: &str, path: &Path, arity: usize) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.loaded
            .lock()
            .unwrap()
            .insert(name.to_string(), Loaded { exe, arity });
        Ok(())
    }

    /// Load every artifact in a manifest.
    pub fn load_manifest(&self, manifest: &Path) -> Result<Vec<String>> {
        let entries = read_manifest(manifest)?;
        let mut names = Vec::new();
        for e in &entries {
            self.load_hlo_text(&e.name, &e.file, e.input_arity)?;
            names.push(e.name.clone());
        }
        Ok(names)
    }

    /// Is an executable loaded?
    pub fn has(&self, name: &str) -> bool {
        self.loaded.lock().unwrap().contains_key(name)
    }

    /// Execute `name` with f32 inputs (data, dims). Returns the flattened
    /// f32 outputs of the (tuple) result, in order.
    pub fn execute_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let guard = self.loaded.lock().unwrap();
        let loaded = guard
            .get(name)
            .ok_or_else(|| anyhow!("executable {name:?} not loaded"))?;
        if loaded.arity != inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                loaded.arity,
                inputs.len()
            ));
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → always a tuple.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn manifest_parses_and_skips_comments() {
        let dir = std::env::temp_dir().join("nmprune_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.tsv");
        let mut f = std::fs::File::create(&p).unwrap();
        writeln!(f, "# comment").unwrap();
        writeln!(f, "conv_s1\tconv_s1.hlo.txt\t2\tstage1 conv").unwrap();
        writeln!(f).unwrap();
        writeln!(f, "model\tmodel.hlo.txt\t1\tfull fwd").unwrap();
        let entries = read_manifest(&p).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "conv_s1");
        assert_eq!(entries[0].input_arity, 2);
        assert!(entries[1].file.ends_with("model.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(read_manifest(Path::new("/nonexistent/manifest.tsv")).is_err());
    }

    #[test]
    fn execute_unknown_name_errors() {
        let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
        assert!(rt.execute_f32("nope", &[]).is_err());
        assert!(!rt.has("nope"));
    }

    /// Full AOT round-trip against real artifacts — exercised when
    /// `make artifacts` has run (CI path); skipped silently otherwise.
    #[test]
    fn roundtrip_artifacts_if_present() {
        let manifest = Path::new("artifacts/manifest.tsv");
        if !manifest.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let names = rt.load_manifest(manifest).unwrap();
        assert!(!names.is_empty());
    }
}
