//! A small line-oriented Rust lexer for the lint pass.
//!
//! [`lex`] splits a source file into [`Line`]s, each carrying three
//! views of the same text: the raw line, a *code view* with every
//! comment removed and every string/char literal blanked to its
//! delimiters, and a *comment view* holding the comment text. Rules
//! match invariants against the code view (so `unsafe` inside a string
//! or a comment can never trip a rule) and read SAFETY justifications
//! and suppression directives from the comment view.
//!
//! The lexer understands exactly the token classes that can hide rule
//! patterns from a naive `grep` — the whole reason this pass exists:
//!
//! * line comments (`//`, `///`, `//!`) and *nested* block comments
//!   (`/* /* */ */`), including doc blocks;
//! * string literals with escapes (`"\" // not a comment"`), byte
//!   strings, and multi-line strings;
//! * raw strings `r"…"` / `r#"…"#` / `br##"…"##` with any hash depth
//!   (no escapes inside — the closing delimiter is quote-plus-hashes);
//! * char and byte-char literals (`'"'`, `b'\''`) versus lifetimes
//!   (`&'a T`, `'outer:`) — a lifetime's `'` must not open a "literal"
//!   that swallows the rest of the file;
//! * CRLF line endings (`\r` is dropped from every view).
//!
//! It does not build a token tree: rules are line-anchored substring
//! and word matches over the cleaned views, which is exactly enough for
//! the repo invariants and keeps the pass dependency-free.

/// One source line in three views.
#[derive(Clone, Debug, Default)]
pub struct Line {
    /// The original text (without the trailing `\n` / `\r\n`).
    pub raw: String,
    /// Code only: comments removed, string/char contents blanked (the
    /// delimiters and raw-string hashes are kept so tokens stay
    /// separated).
    pub code: String,
    /// Comment text on this line, markers included (`// …`, `/* …`).
    pub comment: String,
}

/// Lexer state that survives a line break.
enum State {
    /// Plain code.
    Code,
    /// Inside a block comment, `depth` levels deep (they nest).
    Block(u32),
    /// Inside a `"…"` string literal (escapes active).
    Str,
    /// Inside a raw string closed by `"` followed by this many `#`s.
    RawStr(u32),
}

/// Does `c` continue an identifier? Used to keep `r`/`b` prefixes of
/// raw/byte strings apart from identifiers that merely end in `r`/`b`.
fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Try to read a raw-string opener `r##"` at `chars[i]` (the `r`).
/// Returns the hash count and the index just past the opening quote.
fn raw_opener(chars: &[char], i: usize) -> Option<(u32, usize)> {
    debug_assert_eq!(chars[i], 'r');
    let mut j = i + 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Lex `src` into per-line code/comment views. Never fails: unterminated
/// literals or comments simply run to end of file (the compiler will
/// have plenty to say about such a file; the lint pass stays total).
pub fn lex(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut prev_code_char = ' ';
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\r' {
            // CRLF: the carriage return is invisible to every view.
            i += 1;
            continue;
        }
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            prev_code_char = ' ';
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    // Line comment (incl. `///` and `//!`): the rest of
                    // the line is comment text.
                    while i < chars.len() && chars[i] != '\n' {
                        if chars[i] != '\r' {
                            cur.raw.push(chars[i]);
                            cur.comment.push(chars[i]);
                        }
                        i += 1;
                    }
                    continue;
                }
                if c == '/' && next == Some('*') {
                    cur.raw.push_str("/*");
                    cur.comment.push_str("/*");
                    state = State::Block(1);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    cur.raw.push('"');
                    cur.code.push('"');
                    state = State::Str;
                    i += 1;
                    continue;
                }
                if c == 'r' && !is_ident(prev_code_char) {
                    if let Some((hashes, after)) = raw_opener(&chars, i) {
                        for &rc in &chars[i..after] {
                            cur.raw.push(rc);
                            cur.code.push(rc);
                        }
                        state = State::RawStr(hashes);
                        i = after;
                        continue;
                    }
                }
                if c == 'b' && !is_ident(prev_code_char) {
                    if next == Some('r') {
                        if let Some((hashes, after)) = raw_opener(&chars, i + 1) {
                            for &rc in &chars[i..after] {
                                cur.raw.push(rc);
                                cur.code.push(rc);
                            }
                            state = State::RawStr(hashes);
                            i = after;
                            continue;
                        }
                    }
                    if next == Some('"') {
                        cur.raw.push_str("b\"");
                        cur.code.push_str("b\"");
                        state = State::Str;
                        i += 2;
                        continue;
                    }
                    // `b'…'` falls through to the `'` branch below once
                    // the `b` has been emitted as a plain code char.
                }
                if c == '\'' {
                    // Char literal or lifetime. A char literal is `'`
                    // followed by an escape, or by exactly one char and
                    // a closing `'`. Anything else (`'a`, `'outer:`,
                    // `<'a>`) is a lifetime/label: emit the quote alone.
                    let is_char_lit = match chars.get(i + 1) {
                        Some('\\') => true,
                        Some(_) => chars.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    if is_char_lit {
                        cur.raw.push('\'');
                        cur.code.push('\'');
                        let mut j = i + 1;
                        if chars.get(j) == Some(&'\\') {
                            // Skip the escape head so `'\''` and `'\\'`
                            // don't close early; then run to the quote.
                            cur.raw.push('\\');
                            j += 1;
                            if let Some(&e) = chars.get(j) {
                                cur.raw.push(e);
                                j += 1;
                            }
                        }
                        while j < chars.len() && chars[j] != '\'' {
                            cur.raw.push(chars[j]);
                            j += 1;
                        }
                        if j < chars.len() {
                            cur.raw.push('\'');
                            cur.code.push('\'');
                            j += 1;
                        }
                        prev_code_char = '\'';
                        i = j;
                        continue;
                    }
                    cur.raw.push('\'');
                    cur.code.push('\'');
                    prev_code_char = '\'';
                    i += 1;
                    continue;
                }
                cur.raw.push(c);
                cur.code.push(c);
                prev_code_char = c;
                i += 1;
            }
            State::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    cur.raw.push_str("/*");
                    cur.comment.push_str("/*");
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    cur.raw.push_str("*/");
                    cur.comment.push_str("*/");
                    state = if depth > 1 { State::Block(depth - 1) } else { State::Code };
                    i += 2;
                } else {
                    cur.raw.push(c);
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                cur.raw.push(c);
                if c == '\\' {
                    if let Some(&e) = chars.get(i + 1) {
                        if e != '\n' && e != '\r' {
                            cur.raw.push(e);
                            i += 1;
                        }
                    }
                } else if c == '"' {
                    cur.code.push('"');
                    state = State::Code;
                }
                i += 1;
            }
            State::RawStr(hashes) => {
                cur.raw.push(c);
                if c == '"' {
                    let n = hashes as usize;
                    let closes = (0..n).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closes {
                        cur.code.push('"');
                        for _ in 0..n {
                            cur.raw.push('#');
                            cur.code.push('#');
                        }
                        state = State::Code;
                        i += 1 + n;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    // Final line without a trailing newline.
    if !cur.raw.is_empty() || !lines.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Does `code` contain `word` bounded by non-identifier chars? The
/// word-level match rules (`unsafe`, `elapsed`, `debug_assert` …) use
/// this so `unsafe_op_in_unsafe_fn` or `non_elapsed_field` never match.
pub fn contains_word(code: &str, word: &str) -> bool {
    find_word(code, word).is_some()
}

/// Byte offset of the first word-bounded occurrence of `word` in
/// `code`. The right boundary tolerates a following `!` (macro names:
/// `debug_assert!`/`vec!` are still the banned token).
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(rel) = code[from..].find(word) {
        let at = from + rel;
        let left_ok = at == 0 || {
            let b = bytes[at - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let end = at + word.len();
        let right_ok = end >= bytes.len() || {
            let b = bytes[end];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        if left_ok && right_ok {
            return Some(at);
        }
        from = at + word.len().max(1);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_doc_comments() {
        let lines = lex("let x = 1; // unsafe here\n/// docs unsafe\nlet y = 2;");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert!(lines[0].comment.contains("unsafe here"));
        assert_eq!(lines[1].code, "");
        assert!(lines[1].comment.contains("docs unsafe"));
        assert_eq!(lines[2].code, "let y = 2;");
    }

    #[test]
    fn nested_block_comments() {
        let lines = lex("a /* one /* two */ still */ b\nc");
        assert_eq!(lines[0].code, "a  b");
        assert!(lines[0].comment.contains("two"));
        assert_eq!(lines[1].code, "c");
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let lines = lex("x /* unsafe\nthread::spawn\n*/ y");
        assert_eq!(lines[0].code, "x ");
        assert_eq!(lines[1].code, "");
        assert!(lines[1].comment.contains("thread::spawn"));
        assert_eq!(lines[2].code, " y");
    }

    #[test]
    fn blanks_string_contents_and_keeps_delimiters() {
        let lines = lex("let s = \"unsafe // not a comment\"; call();");
        assert_eq!(lines[0].code, "let s = \"\"; call();");
        assert!(lines[0].comment.is_empty());
    }

    #[test]
    fn string_escapes_do_not_close_early() {
        let lines = lex(r#"let s = "quote \" then // still string"; x"#);
        assert_eq!(lines[0].code, "let s = \"\"; x");
    }

    #[test]
    fn raw_strings_with_hashes() {
        let lines = lex("let s = r#\"unsafe \" inner\"#; y();");
        assert_eq!(lines[0].code, "let s = r#\"\"#; y();");
        let lines = lex("let s = r\"unsafe\"; z();");
        assert_eq!(lines[0].code, "let s = r\"\"; z();");
        let lines = lex("let s = br##\"thread::spawn\"##; w();");
        assert_eq!(lines[0].code, "let s = br##\"\"##; w();");
    }

    #[test]
    fn raw_string_spans_lines() {
        let lines = lex("let s = r#\"line one\nunsafe two\"#;\nnext");
        assert_eq!(lines[0].code, "let s = r#\"");
        assert_eq!(lines[1].code, "\"#;");
        assert_eq!(lines[2].code, "next");
    }

    #[test]
    fn identifier_ending_in_r_is_not_a_raw_string() {
        // `var"x"` is nonsense Rust but the lexer must not treat the
        // `r` of an identifier as a raw-string prefix; more realistic:
        // a macro arg like `write!(f, "…")` after an ident ending in r.
        let lines = lex("let ptr = other;\nlet s = \"x\";");
        assert_eq!(lines[0].code, "let ptr = other;");
        assert_eq!(lines[1].code, "let s = \"\";");
    }

    #[test]
    fn char_literals_blank_but_lifetimes_pass() {
        assert_eq!(code_of("let c = '\"'; f::<'_>();")[0], "let c = ''; f::<'_>();");
        assert_eq!(code_of("let c = '\\''; g();")[0], "let c = ''; g();");
        assert_eq!(code_of("fn f<'a>(x: &'a str) {}")[0], "fn f<'a>(x: &'a str) {}");
        // A quote inside a char literal must not open a string that
        // swallows the following code.
        let mixed = code_of("let c = '\"'; let s = \"k\"; h();");
        assert_eq!(mixed[0], "let c = ''; let s = \"\"; h();");
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        assert_eq!(code_of("let b = b\"unsafe\"; x();")[0], "let b = b\"\"; x();");
        assert_eq!(code_of("let b = b'\\''; y();")[0], "let b = b''; y();");
    }

    #[test]
    fn crlf_is_invisible() {
        let lines = lex("let a = 1;\r\nlet b = 2; // tail\r\n");
        assert_eq!(lines[0].code, "let a = 1;");
        assert_eq!(lines[1].code, "let b = 2; ");
        assert!(lines[1].comment.contains("tail"));
        assert!(!lines[0].raw.contains('\r'));
    }

    #[test]
    fn unterminated_string_runs_to_eof_without_panic() {
        let lines = lex("let s = \"never closed\nmore");
        assert_eq!(lines[0].code, "let s = \"");
        assert_eq!(lines[1].code, "");
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("unsafe {", "unsafe"));
        assert!(contains_word("pub unsafe fn f()", "unsafe"));
        assert!(!contains_word("unsafe_op_in_unsafe_fn", "unsafe"));
        assert!(!contains_word("deny(unsafe_code)", "unsafe"));
        assert!(contains_word("debug_assert!(x)", "debug_assert"));
        assert!(!contains_word("debug_assert_eq_helper", "debug_assert"));
        assert!(contains_word("t.elapsed()", "elapsed"));
        assert!(!contains_word("elapsed_ns", "elapsed"));
    }
}
