//! Line-anchored rules over the lexed views, plus the suppression
//! directive grammar.
//!
//! Each rule encodes an invariant the repo already claims elsewhere
//! (ARCHITECTURE.md "Invariants", docs/SAFETY.md):
//!
//! | id | invariant |
//! |----|-----------|
//! | U1 | every `unsafe` is immediately preceded by a `// SAFETY:` comment (or a `# Safety` doc section) |
//! | S1 | no `thread::spawn` outside `util/threadpool.rs` — all parallelism goes through the persistent pool |
//! | P1 | `engine/policy.rs` is clock-free: no `Instant::now` / `SystemTime` / `.elapsed()` |
//! | A1 | `runtime/artifact.rs` never uses `debug_assert` — loader validation must survive release builds |
//! | N1 | no `.partial_cmp(…).unwrap()` anywhere — NaN turns it into a panic (use `total_cmp`) |
//! | Z1 | no allocating calls inside a zero-alloc-marked region (the `_into` twins) |
//! | L1 | lint hygiene: suppression directives must parse and carry a non-empty justification |
//!
//! Suppression is explicit and always justified:
//!
//! ```text
//! // nmprune-lint: allow(S1) -- dispatcher threads live for the server lifetime
//! ```
//!
//! A directive covers its own line and the next line, so it works both
//! as a trailing comment and as a comment above the flagged statement.
//! A directive that does not parse, names an unknown rule, or has an
//! empty justification is itself an L1 finding — and L1 cannot be
//! suppressed.

use super::lexer::{contains_word, find_word, Line};

/// The marker comment that opens a zero-alloc region: the next `fn` is
/// checked by Z1 over its whole body.
pub const ZERO_ALLOC_MARKER: &str = "nmprune: zero-alloc";

/// Prefix of a suppression directive inside a comment.
pub const SUPPRESS_PREFIX: &str = "nmprune-lint:";

/// Rule identifiers. `L1` is the meta-rule for malformed suppressions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    U1,
    S1,
    P1,
    A1,
    N1,
    Z1,
    L1,
}

impl Rule {
    /// Every rule, in report order.
    pub const ALL: [Rule; 7] =
        [Rule::U1, Rule::S1, Rule::P1, Rule::A1, Rule::N1, Rule::Z1, Rule::L1];

    /// Stable id used in reports and `allow(..)` directives.
    pub fn id(self) -> &'static str {
        match self {
            Rule::U1 => "U1",
            Rule::S1 => "S1",
            Rule::P1 => "P1",
            Rule::A1 => "A1",
            Rule::N1 => "N1",
            Rule::Z1 => "Z1",
            Rule::L1 => "L1",
        }
    }

    /// Parse an id as written in an `allow(..)` directive.
    pub fn from_id(id: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.id() == id)
    }
}

/// One lint finding, anchored to a file and 1-based line.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
    /// The offending source line, trimmed and capped for display.
    pub snippet: String,
}

/// A parsed, well-formed suppression directive.
struct Directive {
    /// 0-based line the directive sits on.
    line: usize,
    rules: Vec<Rule>,
}

fn snippet_of(line: &Line) -> String {
    let t = line.raw.trim();
    if t.chars().count() > 120 {
        let cut: String = t.chars().take(117).collect();
        format!("{cut}...")
    } else {
        t.to_string()
    }
}

fn finding(file: &str, idx: usize, rule: Rule, message: String, lines: &[Line]) -> Finding {
    Finding {
        file: file.to_string(),
        line: idx + 1,
        rule,
        message,
        snippet: snippet_of(&lines[idx]),
    }
}

/// Find `pat` in `code` requiring only a *left* identifier boundary, so
/// `debug_assert` also matches `debug_assert_eq!` while `my_debug_assert`
/// does not. Patterns that begin with a non-identifier char (`.to_vec(`)
/// trivially pass the boundary check at any position.
fn find_ident_prefix(code: &str, pat: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(rel) = code[from..].find(pat) {
        let at = from + rel;
        let left_ok = at == 0 || {
            let b = bytes[at - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        if left_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// Parse every suppression directive in `lines`. Malformed directives
/// come back as L1 findings instead.
fn parse_directives(file: &str, lines: &[Line]) -> (Vec<Directive>, Vec<Finding>) {
    let mut directives = Vec::new();
    let mut bad = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some(pos) = line.comment.find(SUPPRESS_PREFIX) else {
            continue;
        };
        let rest = line.comment[pos + SUPPRESS_PREFIX.len()..].trim_start();
        let Some(inner_start) = rest.strip_prefix("allow(") else {
            let msg = format!("malformed directive: expected `{SUPPRESS_PREFIX} allow(<rule>)`");
            bad.push(finding(file, idx, Rule::L1, msg, lines));
            continue;
        };
        let Some(close) = inner_start.find(')') else {
            let msg = "malformed directive: unterminated allow(...)".to_string();
            bad.push(finding(file, idx, Rule::L1, msg, lines));
            continue;
        };
        let mut rules = Vec::new();
        let mut ok = true;
        for id in inner_start[..close].split(',') {
            let id = id.trim();
            match Rule::from_id(id) {
                Some(Rule::L1) => {
                    let msg = "L1 cannot be suppressed".to_string();
                    bad.push(finding(file, idx, Rule::L1, msg, lines));
                    ok = false;
                }
                Some(r) => rules.push(r),
                None => {
                    let msg = format!("unknown rule id `{id}` in allow(...)");
                    bad.push(finding(file, idx, Rule::L1, msg, lines));
                    ok = false;
                }
            }
        }
        if !ok {
            continue;
        }
        let tail = inner_start[close + 1..].trim_start();
        let Some(just) = tail.strip_prefix("--") else {
            let msg = "suppression without justification: append `-- <why>`".to_string();
            bad.push(finding(file, idx, Rule::L1, msg, lines));
            continue;
        };
        if just.trim().is_empty() {
            let msg = "suppression with empty justification".to_string();
            bad.push(finding(file, idx, Rule::L1, msg, lines));
            continue;
        }
        if rules.is_empty() {
            let msg = "allow() names no rules".to_string();
            bad.push(finding(file, idx, Rule::L1, msg, lines));
            continue;
        }
        directives.push(Directive { line: idx, rules });
    }
    (directives, bad)
}

/// U1 justification scan: is the `unsafe` on line `idx` covered by a
/// trailing `SAFETY:` comment or an immediately preceding comment block
/// containing `SAFETY:` (or a `# Safety` rustdoc section, which covers
/// trait-level `unsafe fn` declarations)?
///
/// The upward scan skips attribute lines (`#[...]`, `#![...]`) and
/// statement-continuation lines (code not ending in `;`/`{`/`}`), so a
/// comment above `let ptr = { unsafe { .. } }` split across lines still
/// counts. It stops — and the check fails — at a blank line or a
/// completed statement: "immediately preceding" is the contract.
fn unsafe_is_justified(lines: &[Line], idx: usize) -> bool {
    let has_safety = |c: &str| c.contains("SAFETY:") || c.contains("# Safety");
    if has_safety(&lines[idx].comment) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let comment = lines[j].comment.trim();
        if code.is_empty() && comment.is_empty() {
            return false; // blank line: not "immediately preceding"
        }
        if code.is_empty() {
            // Comment-only line: accept if its contiguous comment block
            // carries the justification.
            let mut k = j + 1;
            while k > 0 {
                let l = &lines[k - 1];
                if !l.code.trim().is_empty() || l.comment.trim().is_empty() {
                    break;
                }
                if has_safety(&l.comment) {
                    return true;
                }
                k -= 1;
            }
            return false;
        }
        if has_safety(comment) {
            return true;
        }
        if code.starts_with("#[") || code.starts_with("#!") {
            continue; // attributes sit between the comment and the item
        }
        if code.ends_with(';') || code.ends_with('{') || code.ends_with('}') {
            return false; // previous statement completed: no comment
        }
        // Otherwise this is an earlier line of the same statement
        // (e.g. `let f: &T =` above an `unsafe { .. }`): keep scanning.
    }
    false
}

/// N1: `.partial_cmp(..).unwrap()` / `.expect(..)` chains, matched over
/// the concatenated code view so rustfmt line breaks between the call
/// and the unwrap cannot hide the pattern.
fn scan_partial_cmp_unwrap(file: &str, lines: &[Line], out: &mut Vec<Finding>) {
    let mut flat = String::new();
    let mut line_of = Vec::new(); // char index -> 0-based line
    for (idx, line) in lines.iter().enumerate() {
        for c in line.code.chars() {
            flat.push(c);
            line_of.push(idx);
        }
        flat.push('\n');
        line_of.push(idx);
    }
    let chars: Vec<char> = flat.chars().collect();
    let mut from = 0;
    while let Some(rel) = flat[from..].find(".partial_cmp") {
        let at = from + rel;
        from = at + ".partial_cmp".len();
        // flat is pushed char-by-char, so byte offsets == char offsets
        // only for ASCII; recover the char index by counting.
        let char_at = flat[..at].chars().count();
        let mut i = char_at + ".partial_cmp".chars().count();
        while i < chars.len() && chars[i].is_whitespace() {
            i += 1;
        }
        if chars.get(i) != Some(&'(') {
            continue;
        }
        let mut depth = 0i32;
        while i < chars.len() {
            match chars[i] {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        i += 1;
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        while i < chars.len() && chars[i].is_whitespace() {
            i += 1;
        }
        if chars.get(i) != Some(&'.') {
            continue;
        }
        i += 1;
        while i < chars.len() && chars[i].is_whitespace() {
            i += 1;
        }
        let mut ident = String::new();
        while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
            ident.push(chars[i]);
            i += 1;
        }
        if ident == "unwrap" || ident == "expect" {
            let idx = line_of[char_at];
            let msg = format!(".partial_cmp(..).{ident}() panics on NaN; use total_cmp");
            out.push(finding(file, idx, Rule::N1, msg, lines));
        }
    }
}

/// Z1: from each [`ZERO_ALLOC_MARKER`] comment, locate the next `fn`,
/// brace-match its body on the code view, and flag allocating calls
/// inside the span.
fn scan_zero_alloc_regions(file: &str, lines: &[Line], out: &mut Vec<Finding>) {
    const ALLOC_PATTERNS: [(&str, &str); 6] = [
        ("Vec::new", "Vec::new allocates"),
        ("vec!", "vec! allocates"),
        (".to_vec(", ".to_vec() allocates"),
        ("Box::new", "Box::new allocates"),
        ("String::from", "String::from allocates"),
        (".collect", ".collect() allocates"),
    ];
    for (midx, mline) in lines.iter().enumerate() {
        if !mline.comment.contains(ZERO_ALLOC_MARKER) {
            continue;
        }
        // Find the fn this marker annotates: skip comments/attrs/blank.
        let mut fn_idx = None;
        for (j, line) in lines.iter().enumerate().skip(midx).take(12) {
            if contains_word(&line.code, "fn") {
                fn_idx = Some(j);
                break;
            }
        }
        let Some(fn_idx) = fn_idx else {
            let msg = format!("`{ZERO_ALLOC_MARKER}` marker is not followed by a fn");
            out.push(finding(file, midx, Rule::Z1, msg, lines));
            continue;
        };
        // Fn name, for the message.
        let fn_code = &lines[fn_idx].code;
        let name: String = find_word(fn_code, "fn")
            .map(|p| {
                fn_code[p + 2..]
                    .trim_start()
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect()
            })
            .unwrap_or_default();
        // Body span: first '{' at or after the fn line, brace-matched.
        let mut depth = 0i32;
        let mut opened = false;
        let mut end_idx = lines.len().saturating_sub(1);
        'span: for (j, line) in lines.iter().enumerate().skip(fn_idx) {
            for c in line.code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            end_idx = j;
                            break 'span;
                        }
                    }
                    _ => {}
                }
            }
        }
        for (j, line) in lines.iter().enumerate().take(end_idx + 1).skip(fn_idx) {
            for (pat, what) in ALLOC_PATTERNS {
                if find_ident_prefix(&line.code, pat).is_some() {
                    let msg = format!("{what} inside zero-alloc region `fn {name}`");
                    out.push(finding(file, j, Rule::Z1, msg, lines));
                }
            }
        }
    }
}

/// Run every rule over one lexed file. `file` should be a
/// `/`-separated path relative to the lint root — the path-scoped
/// rules (S1/P1/A1) match on its suffix.
pub fn lint_lines(file: &str, lines: &[Line]) -> Vec<Finding> {
    let (directives, mut findings) = parse_directives(file, lines);

    let in_pool = file.ends_with("util/threadpool.rs");
    let in_policy = file.ends_with("engine/policy.rs");
    let in_artifact = file.ends_with("runtime/artifact.rs");

    let mut raw = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if contains_word(code, "unsafe") && !unsafe_is_justified(lines, idx) {
            let msg = "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string();
            raw.push(finding(file, idx, Rule::U1, msg, lines));
        }
        if !in_pool && find_ident_prefix(code, "thread::spawn").is_some() {
            let msg =
                "thread::spawn outside util/threadpool.rs -- use the persistent pool".to_string();
            raw.push(finding(file, idx, Rule::S1, msg, lines));
        }
        if in_policy
            && (find_ident_prefix(code, "Instant::now").is_some()
                || contains_word(code, "SystemTime")
                || contains_word(code, "elapsed"))
        {
            let msg = "clock source in engine/policy.rs -- policies must stay pure".to_string();
            raw.push(finding(file, idx, Rule::P1, msg, lines));
        }
        if in_artifact && find_ident_prefix(code, "debug_assert").is_some() {
            let msg =
                "debug_assert in the artifact loader compiles out of release builds".to_string();
            raw.push(finding(file, idx, Rule::A1, msg, lines));
        }
    }
    scan_partial_cmp_unwrap(file, lines, &mut raw);
    scan_zero_alloc_regions(file, lines, &mut raw);

    // Apply suppressions: a directive covers its own line and the next.
    for f in raw {
        let idx = f.line - 1;
        let suppressed = directives
            .iter()
            .any(|d| (d.line == idx || d.line + 1 == idx) && d.rules.contains(&f.rule));
        if !suppressed {
            findings.push(f);
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule.id()).cmp(&(b.line, b.rule.id())));
    findings
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn run(file: &str, src: &str) -> Vec<Finding> {
        lint_lines(file, &lex(src))
    }

    #[test]
    fn u1_flags_bare_unsafe_and_accepts_safety() {
        let bad = run("x.rs", "fn f() {\n    unsafe { work() }\n}\n");
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].rule, Rule::U1);
        assert_eq!(bad[0].line, 2);
        let good = run(
            "x.rs",
            "fn f() {\n    // SAFETY: bounds checked above.\n    unsafe { work() }\n}\n",
        );
        assert!(good.is_empty(), "{good:?}");
    }

    #[test]
    fn u1_accepts_doc_safety_section_and_attributes_between() {
        let src = "/// # Safety\n/// Caller upholds X.\n#[inline]\npub unsafe fn f() {}\n";
        assert!(run("x.rs", src).is_empty());
    }

    #[test]
    fn u1_blank_line_breaks_adjacency() {
        let src = "// SAFETY: stale comment.\n\nunsafe fn f() {}\n";
        let f = run("x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::U1);
    }

    #[test]
    fn u1_ignores_unsafe_in_strings_and_comments() {
        let src = "let s = \"unsafe\"; // unsafe in a comment\nlet r = r#\"unsafe\"#;\n";
        assert!(run("x.rs", src).is_empty());
    }

    #[test]
    fn s1_scoped_to_pool_file() {
        let src = "std::thread::spawn(|| {});\n";
        assert_eq!(run("src/engine/server.rs", src).len(), 1);
        assert!(run("src/util/threadpool.rs", src).is_empty());
    }

    #[test]
    fn n1_spots_split_lines_and_expect() {
        let src = "v.sort_by(|a, b| a\n    .partial_cmp(b)\n    .unwrap());\n";
        let f = run("x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::N1);
        assert_eq!(f[0].line, 2);
        let ok = run("x.rs", "let c = a.partial_cmp(b).unwrap_or(Ordering::Equal);\n");
        assert!(ok.is_empty());
    }

    #[test]
    fn z1_flags_alloc_in_marked_fn_only() {
        let src = concat!(
            "// nmprune: zero-alloc\n",
            "fn into_twin(out: &mut [f32]) {\n",
            "    let v = Vec::new();\n",
            "}\n",
            "fn free() {\n",
            "    let v = vec![1];\n",
            "}\n",
        );
        let f = run("x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::Z1);
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains("into_twin"));
    }

    #[test]
    fn suppression_covers_line_and_next_and_requires_reason() {
        let src = "// nmprune-lint: allow(S1) -- joined on drop\nstd::thread::spawn(|| {});\n";
        assert!(run("x.rs", src).is_empty());
        let trailing = "std::thread::spawn(|| {}); // nmprune-lint: allow(S1) -- one-shot\n";
        assert!(run("x.rs", trailing).is_empty());
        let empty = "// nmprune-lint: allow(S1) --\nstd::thread::spawn(|| {});\n";
        let f = run("x.rs", empty);
        assert_eq!(f.len(), 2, "{f:?}"); // L1 for the directive + S1 not suppressed
        assert!(f.iter().any(|x| x.rule == Rule::L1));
        assert!(f.iter().any(|x| x.rule == Rule::S1));
    }

    #[test]
    fn l1_on_unknown_rule() {
        let f = run("x.rs", "// nmprune-lint: allow(Q9) -- whatever\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::L1);
    }
}
