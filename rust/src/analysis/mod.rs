//! `nmprune lint`: a dependency-free static-analysis pass over the
//! crate's own source tree.
//!
//! The repo carries invariants that `rustc` cannot check — every
//! `unsafe` justified in a `// SAFETY:` comment, no thread spawns
//! outside the pool, a clock-free policy module, release-mode artifact
//! validation, NaN-safe comparisons, allocation-free `_into` paths.
//! Until this pass they were enforced by convention and one CI `grep`
//! (which false-positived on a doc comment). This module makes them
//! machine-checked: [`lexer`] strips comments/strings so rules only
//! ever see code, [`rules`] anchors each invariant to file:line
//! findings, and the CLI (`nmprune lint [--json] [path]`) exits with
//! bench-diff-style codes: 0 clean, 1 findings, 2 usage/IO error.
//!
//! See `docs/SAFETY.md` for the rule catalogue and suppression policy.

pub mod lexer;
pub mod rules;

pub use rules::{Finding, Rule, SUPPRESS_PREFIX, ZERO_ALLOC_MARKER};

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Lint one in-memory source file. `file` is the path label findings
/// will carry; the path-scoped rules (S1/P1/A1) match on its suffix,
/// so pass something ending in the repo-relative path.
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    rules::lint_lines(file, &lexer::lex(src))
}

/// Recursively collect `.rs` files under `dir`, skipping hidden
/// entries and build output (`target/`). Deterministic order.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        paths.push(entry.path());
    }
    paths.sort();
    for path in paths {
        let name = match path.file_name() {
            Some(n) => n.to_string_lossy().into_owned(),
            None => continue,
        };
        if name.starts_with('.') || name == "target" {
            continue;
        }
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (or `root` itself if it is a
/// file). Findings carry `/`-separated paths relative to `root`.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    if root.is_file() {
        files.push(root.to_path_buf());
    } else if root.is_dir() {
        collect_rs_files(root, &mut files)?;
    } else {
        return Err(format!("no such path: {}", root.display()));
    }
    let mut findings = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let label: String = match path.strip_prefix(root) {
            Ok(rel) if rel.as_os_str().is_empty() => path.to_string_lossy().into_owned(),
            Ok(rel) => rel.to_string_lossy().into_owned(),
            Err(_) => path.to_string_lossy().into_owned(),
        };
        let label = label.replace('\\', "/");
        findings.extend(lint_source(&label, &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule.id()).cmp(&(&b.file, b.line, b.rule.id())));
    Ok(findings)
}

/// Human-readable report: one `file:line: [RULE] message` block per
/// finding with the offending line indented beneath, then a summary.
pub fn render_text(findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule.id(), f.message));
        if !f.snippet.is_empty() {
            s.push_str(&format!("    {}\n", f.snippet));
        }
    }
    if findings.is_empty() {
        s.push_str("lint: clean\n");
    } else {
        s.push_str(&format!("lint: {} finding(s)\n", findings.len()));
    }
    s
}

/// Machine-readable report for CI, rendered with the crate's own JSON
/// writer (schema_version 1).
pub fn render_json(root: &str, findings: &[Finding]) -> String {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            Json::Obj(vec![
                ("file".into(), Json::Str(f.file.clone())),
                ("line".into(), Json::Num(f.line as f64)),
                ("rule".into(), Json::Str(f.rule.id().into())),
                ("message".into(), Json::Str(f.message.clone())),
                ("snippet".into(), Json::Str(f.snippet.clone())),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema_version".into(), Json::Num(1.0)),
        ("root".into(), Json::Str(root.to_string())),
        ("count".into(), Json::Num(findings.len() as f64)),
        ("findings".into(), Json::Arr(items)),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_json_roundtrips_through_parser() {
        let findings = lint_source("src/x.rs", "unsafe fn f() {}\n");
        assert_eq!(findings.len(), 1);
        let text = render_json(".", &findings);
        let parsed = Json::parse(&text).expect("lint JSON must parse");
        assert_eq!(parsed.get("count").and_then(Json::as_f64), Some(1.0));
        let arr = parsed.get("findings").and_then(Json::as_arr).expect("findings array");
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("rule").and_then(Json::as_str), Some("U1"));
        assert_eq!(arr[0].get("line").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn render_text_reports_clean_and_findings() {
        assert_eq!(render_text(&[]), "lint: clean\n");
        let findings = lint_source("src/x.rs", "unsafe fn f() {}\n");
        let text = render_text(&findings);
        assert!(text.contains("src/x.rs:1: [U1]"));
        assert!(text.contains("lint: 1 finding(s)"));
    }
}
