//! Machine-readable bench records: the `BENCH_*.json` trajectory layer.
//!
//! Every bench target prints human markdown tables; this module gives
//! the same measurements a versioned, parseable second life. When
//! `NMPRUNE_BENCH_JSON=<path>` is set, a [`Reporter`] accumulates one
//! [`BenchRecord`] per measured case — bench name, case label,
//! `(LMUL, tile, threads)` configuration, the full nanosecond
//! [`Summary`], effective GFLOP/s, and %-of-peak against the
//! [`super::hardware`] roofline probe — and writes one [`Report`]
//! document on [`Reporter::finish`]. With the variable unset the
//! reporter is inert and table output is byte-identical to before.
//!
//! The emitted files are the repo's perf trajectory: `BENCH_<PR>.json`
//! snapshots are committed per PR and compared by
//! `nmprune bench-diff <old> <new>` (see [`diff_reports`]), which CI
//! runs against the quick profile to catch kernel regressions.
//!
//! JSON emit/parse is hand-rolled on [`crate::util::json`] — the
//! offline crate set has no serde, matching how `util` hand-rolls its
//! other substrates.

use std::path::{Path, PathBuf};

use super::hardware::{self, HwProfile};
use crate::gemm::KernelId;
use crate::tensor::Dtype;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Version stamp written into every document. Bump when a field
/// changes meaning; [`Report::from_json`] rejects mismatched files
/// (a wrong-version trajectory silently diffed would be worse than an
/// error).
pub const SCHEMA_VERSION: usize = 1;

/// The `(LMUL, tile, threads, kernel)` template configuration a record
/// was measured at; `0` in any numeric position means "not applicable
/// / uncapped", and [`KernelId::Auto`] means "runtime dispatch /
/// unspecified". Part of the record identity: `bench-diff` only
/// compares records whose configurations match exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct RecordConfig {
    /// RVV LMUL (strip width / 8 on the 256-bit machine); 0 = n/a.
    pub lmul: usize,
    /// Micro-kernel tile height T; 0 = n/a.
    pub tile: usize,
    /// Parallelism degree (pool workers); 0 = n/a or single-threaded.
    pub threads: usize,
    /// Micro-kernel backend the case was pinned to; Auto = the
    /// dispatcher's choice (what every record was before the kernel
    /// dimension existed — Auto is omitted from keys and JSON so
    /// historical snapshots keep their identities).
    pub kernel: KernelId,
    /// Compute dtype the case ran at; F32 = the historical default and
    /// is omitted from keys and JSON (same compatibility scheme as
    /// `kernel`). I8 records normalize against the int8 roofline.
    pub dtype: Dtype,
}

impl RecordConfig {
    /// No template parameters apply (e.g. end-to-end serving rows).
    pub const NONE: RecordConfig = RecordConfig {
        lmul: 0,
        tile: 0,
        threads: 0,
        kernel: KernelId::Auto,
        dtype: Dtype::F32,
    };

    /// Convenience constructor in `(lmul, tile, threads)` order
    /// (kernel = Auto, dtype = F32; chain [`RecordConfig::with_kernel`]
    /// / [`RecordConfig::with_dtype`] to pin them).
    pub fn new(lmul: usize, tile: usize, threads: usize) -> Self {
        Self {
            lmul,
            tile,
            threads,
            kernel: KernelId::Auto,
            dtype: Dtype::F32,
        }
    }

    /// Same configuration pinned to a specific micro-kernel backend.
    pub fn with_kernel(mut self, kernel: KernelId) -> Self {
        self.kernel = kernel;
        self
    }

    /// Same configuration at a specific compute dtype.
    pub fn with_dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self
    }
}

/// One measured case, roofline-normalized where FLOPs are known.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Suite (bench target) name, e.g. `perf_hotpath`.
    pub bench: String,
    /// Case label within the suite, e.g. `gemm_dense 64x576x3136`.
    pub case: String,
    /// Template configuration the case was measured at.
    pub config: RecordConfig,
    /// Unit of the summary samples: `ns`, `cycles`, `percent`,
    /// `ratio`, or `rps`. `ns` and `cycles` are lower-is-better;
    /// everything else is higher-is-better.
    pub unit: String,
    /// Sample statistics in `unit` (deterministic metrics are stored
    /// as a single-sample summary).
    pub summary: Summary,
    /// Effective GFLOP/s (executed FLOPs / median ns), when known.
    pub gflops: Option<f64>,
    /// `100 × gflops / peak` for this record's thread count, when the
    /// hardware probe ran *and* the probed peak was positive and
    /// finite — a degenerate peak drops the field rather than
    /// poisoning the trajectory with Inf/NaN.
    pub pct_of_peak: Option<f64>,
    /// True when the record measured above the probed roofline
    /// (`pct_of_peak > 100`) — a probe-understating-the-machine signal
    /// that is flagged rather than silently emitted.
    pub over_peak: bool,
    /// Whether `bench-diff` may fail the build on this record. Noisy
    /// end-to-end observables (serving throughput/latency) are
    /// recorded for the trajectory but never gate.
    pub gate: bool,
}

impl BenchRecord {
    /// Identity used by [`diff_reports`] to match records across runs.
    /// The kernel field appears only when pinned (non-Auto), so
    /// records from snapshots predating the kernel dimension keep
    /// their identities and stay diffable.
    pub fn key(&self) -> String {
        let kernel = if self.config.kernel == KernelId::Auto {
            String::new()
        } else {
            format!(" kernel={}", self.config.kernel.name())
        };
        let dtype = if self.config.dtype == Dtype::F32 {
            String::new()
        } else {
            format!(" dtype={}", self.config.dtype.name())
        };
        format!(
            "{}::{} [lmul={} tile={} threads={}{kernel}{dtype}]",
            self.bench,
            self.case,
            self.config.lmul,
            self.config.tile,
            self.config.threads
        )
    }

    /// Whether smaller summary values are better for this unit.
    pub fn lower_is_better(&self) -> bool {
        matches!(self.unit.as_str(), "ns" | "cycles")
    }
}

/// A full bench-run document: schema version, suite, the probing
/// machine's roofline, and the records.
#[derive(Clone, Debug)]
pub struct Report {
    /// Always [`SCHEMA_VERSION`] for documents this build writes.
    pub schema_version: usize,
    /// Suite (bench target) that produced the document.
    pub suite: String,
    /// Roofline probe of the machine that ran the suite, when probed.
    pub hardware: Option<HwProfile>,
    /// One entry per measured case.
    pub records: Vec<BenchRecord>,
}

impl Report {
    /// An empty report for `suite` (no hardware probe attached).
    pub fn new(suite: &str) -> Self {
        Self {
            schema_version: SCHEMA_VERSION,
            suite: suite.to_string(),
            hardware: None,
            records: Vec::new(),
        }
    }

    /// Serialise to the JSON document model.
    pub fn to_json(&self) -> Json {
        let version = self.schema_version as f64;
        let mut top = vec![
            ("schema_version".into(), Json::Num(version)),
            ("suite".into(), Json::Str(self.suite.clone())),
        ];
        if let Some(hw) = &self.hardware {
            top.push((
                "hardware".into(),
                Json::Obj(vec![
                    ("threads".into(), Json::Num(hw.threads as f64)),
                    ("scalar_gflops".into(), Json::Num(hw.scalar_gflops)),
                    ("fma_gflops".into(), Json::Num(hw.fma_gflops)),
                    ("aggregate_gflops".into(), Json::Num(hw.aggregate_gflops)),
                    ("i8_gops".into(), Json::Num(hw.i8_gops)),
                ]),
            ));
        }
        let records = self.records.iter().map(record_to_json).collect();
        top.push(("records".into(), Json::Arr(records)));
        Json::Obj(top)
    }

    /// Render the document as pretty-printed JSON text.
    pub fn render(&self) -> String {
        self.to_json().render()
    }

    /// Rebuild a report from a parsed JSON document.
    pub fn from_json(v: &Json) -> Result<Report, String> {
        let version = v
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or("missing schema_version")?;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} (this build reads {SCHEMA_VERSION})"
            ));
        }
        let suite = v
            .get("suite")
            .and_then(Json::as_str)
            .ok_or("missing suite")?
            .to_string();
        let hardware = match v.get("hardware") {
            None | Some(Json::Null) => None,
            Some(h) => Some(HwProfile {
                threads: h
                    .get("threads")
                    .and_then(Json::as_usize)
                    .ok_or("hardware.threads")?,
                scalar_gflops: num_field(h, "scalar_gflops")?,
                fma_gflops: num_field(h, "fma_gflops")?,
                aggregate_gflops: num_field(h, "aggregate_gflops")?,
                // Absent in snapshots predating the int8 plane; 0.0
                // keeps them loadable (a zero peak drops pct_of_peak
                // for i8 records, never poisons the diff).
                i8_gops: h.get("i8_gops").and_then(Json::as_f64).unwrap_or(0.0),
            }),
        };
        let records = v
            .get("records")
            .and_then(Json::as_arr)
            .ok_or("missing records array")?
            .iter()
            .map(record_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Report {
            schema_version: version,
            suite,
            hardware,
            records,
        })
    }

    /// Parse a report from JSON text.
    pub fn parse(text: &str) -> Result<Report, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }

    /// Read and parse a report file.
    pub fn load(path: &Path) -> Result<Report, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Write the rendered document (parent directories created).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.render())
    }
}

fn num_field(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing numeric field {key:?}"))
}

fn record_to_json(r: &BenchRecord) -> Json {
    let mut config = vec![
        ("lmul".into(), Json::Num(r.config.lmul as f64)),
        ("tile".into(), Json::Num(r.config.tile as f64)),
        ("threads".into(), Json::Num(r.config.threads as f64)),
    ];
    // Auto is the historical default and is omitted so documents from
    // builds predating the kernel dimension stay byte-comparable.
    if r.config.kernel != KernelId::Auto {
        config.push((
            "kernel".into(),
            Json::Str(r.config.kernel.name().to_string()),
        ));
    }
    // Same scheme for dtype: F32 (the historical default) is omitted.
    if r.config.dtype != Dtype::F32 {
        config.push(("dtype".into(), Json::Str(r.config.dtype.name().to_string())));
    }
    let mut pairs = vec![
        ("bench".into(), Json::Str(r.bench.clone())),
        ("case".into(), Json::Str(r.case.clone())),
        ("config".into(), Json::Obj(config)),
        ("unit".into(), Json::Str(r.unit.clone())),
        ("gate".into(), Json::Bool(r.gate)),
        (
            "summary".into(),
            Json::Obj(vec![
                ("n".into(), Json::Num(r.summary.n as f64)),
                ("mean".into(), Json::Num(r.summary.mean)),
                ("stddev".into(), Json::Num(r.summary.stddev)),
                ("min".into(), Json::Num(r.summary.min)),
                ("max".into(), Json::Num(r.summary.max)),
                ("median".into(), Json::Num(r.summary.median)),
                ("p5".into(), Json::Num(r.summary.p5)),
                ("p95".into(), Json::Num(r.summary.p95)),
            ]),
        ),
    ];
    if let Some(g) = r.gflops {
        pairs.push(("gflops".into(), Json::Num(g)));
    }
    if let Some(p) = r.pct_of_peak {
        pairs.push(("pct_of_peak".into(), Json::Num(p)));
    }
    // Emitted only when set: historical documents stay byte-identical.
    if r.over_peak {
        pairs.push(("over_peak".into(), Json::Bool(true)));
    }
    Json::Obj(pairs)
}

fn record_from_json(v: &Json) -> Result<BenchRecord, String> {
    let cfg = v.get("config").ok_or("record missing config")?;
    let s = v.get("summary").ok_or("record missing summary")?;
    Ok(BenchRecord {
        bench: v
            .get("bench")
            .and_then(Json::as_str)
            .ok_or("record missing bench")?
            .to_string(),
        case: v
            .get("case")
            .and_then(Json::as_str)
            .ok_or("record missing case")?
            .to_string(),
        config: RecordConfig {
            lmul: cfg.get("lmul").and_then(Json::as_usize).unwrap_or(0),
            tile: cfg.get("tile").and_then(Json::as_usize).unwrap_or(0),
            threads: cfg.get("threads").and_then(Json::as_usize).unwrap_or(0),
            // Absent or unrecognised → Auto (tolerant: a newer file on
            // an older build degrades to the dispatch default).
            kernel: cfg
                .get("kernel")
                .and_then(Json::as_str)
                .and_then(KernelId::from_name)
                .unwrap_or(KernelId::Auto),
            dtype: cfg
                .get("dtype")
                .and_then(Json::as_str)
                .and_then(Dtype::from_name)
                .unwrap_or(Dtype::F32),
        },
        unit: v
            .get("unit")
            .and_then(Json::as_str)
            .unwrap_or("ns")
            .to_string(),
        summary: Summary {
            n: s.get("n").and_then(Json::as_usize).unwrap_or(0),
            mean: num_field(s, "mean")?,
            stddev: num_field(s, "stddev")?,
            min: num_field(s, "min")?,
            max: num_field(s, "max")?,
            median: num_field(s, "median")?,
            p5: num_field(s, "p5")?,
            p95: num_field(s, "p95")?,
        },
        gflops: v.get("gflops").and_then(Json::as_f64),
        pct_of_peak: v.get("pct_of_peak").and_then(Json::as_f64),
        over_peak: v.get("over_peak").and_then(Json::as_bool).unwrap_or(false),
        gate: v.get("gate").and_then(Json::as_bool).unwrap_or(true),
    })
}

// ----------------------------------------------------------------------
// Reporter: the env-gated accumulator the bench targets talk to.

/// Accumulates [`BenchRecord`]s during a bench run and writes one
/// [`Report`] at the end — active only when `NMPRUNE_BENCH_JSON=<path>`
/// is set, so plain table runs pay nothing (not even the hardware
/// probe).
pub struct Reporter {
    out: Option<(PathBuf, Report)>,
}

impl Reporter {
    /// Build from the environment: inert unless `NMPRUNE_BENCH_JSON`
    /// names an output path. When active, the [`hardware`] roofline
    /// probe runs once (memoised) so records can be %-of-peak
    /// normalized.
    pub fn from_env(suite: &str) -> Self {
        let out = std::env::var_os("NMPRUNE_BENCH_JSON").map(|p| {
            let mut report = Report::new(suite);
            report.hardware = Some(*hardware::probe());
            (PathBuf::from(p), report)
        });
        Reporter { out }
    }

    /// Whether records are being collected this run.
    pub fn active(&self) -> bool {
        self.out.is_some()
    }

    /// Record a wall-clock measurement (unit `ns`, gating). When
    /// `flops` (executed FLOPs per iteration) is given, the record
    /// carries effective GFLOP/s (`flops / median ns`) and %-of-peak
    /// for `config.threads` workers against `config.dtype`'s roofline.
    pub fn record(
        &mut self,
        case: &str,
        config: RecordConfig,
        summary: &Summary,
        flops: Option<f64>,
    ) {
        let Some((_, report)) = self.out.as_mut() else {
            return;
        };
        let gflops = match flops {
            Some(f) if summary.median > 0.0 => Some(f / summary.median),
            _ => None,
        };
        // Guard the normalization: a zero/negative/non-finite peak
        // (possible if the probe misbehaves on an exotic host) must
        // drop pct_of_peak — an Inf/NaN here poisons every later
        // bench-diff of the file. gflops is kept either way.
        let pct_of_peak = gflops.and_then(|g| {
            let peak = report
                .hardware
                .as_ref()
                .expect("active reporter probes hardware")
                .peak_gops(config.threads, config.dtype);
            if peak.is_finite() && peak > 0.0 {
                Some(100.0 * g / peak)
            } else {
                None
            }
        });
        let bench = report.suite.clone();
        report.records.push(BenchRecord {
            bench,
            case: case.to_string(),
            config,
            unit: "ns".to_string(),
            summary: summary.clone(),
            gflops,
            pct_of_peak,
            // Above the probed roofline: flagged, never silent.
            over_peak: pct_of_peak.is_some_and(|p| p > 100.0),
            gate: true,
        });
    }

    /// Record a single-valued metric (simulator cycles, ratios,
    /// percentages, serving throughput). `gate = false` marks noisy
    /// observables that the trajectory tracks but `bench-diff` must
    /// not fail the build on.
    pub fn record_value(
        &mut self,
        case: &str,
        config: RecordConfig,
        value: f64,
        unit: &str,
        gate: bool,
    ) {
        let Some((_, report)) = self.out.as_mut() else {
            return;
        };
        let bench = report.suite.clone();
        report.records.push(BenchRecord {
            bench,
            case: case.to_string(),
            config,
            unit: unit.to_string(),
            summary: Summary::of(&[value]),
            gflops: None,
            pct_of_peak: None,
            over_peak: false,
            gate,
        });
    }

    /// Write the accumulated report (no-op when inert). Prints a
    /// one-line confirmation to stderr so table output stays clean.
    pub fn finish(self) {
        let Some((path, report)) = self.out else {
            return;
        };
        match report.save(&path) {
            Ok(()) => eprintln!(
                "bench json: wrote {} records to {}",
                report.records.len(),
                path.display()
            ),
            Err(e) => eprintln!("bench json: FAILED writing {}: {e}", path.display()),
        }
    }
}

// ----------------------------------------------------------------------
// bench-diff: regression gating between two reports.

/// Classification of one compared record pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffStatus {
    /// Worse than the threshold allows.
    Regression,
    /// Better by more than the threshold.
    Improvement,
    /// Within the threshold either way.
    Unchanged,
    /// Present only in the old report (case removed or skipped).
    OnlyOld,
    /// Present only in the new report (case added).
    OnlyNew,
}

/// One row of a [`DiffReport`].
#[derive(Clone, Debug)]
pub struct DiffEntry {
    /// Record identity ([`BenchRecord::key`]).
    pub key: String,
    /// What was compared: `%peak` when both sides carry roofline
    /// normalization (machine-portable), otherwise the record unit
    /// compared on the summary median.
    pub metric: String,
    /// Old-side value of `metric` (0 for [`DiffStatus::OnlyNew`]).
    pub old: f64,
    /// New-side value of `metric` (0 for [`DiffStatus::OnlyOld`]).
    pub new: f64,
    /// Signed relative change in percent; positive is improvement.
    pub delta_pct: f64,
    /// Whether both sides allow gating (see [`BenchRecord::gate`]).
    pub gated: bool,
    /// Classification against the threshold.
    pub status: DiffStatus,
}

/// Result of comparing two reports.
#[derive(Clone, Debug)]
pub struct DiffReport {
    /// Relative threshold (percent) separating noise from signal.
    pub threshold_pct: f64,
    /// One entry per record key present in either report, old-report
    /// order first, then new-only keys.
    pub entries: Vec<DiffEntry>,
}

impl DiffReport {
    /// Gated regressions — the count that fails a build.
    pub fn regressions(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.status == DiffStatus::Regression && e.gated)
            .count()
    }

    /// Gated improvements beyond the threshold.
    pub fn improvements(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.status == DiffStatus::Improvement && e.gated)
            .count()
    }

    /// Whether `bench-diff` should exit nonzero.
    pub fn has_regressions(&self) -> bool {
        self.regressions() > 0
    }
}

/// Compare two reports record-by-record. Records match on
/// `(bench, case, config)` — a config change is a different record
/// (reported as removed + added), never a false regression. Matched
/// pairs compare on `%-of-peak` when both sides have it (normalized by
/// each machine's own roofline, so snapshots from different hosts stay
/// comparable), else on the summary median in the record's unit with
/// the unit's better-direction. Only pairs gated on *both* sides can
/// count as regressions.
pub fn diff_reports(old: &Report, new: &Report, threshold_pct: f64) -> DiffReport {
    use std::collections::{BTreeMap, BTreeSet};
    let new_by_key: BTreeMap<String, &BenchRecord> =
        new.records.iter().map(|r| (r.key(), r)).collect();
    let old_keys: BTreeSet<String> = old.records.iter().map(|r| r.key()).collect();

    let mut entries = Vec::new();
    for o in &old.records {
        let key = o.key();
        match new_by_key.get(&key) {
            None => entries.push(DiffEntry {
                key,
                metric: o.unit.clone(),
                old: o.summary.median,
                new: 0.0,
                delta_pct: 0.0,
                gated: false,
                status: DiffStatus::OnlyOld,
            }),
            Some(n) => entries.push(compare_pair(o, n, threshold_pct)),
        }
    }
    for n in &new.records {
        let key = n.key();
        if !old_keys.contains(&key) {
            entries.push(DiffEntry {
                key,
                metric: n.unit.clone(),
                old: 0.0,
                new: n.summary.median,
                delta_pct: 0.0,
                gated: false,
                status: DiffStatus::OnlyNew,
            });
        }
    }
    DiffReport {
        threshold_pct,
        entries,
    }
}

fn compare_pair(o: &BenchRecord, n: &BenchRecord, threshold_pct: f64) -> DiffEntry {
    // Prefer the roofline-normalized view; fall back to the raw median.
    let (metric, old_v, new_v, higher_is_better) = match (o.pct_of_peak, n.pct_of_peak) {
        (Some(a), Some(b)) => ("%peak".to_string(), a, b, true),
        _ => (
            o.unit.clone(),
            o.summary.median,
            n.summary.median,
            !o.lower_is_better(),
        ),
    };
    let delta_pct = if old_v.abs() > f64::EPSILON {
        let raw = (new_v - old_v) / old_v.abs() * 100.0;
        if higher_is_better {
            raw
        } else {
            -raw
        }
    } else {
        0.0
    };
    let status = if delta_pct < -threshold_pct {
        DiffStatus::Regression
    } else if delta_pct > threshold_pct {
        DiffStatus::Improvement
    } else {
        DiffStatus::Unchanged
    };
    DiffEntry {
        key: o.key(),
        metric,
        old: old_v,
        new: new_v,
        delta_pct,
        gated: o.gate && n.gate,
        status,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(case: &str, median: f64, pct: Option<f64>) -> BenchRecord {
        BenchRecord {
            bench: "suite".into(),
            case: case.into(),
            config: RecordConfig::new(2, 8, 1),
            unit: "ns".into(),
            summary: Summary::of(&[median]),
            gflops: pct.map(|_| 1.0),
            pct_of_peak: pct,
            over_peak: false,
            gate: true,
        }
    }

    fn report_with(records: Vec<BenchRecord>) -> Report {
        Report {
            schema_version: SCHEMA_VERSION,
            suite: "suite".into(),
            hardware: None,
            records,
        }
    }

    #[test]
    fn self_diff_is_clean() {
        let records = vec![record("a", 100.0, Some(40.0)), record("b", 5.0, None)];
        let r = report_with(records);
        let d = diff_reports(&r, &r, 10.0);
        assert_eq!(d.regressions(), 0);
        assert!(!d.has_regressions());
        assert!(d.entries.iter().all(|e| e.status == DiffStatus::Unchanged));
    }

    #[test]
    fn pct_of_peak_is_preferred_and_directional() {
        // %-of-peak fell 50 → 30: a 40% regression even though raw ns
        // (the fallback metric) also changed.
        let old = report_with(vec![record("k", 100.0, Some(50.0))]);
        let new = report_with(vec![record("k", 100.0, Some(30.0))]);
        let d = diff_reports(&old, &new, 10.0);
        assert_eq!(d.entries.len(), 1);
        let e = &d.entries[0];
        assert_eq!(e.metric, "%peak");
        assert_eq!(e.status, DiffStatus::Regression);
        assert!((e.delta_pct + 40.0).abs() < 1e-9);
        assert!(d.has_regressions());
        // The reverse direction is an improvement.
        let d = diff_reports(&new, &old, 10.0);
        assert_eq!(d.entries[0].status, DiffStatus::Improvement);
        assert!(!d.has_regressions());
    }

    #[test]
    fn ns_fallback_treats_slower_as_regression() {
        let old = report_with(vec![record("k", 100.0, None)]);
        let new = report_with(vec![record("k", 125.0, None)]);
        let d = diff_reports(&old, &new, 10.0);
        assert_eq!(d.entries[0].metric, "ns");
        assert_eq!(d.entries[0].status, DiffStatus::Regression);
        assert!((d.entries[0].delta_pct + 25.0).abs() < 1e-9);
        // 25% slower under a 30% threshold is within noise.
        assert!(!diff_reports(&old, &new, 30.0).has_regressions());
    }

    #[test]
    fn higher_is_better_units_invert_direction() {
        let mut o = record("serve", 100.0, None);
        o.unit = "rps".into();
        let mut n = o.clone();
        n.summary = Summary::of(&[150.0]);
        let d = diff_reports(&report_with(vec![o]), &report_with(vec![n]), 10.0);
        assert_eq!(d.entries[0].status, DiffStatus::Improvement);
    }

    #[test]
    fn config_change_is_add_plus_remove_not_a_regression() {
        let old = report_with(vec![record("k", 100.0, Some(50.0))]);
        let mut moved = record("k", 300.0, Some(10.0));
        moved.config.threads = 4;
        let new = report_with(vec![moved]);
        let d = diff_reports(&old, &new, 10.0);
        assert_eq!(d.entries.len(), 2);
        assert!(d.entries.iter().any(|e| e.status == DiffStatus::OnlyOld));
        assert!(d.entries.iter().any(|e| e.status == DiffStatus::OnlyNew));
        assert!(!d.has_regressions());
    }

    #[test]
    fn ungated_records_never_fail_the_diff() {
        let mut o = record("serve p95", 100.0, None);
        o.gate = false;
        let mut n = o.clone();
        n.summary = Summary::of(&[1000.0]);
        let d = diff_reports(&report_with(vec![o]), &report_with(vec![n]), 10.0);
        assert_eq!(d.entries[0].status, DiffStatus::Regression);
        assert!(!d.entries[0].gated);
        assert_eq!(d.regressions(), 0);
        assert!(!d.has_regressions());
    }

    #[test]
    fn report_json_roundtrip_preserves_everything() {
        let records = vec![record("a", 123.456, Some(41.5)), record("b", 7.0, None)];
        let mut r = report_with(records);
        r.hardware = Some(HwProfile {
            threads: 8,
            scalar_gflops: 1.25,
            fma_gflops: 9.5,
            aggregate_gflops: 40.0,
            i8_gops: 22.0,
        });
        r.records[1].unit = "cycles".into();
        r.records[1].gate = false;
        // An explicitly empty summary (n = 0) must survive the trip.
        r.records.push(BenchRecord {
            bench: "suite".into(),
            case: "empty".into(),
            config: RecordConfig::NONE,
            unit: "ns".into(),
            summary: Summary::empty(),
            gflops: None,
            pct_of_peak: None,
            over_peak: false,
            gate: true,
        });
        // A kernel-pinned, over-peak record must survive the trip too.
        let mut pinned = record("pinned", 10.0, Some(104.0));
        pinned.config = pinned.config.with_kernel(KernelId::Avx2);
        pinned.over_peak = true;
        r.records.push(pinned);
        // ... and a dtype-pinned one.
        let mut quant = record("quant", 10.0, Some(33.0));
        quant.config = quant.config.with_dtype(Dtype::I8);
        r.records.push(quant);
        let text = r.render();
        let back = Report::parse(&text).unwrap();
        assert_eq!(back.schema_version, SCHEMA_VERSION);
        assert_eq!(back.suite, r.suite);
        let hw = back.hardware.unwrap();
        assert_eq!(hw.threads, 8);
        assert_eq!(hw.fma_gflops, 9.5);
        assert_eq!(hw.i8_gops, 22.0);
        assert_eq!(back.records.len(), r.records.len());
        for (a, b) in back.records.iter().zip(&r.records) {
            assert_eq!(a.key(), b.key());
            assert_eq!(a.unit, b.unit);
            assert_eq!(a.gate, b.gate);
            assert_eq!(a.summary, b.summary);
            assert_eq!(a.gflops, b.gflops);
            assert_eq!(a.pct_of_peak, b.pct_of_peak);
            assert_eq!(a.over_peak, b.over_peak);
            assert_eq!(a.config, b.config);
        }
        // A round-tripped report self-diffs clean.
        assert!(!diff_reports(&r, &back, 0.001).has_regressions());
    }

    /// Bugfix: a degenerate roofline (zero or non-finite peak) must
    /// drop pct_of_peak — not emit Inf/NaN into the trajectory file.
    /// gflops survives; the over-peak flag stays clear.
    #[test]
    fn degenerate_peak_drops_pct_of_peak_keeps_gflops() {
        for (scalar, fma, agg) in [
            (0.0, 0.0, 0.0),
            (1.0, f64::NAN, f64::NAN),
            (1.0, f64::INFINITY, f64::INFINITY),
            (1.0, -2.0, -2.0),
        ] {
            let mut report = Report::new("suite");
            report.hardware = Some(HwProfile {
                threads: 1,
                scalar_gflops: scalar,
                fma_gflops: fma,
                aggregate_gflops: agg,
                i8_gops: 0.0,
            });
            let mut rep = Reporter {
                out: Some((PathBuf::from("/tmp/unused.json"), report)),
            };
            let s = Summary::of(&[100.0]);
            rep.record("case", RecordConfig::new(1, 8, 1), &s, Some(1000.0));
            let rec = &rep.out.as_ref().unwrap().1.records[0];
            assert_eq!(rec.gflops, Some(10.0), "gflops must survive");
            assert_eq!(rec.pct_of_peak, None, "peak {fma} must drop pct");
            assert!(!rec.over_peak);
            // The emitted document parses back cleanly.
            let text = rep.out.as_ref().unwrap().1.render();
            assert!(Report::parse(&text).is_ok());
        }
    }

    /// A measurement above the probed roofline is flagged, not silent.
    #[test]
    fn over_peak_measurements_are_flagged() {
        let mut report = Report::new("suite");
        report.hardware = Some(HwProfile {
            threads: 1,
            scalar_gflops: 1.0,
            fma_gflops: 5.0,
            aggregate_gflops: 5.0,
            i8_gops: 5.0,
        });
        let mut rep = Reporter {
            out: Some((PathBuf::from("/tmp/unused.json"), report)),
        };
        let s = Summary::of(&[100.0]);
        // 10 GFLOP/s against a 5 GFLOP/s roofline → 200% of peak.
        rep.record("hot", RecordConfig::new(1, 8, 1), &s, Some(1000.0));
        // 2.5 GFLOP/s → 50% of peak: not flagged.
        rep.record("cool", RecordConfig::new(1, 8, 1), &s, Some(250.0));
        let records = &rep.out.as_ref().unwrap().1.records;
        assert!(records[0].over_peak);
        assert_eq!(records[0].pct_of_peak, Some(200.0));
        assert!(!records[1].over_peak);
    }

    /// Kernel-pinned records get distinct identities; Auto records keep
    /// the historical key format so old snapshots stay diffable.
    #[test]
    fn kernel_appears_in_key_only_when_pinned() {
        let auto = record("k", 1.0, None);
        assert_eq!(auto.key(), "suite::k [lmul=2 tile=8 threads=1]");
        let mut pinned = record("k", 1.0, None);
        pinned.config = pinned.config.with_kernel(KernelId::Scalar);
        assert_eq!(
            pinned.key(),
            "suite::k [lmul=2 tile=8 threads=1 kernel=scalar]"
        );
        assert_ne!(auto.key(), pinned.key());
    }

    /// Int8 records get distinct identities; F32 records keep the
    /// historical key format so old snapshots stay diffable.
    #[test]
    fn dtype_appears_in_key_only_when_i8() {
        let f32rec = record("k", 1.0, None);
        assert_eq!(f32rec.key(), "suite::k [lmul=2 tile=8 threads=1]");
        let mut quant = record("k", 1.0, None);
        quant.config = quant.config.with_dtype(Dtype::I8);
        assert_eq!(quant.key(), "suite::k [lmul=2 tile=8 threads=1 dtype=i8]");
        assert_ne!(f32rec.key(), quant.key());
    }

    /// Int8 records normalize against the int8 roofline, not the f32
    /// one — and a snapshot predating the i8 probe (i8_gops absent →
    /// 0.0) drops pct_of_peak for i8 records instead of emitting Inf.
    #[test]
    fn i8_records_normalize_against_the_i8_peak() {
        let mut report = Report::new("suite");
        report.hardware = Some(HwProfile {
            threads: 1,
            scalar_gflops: 1.0,
            fma_gflops: 5.0,
            aggregate_gflops: 5.0,
            i8_gops: 20.0,
        });
        let mut rep = Reporter {
            out: Some((PathBuf::from("/tmp/unused.json"), report)),
        };
        let s = Summary::of(&[100.0]);
        // 10 Gop/s: 200% of the 5 GFLOP/s f32 peak, but 50% of the
        // 20 Gop/s i8 peak.
        let cfg = RecordConfig::new(1, 8, 1).with_dtype(Dtype::I8);
        rep.record("quant", cfg, &s, Some(1000.0));
        let rec = &rep.out.as_ref().unwrap().1.records[0];
        assert_eq!(rec.pct_of_peak, Some(50.0));
        assert!(!rec.over_peak);

        let mut legacy = Report::new("suite");
        legacy.hardware = Some(HwProfile {
            threads: 1,
            scalar_gflops: 1.0,
            fma_gflops: 5.0,
            aggregate_gflops: 5.0,
            i8_gops: 0.0,
        });
        let mut rep = Reporter {
            out: Some((PathBuf::from("/tmp/unused.json"), legacy)),
        };
        rep.record("quant", cfg, &s, Some(1000.0));
        let rec = &rep.out.as_ref().unwrap().1.records[0];
        assert_eq!(rec.gflops, Some(10.0));
        assert_eq!(rec.pct_of_peak, None);
    }

    #[test]
    fn wrong_schema_version_is_rejected() {
        let text = r#"{"schema_version": 99, "suite": "s", "records": []}"#;
        let e = Report::parse(text).unwrap_err();
        assert!(e.contains("schema_version 99"), "{e}");
    }

    #[test]
    fn malformed_documents_error_not_panic() {
        for bad in [
            "",
            "{}",
            "[]",
            r#"{"schema_version": 1}"#,
            r#"{"schema_version": 1, "suite": "s"}"#,
            r#"{"schema_version": 1, "suite": "s", "records": [{}]}"#,
        ] {
            assert!(Report::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn inert_reporter_records_nothing() {
        // NMPRUNE_BENCH_JSON is not set under `cargo test`.
        std::env::remove_var("NMPRUNE_BENCH_JSON");
        let mut rep = Reporter::from_env("suite");
        assert!(!rep.active());
        let s = Summary::of(&[1.0]);
        rep.record("case", RecordConfig::NONE, &s, Some(10.0));
        rep.record_value("v", RecordConfig::NONE, 1.0, "cycles", true);
        rep.finish(); // must not write anywhere / panic
    }
}
