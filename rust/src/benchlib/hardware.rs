//! Peak-throughput probe for roofline normalization.
//!
//! Raw nanoseconds are machine-bound: a kernel at 2.1 GFLOP/s is
//! excellent on one host and a regression on another. Following pire's
//! `hardware.rs` idiom, this module measures — at bench startup, on the
//! machine actually running the bench — what the compiler + CPU sustain
//! on the same kind of code the hot kernels are written in, so every
//! [`super::report::BenchRecord`] can carry a `%-of-peak` figure that
//! is comparable across hosts.
//!
//! Four numbers are probed:
//!
//! * **scalar** — one dependent multiply-add chain: the latency-bound
//!   floor a serial reduction pays;
//! * **fma** — the *dispatched micro-kernel layer itself*
//!   ([`crate::gemm::kernels`], best available backend) running a
//!   dense strip on an L1-resident synthetic problem. Earlier
//!   revisions timed an auto-vectorised `a * m + b` lane loop here,
//!   which understated the roofline on hosts whose native backends
//!   use real FMA instructions — kernels could then report > 100% of
//!   "peak". Probing through the same code path the records measure
//!   closes that gap by construction. The probe always uses the best
//!   *available* backend, deliberately ignoring `NMPRUNE_KERNEL`: the
//!   roofline is a machine property, not a configuration;
//! * **aggregate** — the fma probe on every available hardware thread
//!   simultaneously (barrier-started), capturing the frequency/SMT
//!   scaling loss that makes `N × single-core` an overestimate;
//! * **i8** — the same dispatched micro-kernel layer running the
//!   quantized `dense_strip_i8` path (i8×i8→i32 accumulate,
//!   requantize-to-f32 epilogue) on the same L1-resident problem, so
//!   int8 records normalize against the int8 ceiling rather than the
//!   f32 one. One multiply-add counts as 2 ops, matching the f32
//!   convention, so int8-vs-f32 %-of-peak figures are comparable.
//!
//! The probe costs ~100 ms, runs once per process (memoised), and is
//! only triggered when JSON output is requested — plain table runs
//! never pay for it.

use std::sync::{Barrier, OnceLock};
use std::time::Instant;

use crate::gemm::kernels;
use crate::im2col::{pack_data_matrix, quantize_panel_into, QuantPanel};
use crate::pruning::QuantDense;
use crate::tensor::Dtype;

/// Measured peak throughput of the probing machine.
#[derive(Clone, Copy, Debug)]
pub struct HwProfile {
    /// Hardware threads used for the aggregate probe
    /// (`available_parallelism`, not `NMPRUNE_THREADS` — the roofline
    /// is a machine property, not a configuration).
    pub threads: usize,
    /// Dependent-chain multiply-add throughput, one thread (GFLOP/s).
    pub scalar_gflops: f64,
    /// Best-available micro-kernel backend throughput on an
    /// L1-resident dense strip, one thread (GFLOP/s). The field keeps
    /// its historical name for report comparability; since the kernel
    /// dispatch layer landed it is measured through
    /// [`crate::gemm::kernels`], not a standalone lane loop.
    pub fma_gflops: f64,
    /// Sum of per-thread fma throughput with all threads running
    /// (GFLOP/s); at most `threads × fma_gflops`, typically less.
    pub aggregate_gflops: f64,
    /// Best-available micro-kernel backend int8 dense-strip throughput
    /// on the same L1-resident problem, one thread (Gop/s; one
    /// multiply-add = 2 ops, same convention as the f32 fields).
    pub i8_gops: f64,
}

impl HwProfile {
    /// Roofline for a kernel allowed `threads` workers: the single-core
    /// fma peak at 1, the measured aggregate at full occupancy, and a
    /// linear interpolation between the two endpoints in between (both
    /// are measurements, so the estimate never extrapolates). `0` means
    /// "uncapped" and maps to one thread — single-thread records are
    /// the common case in the figure benches.
    pub fn peak_gflops(&self, threads: usize) -> f64 {
        let t = threads.max(1).min(self.threads.max(1));
        if t == 1 || self.threads <= 1 {
            return self.fma_gflops;
        }
        let frac = (t - 1) as f64 / (self.threads - 1) as f64;
        self.fma_gflops + (self.aggregate_gflops - self.fma_gflops) * frac
    }

    /// Dtype-aware roofline: f32 records use [`Self::peak_gflops`]
    /// directly; int8 records scale the single-thread i8 peak by the
    /// *measured f32 multi-thread curve* (`peak_gflops(t) /
    /// fma_gflops`). The i8 aggregate is not probed separately —
    /// contention scaling is dominated by frequency/SMT effects that
    /// are dtype-independent, and a second barrier probe would double
    /// the startup cost for a second-order correction.
    pub fn peak_gops(&self, threads: usize, dtype: Dtype) -> f64 {
        match dtype {
            Dtype::F32 => self.peak_gflops(threads),
            Dtype::I8 => self.i8_gops * (self.peak_gflops(threads) / self.fma_gflops.max(1e-12)),
        }
    }
}

/// The process-wide memoised probe result.
pub fn probe() -> &'static HwProfile {
    static PROFILE: OnceLock<HwProfile> = OnceLock::new();
    PROFILE.get_or_init(measure)
}

/// Multiplier/addend chosen so the iteration `a = a * M + B` converges
/// to `B / (1 - M)` = 0.1: accumulators stay normal (no denormal or
/// overflow stalls distorting the measurement) for any iteration count.
const M: f32 = 0.999_999;
const B: f32 = 1.0e-7;

/// Kernel-probe problem: an 8-row tile over a full-width strip with a
/// 64-deep reduction — ~20 KB of working set (weights + one packed
/// strip + output), L1-resident on any target, compute-bound.
const PROBE_ROWS: usize = 8;
const PROBE_K: usize = 64;
const PROBE_V: usize = 64;

fn measure() -> HwProfile {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let scalar_iters = calibrate(run_scalar);
    let kernel_iters = calibrate(run_kernel);
    let i8_iters = calibrate(run_kernel_i8);
    HwProfile {
        threads,
        scalar_gflops: best_of(3, || scalar_flops(scalar_iters) / run_scalar(scalar_iters)),
        fma_gflops: best_of(3, || kernel_flops(kernel_iters) / run_kernel(kernel_iters)),
        aggregate_gflops: best_of(2, || run_aggregate(threads, kernel_iters)),
        i8_gops: best_of(3, || kernel_flops(i8_iters) / run_kernel_i8(i8_iters)),
    }
}

/// Peak means *best observed*: take the max over `n` trials, so a
/// scheduler hiccup can only understate a record's %-of-peak, never
/// flatter it.
fn best_of<F: FnMut() -> f64>(n: usize, mut f: F) -> f64 {
    (0..n).map(|_| f()).fold(0.0, f64::max)
}

/// Double the iteration count until one run takes ≥ 2 ms — long enough
/// to dwarf timer quantisation, short enough that the whole probe stays
/// around 100 ms.
fn calibrate(run: fn(usize) -> f64) -> usize {
    let mut iters = 1usize << 12;
    while run(iters) < 2.0e6 && iters < 1usize << 28 {
        iters *= 2;
    }
    iters
}

fn scalar_flops(iters: usize) -> f64 {
    2.0 * iters as f64
}

fn kernel_flops(iters: usize) -> f64 {
    2.0 * (iters * PROBE_ROWS * PROBE_K * PROBE_V) as f64
}

/// One dependent multiply-add chain; returns elapsed nanoseconds.
fn run_scalar(iters: usize) -> f64 {
    let m = std::hint::black_box(M);
    let b = std::hint::black_box(B);
    let mut acc = std::hint::black_box(1.0f32);
    let t0 = Instant::now();
    for _ in 0..iters {
        acc = acc * m + b;
    }
    let ns = t0.elapsed().as_nanos() as f64;
    std::hint::black_box(acc);
    ns.max(1.0)
}

/// The best available micro-kernel backend on the L1-resident probe
/// problem; returns elapsed nanoseconds for `iters` strip invocations.
/// Fixture construction happens outside the timed region.
fn run_kernel(iters: usize) -> f64 {
    // best_available(), not resolve(): NMPRUNE_KERNEL forces what the
    // *benchmarked* kernels run, but the roofline stays the machine's
    // actual ceiling so a forced-scalar run reads as a low %-of-peak
    // rather than moving the goalposts.
    let kern = kernels::by_id(kernels::best_available()).expect("best kernel is registered");
    let w: Vec<f32> = (0..PROBE_ROWS * PROBE_K)
        .map(|i| 0.5 + (i % 13) as f32 * 0.01)
        .collect();
    let a: Vec<f32> = (0..PROBE_K * PROBE_V)
        .map(|i| 0.25 + (i % 17) as f32 * 0.005)
        .collect();
    let p = pack_data_matrix(&a, PROBE_K, PROBE_V, PROBE_V);
    let mut c = vec![0.0f32; PROBE_ROWS * PROBE_V];
    let w = std::hint::black_box(w);
    let t0 = Instant::now();
    for _ in 0..iters {
        // SAFETY: `c` covers the whole single-strip output and is
        // uniquely borrowed here.
        unsafe {
            kern.dense_strip(&w, PROBE_ROWS, &p, PROBE_ROWS, 0, c.as_mut_ptr(), c.len());
        }
        std::hint::black_box(&mut c);
    }
    let ns = t0.elapsed().as_nanos() as f64;
    std::hint::black_box(c);
    ns.max(1.0)
}

/// The best available backend's quantized `dense_strip_i8` path on the
/// same probe problem; returns elapsed nanoseconds for `iters` strip
/// invocations. Quantization happens outside the timed region — the
/// serving path stages activations once per panel, not per strip.
fn run_kernel_i8(iters: usize) -> f64 {
    let kern = kernels::by_id(kernels::best_available()).expect("best kernel is registered");
    let w: Vec<f32> = (0..PROBE_ROWS * PROBE_K)
        .map(|i| 0.5 + (i % 13) as f32 * 0.01)
        .collect();
    let qw = QuantDense::quantize(&w, PROBE_ROWS, PROBE_K);
    let a: Vec<f32> = (0..PROBE_K * PROBE_V)
        .map(|i| 0.25 + (i % 17) as f32 * 0.005)
        .collect();
    let p = pack_data_matrix(&a, PROBE_K, PROBE_V, PROBE_V);
    let mut q = QuantPanel::zeros(PROBE_K, PROBE_V, PROBE_V);
    quantize_panel_into(&p, &mut q);
    let mut c = vec![0.0f32; PROBE_ROWS * PROBE_V];
    let t0 = Instant::now();
    for _ in 0..iters {
        // SAFETY: `c` covers the whole single-strip output and is
        // uniquely borrowed here; strip 0 exists, tile = PROBE_ROWS
        // is within MAX_TILE, and qw.k == q.k by construction.
        unsafe {
            kern.dense_strip_i8(&qw, &q, PROBE_ROWS, 0, c.as_mut_ptr(), c.len());
        }
        std::hint::black_box(&mut c);
    }
    let ns = t0.elapsed().as_nanos() as f64;
    std::hint::black_box(c);
    ns.max(1.0)
}

/// The kernel probe on `n` plain threads at once (barrier-started so
/// every thread measures under full contention); returns the sum of
/// per-thread GFLOP/s. Startup-only code — spawning OS threads here is
/// fine; the no-spawn rule protects the serving hot path.
fn run_aggregate(n: usize, iters: usize) -> f64 {
    if n <= 1 {
        return kernel_flops(iters) / run_kernel(iters);
    }
    let barrier = Barrier::new(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    kernel_flops(iters) / run_kernel(iters)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(0.0)).sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_reports_positive_finite_peaks() {
        let p = probe();
        assert!(p.threads >= 1);
        for v in [p.scalar_gflops, p.fma_gflops, p.aggregate_gflops, p.i8_gops] {
            assert!(v.is_finite() && v > 0.0, "non-positive peak {v}");
        }
        // Independent lanes can never be slower than a dependent chain
        // by more than measurement noise.
        assert!(p.fma_gflops >= p.scalar_gflops * 0.5);
    }

    #[test]
    fn kernel_probe_runs_and_is_positive() {
        // The dispatched-kernel probe (the fma field's source since the
        // kernel layer landed) must run on whatever backend this host
        // resolves without panicking or returning degenerate timings.
        let ns = run_kernel(10);
        assert!(ns.is_finite() && ns >= 1.0, "{ns}");
        assert!(kernel_flops(10) > 0.0);
    }

    #[test]
    fn probe_is_memoised() {
        let a = probe() as *const HwProfile;
        let b = probe() as *const HwProfile;
        assert_eq!(a, b);
    }

    #[test]
    fn peak_interpolates_between_measurements() {
        let p = HwProfile {
            threads: 4,
            scalar_gflops: 1.0,
            fma_gflops: 10.0,
            aggregate_gflops: 28.0,
            i8_gops: 25.0,
        };
        assert_eq!(p.peak_gflops(0), 10.0); // uncapped records = 1 thread
        assert_eq!(p.peak_gflops(1), 10.0);
        assert_eq!(p.peak_gflops(4), 28.0);
        assert_eq!(p.peak_gflops(99), 28.0); // clamped to the machine
        let mid = p.peak_gflops(2);
        assert!(mid > 10.0 && mid < 28.0);
    }

    #[test]
    fn i8_peak_follows_the_f32_scaling_curve() {
        let p = HwProfile {
            threads: 4,
            scalar_gflops: 1.0,
            fma_gflops: 10.0,
            aggregate_gflops: 28.0,
            i8_gops: 25.0,
        };
        assert_eq!(p.peak_gops(1, Dtype::F32), 10.0);
        assert_eq!(p.peak_gops(1, Dtype::I8), 25.0);
        // Full occupancy: i8 peak scales by the measured 2.8× f32 curve.
        assert!((p.peak_gops(4, Dtype::I8) - 70.0).abs() < 1e-9);
        let mid = p.peak_gops(2, Dtype::I8);
        assert!(mid > 25.0 && mid < 70.0);
    }

    #[test]
    fn i8_kernel_probe_runs_and_is_positive() {
        let ns = run_kernel_i8(10);
        assert!(ns.is_finite() && ns >= 1.0, "{ns}");
    }

    #[test]
    fn single_core_machines_use_the_fma_peak_everywhere() {
        let p = HwProfile {
            threads: 1,
            scalar_gflops: 1.0,
            fma_gflops: 8.0,
            aggregate_gflops: 8.0,
            i8_gops: 16.0,
        };
        assert_eq!(p.peak_gflops(1), 8.0);
        assert_eq!(p.peak_gflops(16), 8.0);
    }
}
