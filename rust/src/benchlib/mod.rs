//! Criterion-style micro-benchmark harness (criterion itself is not in
//! the offline crate set). Warmup, adaptive iteration count targeting a
//! wall-time budget, outlier-trimmed statistics, and markdown table
//! output shared by every `benches/` target.
//!
//! Two environment variables shape every bench run:
//!
//! * `NMPRUNE_BENCH_QUICK=1` — shrink measurement budgets *and* case
//!   counts ([`is_quick`]) so the full suite finishes in CI smoke time;
//! * `NMPRUNE_BENCH_JSON=<path>` — additionally emit a machine-readable
//!   [`report::Report`] with roofline-normalized records (see
//!   [`hardware`]), consumed by `nmprune bench-diff`.

pub mod hardware;
pub mod report;

use std::sync::Arc;
use std::time::{Duration, Instant};

pub use hardware::HwProfile;
pub use report::{diff_reports, BenchRecord, RecordConfig, Report, Reporter};

use crate::util::stats::{fmt_ns, trimmed, Summary};
use crate::util::threadpool::ThreadPool;

/// Whether `NMPRUNE_BENCH_QUICK` asked for the reduced-case CI
/// profile. Every bench target must consult this single predicate —
/// both for [`BenchConfig::quick`] budgets and for shrinking its case
/// list — so "quick" means the same thing suite-wide. Parsed by
/// [`crate::util::env::flag`]: it used to accept any non-empty value,
/// so `NMPRUNE_BENCH_QUICK=0` *triggered* quick mode; `""`/`"0"`/
/// `"false"` are now off like every other flag.
pub fn is_quick() -> bool {
    crate::util::env::flag("NMPRUNE_BENCH_QUICK")
}

/// Persistent, per-size worker pools shared by every bench target.
/// Benches sweeping thread counts must route through this so that no
/// pool (and no OS thread) is ever constructed inside a measured loop —
/// the measurement then covers exactly the steady-state dispatch cost a
/// long-lived server pays.
pub fn bench_pool(threads: usize) -> Arc<ThreadPool> {
    ThreadPool::shared(threads)
}

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup wall-time before measuring.
    pub warmup: Duration,
    /// Measurement wall-time budget.
    pub measure: Duration,
    /// Minimum / maximum sample count.
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(800),
            min_samples: 5,
            max_samples: 200,
        }
    }
}

impl BenchConfig {
    /// A faster profile for heavyweight end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(300),
            min_samples: 3,
            max_samples: 30,
        }
    }

    /// The auto-tuner's per-candidate profile: short enough that the
    /// `(LMUL, T, P)` sweep stays interactive across a whole model,
    /// long enough to rank candidates on a quiet machine.
    pub fn tuning() -> Self {
        Self {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(40),
            min_samples: 3,
            max_samples: 20,
        }
    }
}

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    /// Mean nanoseconds per iteration.
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean
    }

    pub fn mean_ms(&self) -> f64 {
        self.summary.mean / 1e6
    }
}

/// Measure a closure: run it repeatedly, one timing sample per call.
/// The result is passed through `std::hint::black_box` to defeat
/// dead-code elimination.
pub fn bench<T, F: FnMut() -> T>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    // Warmup.
    let w0 = Instant::now();
    while w0.elapsed() < cfg.warmup {
        std::hint::black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let m0 = Instant::now();
    while (m0.elapsed() < cfg.measure || samples.len() < cfg.min_samples)
        && samples.len() < cfg.max_samples
    {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    // Trim 5% from each tail for robustness.
    let robust = trimmed(&samples, 0.05);
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&robust),
    }
}

/// A markdown results table accumulated row by row; every bench binary
/// prints one of these so `cargo bench` output maps 1:1 onto the paper's
/// figures/tables.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    /// Render as github-flavoured markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        let mut out = format!("\n## {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}--|", "", w = w));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helper: nanoseconds → human string (re-export).
pub fn fmt_time(ns: f64) -> String {
    fmt_ns(ns)
}

/// Format a speedup ratio.
pub fn fmt_speedup(base_ns: f64, other_ns: f64) -> String {
    format!("{:.2}x", base_ns / other_ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite (env-flag unification): `NMPRUNE_BENCH_QUICK=0` used
    /// to *enable* quick mode (any non-empty value counted). Off values
    /// must now read as off, on values as on.
    #[test]
    fn is_quick_follows_the_flag_convention() {
        let k = "NMPRUNE_BENCH_QUICK";
        let saved = std::env::var(k).ok();
        std::env::remove_var(k);
        assert!(!is_quick(), "unset is off");
        for v in ["0", "false", ""] {
            std::env::set_var(k, v);
            assert!(!is_quick(), "{v:?} must be off");
        }
        for v in ["1", "true", "yes"] {
            std::env::set_var(k, v);
            assert!(is_quick(), "{v:?} must be on");
        }
        match saved {
            Some(v) => std::env::set_var(k, v),
            None => std::env::remove_var(k),
        }
    }

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
            max_samples: 50,
        };
        let r = bench("spin", cfg, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean_ns() > 0.0);
        assert!(r.summary.n >= 3);
    }

    #[test]
    fn bench_orders_fast_before_slow() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_samples: 5,
            max_samples: 100,
        };
        let fast = bench("fast", cfg, || {
            std::hint::black_box((0..100u64).sum::<u64>())
        });
        let slow = bench("slow", cfg, || {
            let mut s = 0u64;
            for i in 0..1_000_000u64 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            s
        });
        // Medians: robust to scheduler noise on a loaded single core.
        assert!(
            slow.summary.median > fast.summary.median,
            "slow {} !> fast {}",
            slow.summary.median,
            fast.summary.median
        );
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Fig. X", &["layer", "time"]);
        t.row(&["conv1".into(), "1.00 ms".into()]);
        t.row(&["conv2".into(), "2.00 ms".into()]);
        let s = t.render();
        assert!(s.contains("## Fig. X"));
        assert!(s.contains("| conv1"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn speedup_format() {
        assert_eq!(fmt_speedup(200.0, 100.0), "2.00x");
    }
}
