//! Minimal dataflow graph IR for CNN inference.
//!
//! Nodes are appended in topological order by the zoo builders; each
//! node records its logical output geometry (c, h, w) for a fixed batch
//! size so the executor can pre-allocate and the tuner can enumerate
//! conv shapes without running anything.

use crate::conv::ConvShape;

/// Operator kinds. Convolution weights are not stored in the graph —
/// the executor materialises them (seeded) per node at load time, as a
/// stand-in for checkpoint loading.
#[derive(Clone, Debug)]
pub enum Op {
    /// Graph input: `[N, c, h, w]` logical activation.
    Input { c: usize, h: usize, w: usize },
    /// 2-D convolution (+ folded bias/BN omitted: inference-time BN is
    /// fused multiplicatively and does not change kernel cost shape).
    Conv { shape: ConvShape, relu: bool },
    /// Depthwise 3×3 convolution (MobileNet-V2).
    DepthwiseConv {
        c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        relu: bool,
    },
    /// Max pooling.
    MaxPool { k: usize, stride: usize, pad: usize },
    /// Average pooling (DenseNet transitions).
    AvgPool { k: usize, stride: usize },
    /// Global average pool to `[c]` per image.
    GlobalAvgPool,
    /// Elementwise residual add (two inputs).
    Add { relu: bool },
    /// Channel concatenation (DenseNet).
    Concat,
    /// Fully connected classifier head.
    Fc { in_features: usize, out_features: usize },
}

/// One graph node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub op: Op,
    /// Producer node ids.
    pub inputs: Vec<usize>,
    /// Output geometry (channels, height, width); h=w=0 after GAP/FC.
    pub out_c: usize,
    pub out_h: usize,
    pub out_w: usize,
}

/// A CNN inference graph for a fixed batch size.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub batch: usize,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str, batch: usize) -> Self {
        Self {
            name: name.to_string(),
            batch,
            nodes: Vec::new(),
        }
    }

    /// Append a node; returns its id. Output geometry is derived from
    /// the op and its inputs.
    pub fn add(&mut self, name: &str, op: Op, inputs: &[usize]) -> usize {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "inputs must precede node (topo order)");
        }
        let (out_c, out_h, out_w) = self.infer_shape(&op, inputs);
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            op,
            inputs: inputs.to_vec(),
            out_c,
            out_h,
            out_w,
        });
        id
    }

    fn infer_shape(&self, op: &Op, inputs: &[usize]) -> (usize, usize, usize) {
        let input = |i: usize| {
            let n = &self.nodes[inputs[i]];
            (n.out_c, n.out_h, n.out_w)
        };
        match op {
            Op::Input { c, h, w } => (*c, *h, *w),
            Op::Conv { shape, .. } => {
                let (c, h, w) = input(0);
                assert_eq!(
                    (c, h, w),
                    (shape.c_in, shape.h_in, shape.w_in),
                    "conv input geometry mismatch"
                );
                assert_eq!(shape.n, self.batch);
                (shape.c_out, shape.h_out(), shape.w_out())
            }
            Op::DepthwiseConv { c, k, stride, pad, .. } => {
                let (ci, h, w) = input(0);
                assert_eq!(ci, *c);
                (
                    *c,
                    (h + 2 * pad - k) / stride + 1,
                    (w + 2 * pad - k) / stride + 1,
                )
            }
            Op::MaxPool { k, stride, pad } => {
                let (c, h, w) = input(0);
                (
                    c,
                    (h + 2 * pad - k) / stride + 1,
                    (w + 2 * pad - k) / stride + 1,
                )
            }
            Op::AvgPool { k, stride } => {
                let (c, h, w) = input(0);
                (c, (h - k) / stride + 1, (w - k) / stride + 1)
            }
            Op::GlobalAvgPool => {
                let (c, _, _) = input(0);
                (c, 0, 0)
            }
            Op::Add { .. } => {
                let a = input(0);
                let b = input(1);
                assert_eq!(a, b, "residual add shape mismatch");
                a
            }
            Op::Concat => {
                let mut c_total = 0;
                let (_, h0, w0) = input(0);
                for i in 0..inputs.len() {
                    let (c, h, w) = input(i);
                    assert_eq!((h, w), (h0, w0), "concat spatial mismatch");
                    c_total += c;
                }
                (c_total, h0, w0)
            }
            Op::Fc { in_features, out_features } => {
                let (c, h, w) = input(0);
                let feat = if h == 0 { c } else { c * h * w };
                assert_eq!(feat, *in_features, "fc input features");
                (*out_features, 0, 0)
            }
        }
    }

    /// All convolution shapes in the graph (for tuning / stats).
    pub fn conv_shapes(&self) -> Vec<(String, ConvShape)> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Conv { shape, .. } => Some((n.name.clone(), *shape)),
                _ => None,
            })
            .collect()
    }

    /// Total dense conv MACs.
    pub fn conv_macs(&self) -> usize {
        self.conv_shapes().iter().map(|(_, s)| s.macs()).sum()
    }

    /// Total conv weight parameters.
    pub fn conv_params(&self) -> usize {
        self.conv_shapes().iter().map(|(_, s)| s.weight_len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_propagate() {
        let mut g = Graph::new("t", 1);
        let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
        let c1 = g.add(
            "c1",
            Op::Conv {
                shape: ConvShape::square(1, 3, 8, 16, 3, 1, 1),
                relu: true,
            },
            &[x],
        );
        let p = g.add(
            "pool",
            Op::MaxPool {
                k: 2,
                stride: 2,
                pad: 0,
            },
            &[c1],
        );
        let gap = g.add("gap", Op::GlobalAvgPool, &[p]);
        let fc = g.add(
            "fc",
            Op::Fc {
                in_features: 16,
                out_features: 10,
            },
            &[gap],
        );
        assert_eq!(
            (g.nodes[c1].out_c, g.nodes[c1].out_h, g.nodes[c1].out_w),
            (16, 8, 8)
        );
        assert_eq!((g.nodes[p].out_h, g.nodes[p].out_w), (4, 4));
        assert_eq!(g.nodes[gap].out_c, 16);
        assert_eq!(g.nodes[fc].out_c, 10);
        assert_eq!(g.conv_shapes().len(), 1);
    }

    #[test]
    fn concat_sums_channels() {
        let mut g = Graph::new("t", 1);
        let x = g.add("in", Op::Input { c: 4, h: 4, w: 4 }, &[]);
        let y = g.add(
            "c",
            Op::Conv {
                shape: ConvShape::square(1, 4, 4, 8, 1, 1, 0),
                relu: false,
            },
            &[x],
        );
        let cat = g.add("cat", Op::Concat, &[x, y]);
        assert_eq!(g.nodes[cat].out_c, 12);
    }

    #[test]
    #[should_panic(expected = "conv input geometry mismatch")]
    fn bad_conv_shape_panics() {
        let mut g = Graph::new("t", 1);
        let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
        g.add(
            "c",
            Op::Conv {
                shape: ConvShape::square(1, 4, 8, 16, 3, 1, 1),
                relu: false,
            },
            &[x],
        );
    }

    #[test]
    #[should_panic(expected = "topo order")]
    fn forward_reference_panics() {
        let mut g = Graph::new("t", 1);
        g.add("bad", Op::GlobalAvgPool, &[3]);
    }
}
