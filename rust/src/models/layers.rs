//! Representative ResNet-50 layer tables used by the figure benchmarks.
//!
//! §4.2: "ResNet-50 comprises four stages, each containing three
//! representative convolution layers. We select these layers with
//! varying shapes for evaluation, excluding the downsampling layers."
//! §4.3 uses the stem + the 3×3 conv2 of each stage; Fig. 10 adds the
//! downsampling convs.

use crate::conv::ConvShape;

/// A named conv layer instance.
#[derive(Clone, Copy, Debug)]
pub struct NamedConv {
    pub name: &'static str,
    pub shape: ConvShape,
}

fn c(name: &'static str, n: usize, c_in: usize, hw: usize, c_out: usize, k: usize, stride: usize, pad: usize) -> NamedConv {
    NamedConv {
        name,
        shape: ConvShape::square(n, c_in, hw, c_out, k, stride, pad),
    }
}

/// The 12 Fig. 5 layers: conv1/conv2/conv3 of the first block of each
/// stage (torchvision ResNet-50 geometry, batch `n`).
pub fn resnet50_fig5_layers(n: usize) -> Vec<NamedConv> {
    vec![
        // Stage 1 @56×56
        c("Stage1-conv1", n, 64, 56, 64, 1, 1, 0),
        c("Stage1-conv2", n, 64, 56, 64, 3, 1, 1),
        c("Stage1-conv3", n, 64, 56, 256, 1, 1, 0),
        // Stage 2: conv1 @56, stride-2 conv2 →28
        c("Stage2-conv1", n, 256, 56, 128, 1, 1, 0),
        c("Stage2-conv2", n, 128, 56, 128, 3, 2, 1),
        c("Stage2-conv3", n, 128, 28, 512, 1, 1, 0),
        // Stage 3
        c("Stage3-conv1", n, 512, 28, 256, 1, 1, 0),
        c("Stage3-conv2", n, 256, 28, 256, 3, 2, 1),
        c("Stage3-conv3", n, 256, 14, 1024, 1, 1, 0),
        // Stage 4
        c("Stage4-conv1", n, 1024, 14, 512, 1, 1, 0),
        c("Stage4-conv2", n, 512, 14, 512, 3, 2, 1),
        c("Stage4-conv3", n, 512, 7, 2048, 1, 1, 0),
    ]
}

/// The Fig. 6/7/8 layers: stem (7×7) + the 3×3 conv2 of each stage —
/// the layers where im2col overhead matters.
pub fn resnet50_fig6_layers(n: usize) -> Vec<NamedConv> {
    vec![
        c("Stem-conv", n, 3, 224, 64, 7, 2, 3),
        c("Stage1-conv2", n, 64, 56, 64, 3, 1, 1),
        c("Stage2-conv2", n, 128, 56, 128, 3, 2, 1),
        c("Stage3-conv2", n, 256, 28, 256, 3, 2, 1),
        c("Stage4-conv2", n, 512, 14, 512, 3, 2, 1),
    ]
}

/// Fig. 10's layer set: Fig. 5's layers plus the per-stage downsampling
/// convs (1×1 stride-2 projections).
pub fn resnet50_fig10_layers(n: usize) -> Vec<NamedConv> {
    let mut layers = resnet50_fig5_layers(n);
    layers.push(c("Stage1-down", n, 64, 56, 256, 1, 1, 0));
    layers.push(c("Stage2-down", n, 256, 56, 512, 1, 2, 0));
    layers.push(c("Stage3-down", n, 512, 28, 1024, 1, 2, 0));
    layers.push(c("Stage4-down", n, 1024, 14, 2048, 1, 2, 0));
    layers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_model, ModelArch};

    /// Every Fig. 5 layer must actually occur in the ResNet-50 graph.
    #[test]
    fn fig5_layers_exist_in_resnet50() {
        let g = build_model(ModelArch::ResNet50, 1, 224);
        let shapes: Vec<ConvShape> = g.conv_shapes().into_iter().map(|(_, s)| s).collect();
        for layer in resnet50_fig5_layers(1) {
            assert!(
                shapes.contains(&layer.shape),
                "{} {:?} not found in graph",
                layer.name,
                layer.shape
            );
        }
    }

    #[test]
    fn fig10_downsampling_layers_exist() {
        let g = build_model(ModelArch::ResNet50, 1, 224);
        let shapes: Vec<ConvShape> = g.conv_shapes().into_iter().map(|(_, s)| s).collect();
        for layer in resnet50_fig10_layers(1) {
            assert!(shapes.contains(&layer.shape), "{}", layer.name);
        }
    }

    #[test]
    fn fig6_layers_are_spatial_kernels() {
        for l in resnet50_fig6_layers(1) {
            assert!(l.shape.kh >= 3, "{} must be a spatial conv", l.name);
        }
    }

    #[test]
    fn output_geometry_sane() {
        for l in resnet50_fig5_layers(2) {
            assert!(l.shape.h_out() > 0 && l.shape.w_out() > 0);
            assert_eq!(l.shape.n, 2);
        }
    }
}
