//! Builders for the paper's seven evaluation networks (§4.1.2), with
//! torchvision-faithful ImageNet geometry: ResNet-18/34 (BasicBlock),
//! ResNet-50/101/152 (Bottleneck), MobileNet-V2 (inverted residuals),
//! DenseNet-121 (dense blocks + transitions).

use super::graph::{Graph, Op};
use crate::conv::ConvShape;

/// Architectures in the evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelArch {
    ResNet18,
    ResNet34,
    ResNet50,
    ResNet101,
    ResNet152,
    MobileNetV2,
    DenseNet121,
}

impl ModelArch {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "resnet18" | "resnet-18" => Self::ResNet18,
            "resnet34" | "resnet-34" => Self::ResNet34,
            "resnet50" | "resnet-50" => Self::ResNet50,
            "resnet101" | "resnet-101" => Self::ResNet101,
            "resnet152" | "resnet-152" => Self::ResNet152,
            "mobilenetv2" | "mobilenet-v2" | "mobilenet_v2" => Self::MobileNetV2,
            "densenet121" | "densenet-121" => Self::DenseNet121,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::ResNet18 => "resnet18",
            Self::ResNet34 => "resnet34",
            Self::ResNet50 => "resnet50",
            Self::ResNet101 => "resnet101",
            Self::ResNet152 => "resnet152",
            Self::MobileNetV2 => "mobilenet_v2",
            Self::DenseNet121 => "densenet121",
        }
    }
}

/// All model names (Table 2 / Fig. 12 order).
pub fn model_names() -> &'static [&'static str] {
    &[
        "resnet18",
        "resnet34",
        "resnet50",
        "resnet101",
        "resnet152",
        "mobilenet_v2",
        "densenet121",
    ]
}

/// Build a model graph for a batch size. `res` is the input resolution
/// (224 for the paper's ImageNet setting; smaller for quick tests).
pub fn build_model(arch: ModelArch, batch: usize, res: usize) -> Graph {
    match arch {
        ModelArch::ResNet18 => resnet_basic(arch.name(), batch, res, &[2, 2, 2, 2]),
        ModelArch::ResNet34 => resnet_basic(arch.name(), batch, res, &[3, 4, 6, 3]),
        ModelArch::ResNet50 => resnet_bottleneck(arch.name(), batch, res, &[3, 4, 6, 3]),
        ModelArch::ResNet101 => resnet_bottleneck(arch.name(), batch, res, &[3, 4, 23, 3]),
        ModelArch::ResNet152 => resnet_bottleneck(arch.name(), batch, res, &[3, 8, 36, 3]),
        ModelArch::MobileNetV2 => mobilenet_v2(batch, res),
        ModelArch::DenseNet121 => densenet121(batch, res),
    }
}

fn conv(g: &mut Graph, name: &str, from: usize, c_out: usize, k: usize, stride: usize, pad: usize, relu: bool) -> usize {
    let n = &g.nodes[from];
    let shape = ConvShape {
        n: g.batch,
        c_in: n.out_c,
        h_in: n.out_h,
        w_in: n.out_w,
        c_out,
        kh: k,
        kw: k,
        stride,
        pad,
    };
    g.add(name, Op::Conv { shape, relu }, &[from])
}

/// Shared ResNet stem: 7×7/2 conv + 3×3/2 maxpool.
fn resnet_stem(g: &mut Graph, res: usize) -> usize {
    let x = g.add("input", Op::Input { c: 3, h: res, w: res }, &[]);
    let c = conv(g, "stem-conv", x, 64, 7, 2, 3, true);
    g.add(
        "stem-pool",
        Op::MaxPool {
            k: 3,
            stride: 2,
            pad: 1,
        },
        &[c],
    )
}

fn resnet_head(g: &mut Graph, from: usize, in_features: usize) -> usize {
    let gap = g.add("gap", Op::GlobalAvgPool, &[from]);
    g.add(
        "fc",
        Op::Fc {
            in_features,
            out_features: 1000,
        },
        &[gap],
    )
}

/// ResNet-18/34 (BasicBlock: two 3×3 convs).
fn resnet_basic(name: &str, batch: usize, res: usize, blocks: &[usize; 4]) -> Graph {
    let mut g = Graph::new(name, batch);
    let mut cur = resnet_stem(&mut g, res);
    let widths = [64usize, 128, 256, 512];
    for (stage, (&w, &nblocks)) in widths.iter().zip(blocks).enumerate() {
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let pre = format!("s{}b{}", stage + 1, b);
            let identity = cur;
            let c1 = conv(&mut g, &format!("{pre}-conv1"), cur, w, 3, stride, 1, true);
            let c2 = conv(&mut g, &format!("{pre}-conv2"), c1, w, 3, 1, 1, false);
            let skip = if stride != 1 || g.nodes[identity].out_c != w {
                conv(&mut g, &format!("{pre}-down"), identity, w, 1, stride, 0, false)
            } else {
                identity
            };
            cur = g.add(&format!("{pre}-add"), Op::Add { relu: true }, &[c2, skip]);
        }
    }
    resnet_head(&mut g, cur, 512);
    g
}

/// ResNet-50/101/152 (Bottleneck: 1×1 reduce, 3×3, 1×1 expand ×4).
fn resnet_bottleneck(name: &str, batch: usize, res: usize, blocks: &[usize; 4]) -> Graph {
    let mut g = Graph::new(name, batch);
    let mut cur = resnet_stem(&mut g, res);
    let widths = [64usize, 128, 256, 512];
    for (stage, (&w, &nblocks)) in widths.iter().zip(blocks).enumerate() {
        for b in 0..nblocks {
            // torchvision: stride lives on the 3×3 conv of the first
            // block of stages 2–4.
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let pre = format!("s{}b{}", stage + 1, b);
            let identity = cur;
            let c1 = conv(&mut g, &format!("{pre}-conv1"), cur, w, 1, 1, 0, true);
            let c2 = conv(&mut g, &format!("{pre}-conv2"), c1, w, 3, stride, 1, true);
            let c3 = conv(&mut g, &format!("{pre}-conv3"), c2, 4 * w, 1, 1, 0, false);
            let skip = if stride != 1 || g.nodes[identity].out_c != 4 * w {
                conv(&mut g, &format!("{pre}-down"), identity, 4 * w, 1, stride, 0, false)
            } else {
                identity
            };
            cur = g.add(&format!("{pre}-add"), Op::Add { relu: true }, &[c3, skip]);
        }
    }
    resnet_head(&mut g, cur, 2048);
    g
}

/// MobileNet-V2 inverted residual settings: (expand t, out c, repeat n,
/// stride s) per the paper.
const MBV2_CFG: &[(usize, usize, usize, usize)] = &[
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

fn mobilenet_v2(batch: usize, res: usize) -> Graph {
    let mut g = Graph::new("mobilenet_v2", batch);
    let x = g.add("input", Op::Input { c: 3, h: res, w: res }, &[]);
    let mut cur = conv(&mut g, "stem-conv", x, 32, 3, 2, 1, true);
    let mut block = 0;
    for &(t, c, n, s) in MBV2_CFG {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let pre = format!("b{block}");
            let in_c = g.nodes[cur].out_c;
            let identity = cur;
            let mut h = cur;
            if t != 1 {
                h = conv(&mut g, &format!("{pre}-expand"), h, in_c * t, 1, 1, 0, true);
            }
            h = g.add(
                &format!("{pre}-dw"),
                Op::DepthwiseConv {
                    c: g.nodes[h].out_c,
                    k: 3,
                    stride,
                    pad: 1,
                    relu: true,
                },
                &[h],
            );
            h = conv(&mut g, &format!("{pre}-project"), h, c, 1, 1, 0, false);
            cur = if stride == 1 && in_c == c {
                g.add(&format!("{pre}-add"), Op::Add { relu: false }, &[h, identity])
            } else {
                h
            };
            block += 1;
        }
    }
    let last = conv(&mut g, "head-conv", cur, 1280, 1, 1, 0, true);
    let gap = g.add("gap", Op::GlobalAvgPool, &[last]);
    g.add(
        "fc",
        Op::Fc {
            in_features: 1280,
            out_features: 1000,
        },
        &[gap],
    );
    g
}

/// DenseNet-121: growth 32, block config (6, 12, 24, 16), bottleneck
/// 4×growth, transitions halve channels + 2×2 avgpool.
fn densenet121(batch: usize, res: usize) -> Graph {
    let growth = 32usize;
    let mut g = Graph::new("densenet121", batch);
    let x = g.add("input", Op::Input { c: 3, h: res, w: res }, &[]);
    let c = conv(&mut g, "stem-conv", x, 64, 7, 2, 3, true);
    let mut cur = g.add(
        "stem-pool",
        Op::MaxPool {
            k: 3,
            stride: 2,
            pad: 1,
        },
        &[c],
    );
    for (bi, &layers) in [6usize, 12, 24, 16].iter().enumerate() {
        for l in 0..layers {
            let pre = format!("d{}l{}", bi + 1, l);
            // Dense layer: 1×1 bottleneck to 4·growth, then 3×3 growth.
            let b = conv(&mut g, &format!("{pre}-bottleneck"), cur, 4 * growth, 1, 1, 0, true);
            let n = conv(&mut g, &format!("{pre}-conv"), b, growth, 3, 1, 1, true);
            cur = g.add(&format!("{pre}-cat"), Op::Concat, &[cur, n]);
        }
        if bi < 3 {
            let half = g.nodes[cur].out_c / 2;
            let t = conv(&mut g, &format!("t{}-conv", bi + 1), cur, half, 1, 1, 0, true);
            cur = g.add(
                &format!("t{}-pool", bi + 1),
                Op::AvgPool { k: 2, stride: 2 },
                &[t],
            );
        }
    }
    let gap = g.add("gap", Op::GlobalAvgPool, &[cur]);
    g.add(
        "fc",
        Op::Fc {
            in_features: 1024,
            out_features: 1000,
        },
        &[gap],
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_macs_match_published() {
        // torchvision ResNet-50 @224: ~4.09 GMACs of conv (+2M fc).
        let g = build_model(ModelArch::ResNet50, 1, 224);
        let gmacs = g.conv_macs() as f64 / 1e9;
        assert!((3.9..4.3).contains(&gmacs), "got {gmacs} GMACs");
        // 53 conv layers (incl. stem + 4 downsample).
        assert_eq!(g.conv_shapes().len(), 53);
    }

    #[test]
    fn resnet18_geometry() {
        let g = build_model(ModelArch::ResNet18, 1, 224);
        let gmacs = g.conv_macs() as f64 / 1e9;
        assert!((1.6..1.9).contains(&gmacs), "got {gmacs}");
        assert_eq!(g.conv_shapes().len(), 20);
        // Final feature map before GAP is 7×7×512.
        let gap = g.nodes.iter().find(|n| n.name == "gap").unwrap();
        let pre = &g.nodes[gap.inputs[0]];
        assert_eq!((pre.out_c, pre.out_h, pre.out_w), (512, 7, 7));
    }

    #[test]
    fn resnet101_and_152_layer_counts() {
        assert_eq!(
            build_model(ModelArch::ResNet101, 1, 224).conv_shapes().len(),
            104
        );
        assert_eq!(
            build_model(ModelArch::ResNet152, 1, 224).conv_shapes().len(),
            155
        );
    }

    #[test]
    fn mobilenet_v2_params_and_macs() {
        let g = build_model(ModelArch::MobileNetV2, 1, 224);
        let gmacs = g.conv_macs() as f64 / 1e9;
        // ~0.3 GMACs total; our conv_macs excludes depthwise (counted as
        // Op::DepthwiseConv), so slightly lower.
        assert!((0.2..0.35).contains(&gmacs), "got {gmacs}");
        let fc = g.nodes.last().unwrap();
        assert_eq!(fc.out_c, 1000);
    }

    #[test]
    fn densenet121_channel_growth() {
        let g = build_model(ModelArch::DenseNet121, 1, 224);
        // Final dense block output: 512 + 16*32 = 1024 channels.
        let gap = g.nodes.iter().find(|n| n.name == "gap").unwrap();
        let pre = &g.nodes[gap.inputs[0]];
        assert_eq!(pre.out_c, 1024);
        assert_eq!((pre.out_h, pre.out_w), (7, 7));
        let gmacs = g.conv_macs() as f64 / 1e9;
        assert!((2.5..3.1).contains(&gmacs), "got {gmacs}");
    }

    #[test]
    fn batch_propagates_to_conv_shapes() {
        let g = build_model(ModelArch::ResNet18, 4, 224);
        for (_, s) in g.conv_shapes() {
            assert_eq!(s.n, 4);
        }
    }

    #[test]
    fn smaller_resolution_builds() {
        for arch in [
            ModelArch::ResNet18,
            ModelArch::ResNet50,
            ModelArch::MobileNetV2,
            ModelArch::DenseNet121,
        ] {
            let g = build_model(arch, 1, 64);
            assert!(g.nodes.len() > 10, "{}", g.name);
        }
    }

    #[test]
    fn parse_names() {
        for &n in model_names() {
            assert!(ModelArch::parse(n).is_some(), "{n}");
        }
        assert!(ModelArch::parse("vgg16").is_none());
    }
}
