//! Model zoo: the seven CNNs of the paper's evaluation (§4.1.2), as
//! layer graphs with exact ImageNet geometry, plus the representative
//! per-layer shape tables used by Figs. 5–10.

pub mod graph;
pub mod zoo;
pub mod layers;

pub use graph::{Graph, Node, Op};
pub use layers::{resnet50_fig5_layers, resnet50_fig6_layers, resnet50_fig10_layers, NamedConv};
pub use zoo::{build_model, model_names, ModelArch};
