//! `nmprune` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   models                       list model zoo entries with MACs/params
//!   pack   --model M --out F     AOT-pack pruned conv weights + tuned
//!                                per-layer choices into a versioned
//!                                binary artifact (validated on load;
//!                                --cache picks up `nmprune tune` results;
//!                                --dtype {f32|i8} sets the default
//!                                compute dtype baked into the artifact)
//!   run    --model M [...]       single inference, timing report
//!                                (--artifact F: load an AOT-packed
//!                                artifact instead of packing at startup;
//!                                --dtype {f32|i8}: default per-layer
//!                                compute dtype for online builds)
//!   serve  --model M [...]       batching server demo with load generator
//!                                (--executors N: concurrent batch executors;
//!                                --adaptive: load-aware batch size + caps +
//!                                dispatcher parking; --pin: core-pinned pool
//!                                workers; --prio-mix F: fraction F
//!                                interactive / 1−F background traffic on the
//!                                priority/deadline intake; --deadline-ms D:
//!                                interactive deadline; --fifo: keep FIFO
//!                                intake for comparison; --artifact F:
//!                                serve from an AOT-packed artifact —
//!                                model load is a validation pass)
//!   tune   --model M [...]       per-layer (LMUL, T, P, kernel, dtype)
//!                                auto-tuning
//!   kernels [--best]             list compiled-in micro-kernel backends,
//!                                their availability on this host and
//!                                whether each carries a native int8
//!                                micro-kernel (--best: print just the
//!                                best available backend's name — used by
//!                                CI to force it via NMPRUNE_KERNEL)
//!   sim    [--layer i]           RVV-simulator kernel comparison
//!   artifacts [--manifest path]  load + smoke-run AOT artifacts via PJRT
//!   bench-diff OLD NEW [...]     compare two NMPRUNE_BENCH_JSON reports
//!                                (--threshold-pct X, default 10): prints a
//!                                regression/improvement table and exits
//!                                nonzero if any gated record regressed
//!                                beyond the threshold — the CI perf gate
//!   lint [--json] [path]         static-analysis pass over the source
//!                                tree (default `rust/src`): checks the
//!                                repo invariants (SAFETY comments on
//!                                unsafe, pool-only thread spawns,
//!                                clock-free policy, release-mode
//!                                artifact validation, NaN-safe sorts,
//!                                zero-alloc regions); exit 0 clean,
//!                                1 findings, 2 usage — the CI lint gate

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use nmprune::conv::ConvPath;
use nmprune::engine::{ExecConfig, Executor, Priority, QueueDiscipline, Server, ServerConfig};
use nmprune::models::{build_model, model_names, resnet50_fig5_layers, ModelArch};
use nmprune::runtime::PackedArtifact;
use nmprune::tensor::{Dtype, Tensor};
use nmprune::tuner;
use nmprune::util::cli::Args;
use nmprune::util::{ThreadPool, XorShiftRng};

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("models") => cmd_models(),
        Some("pack") => cmd_pack(&args),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("tune") => cmd_tune(&args),
        Some("kernels") => cmd_kernels(&args),
        Some("sim") => cmd_sim(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: nmprune <models|pack|run|serve|tune|kernels|sim|artifacts|bench-diff|lint> [options]\n\
                 common options: --model resnet50 --batch 1 --res 224 \
                 --threads N (default: all hardware threads, or NMPRUNE_THREADS) \
                 --path {{nhwc|cnhw|sparse}} --sparsity 0.5 --dtype {{f32|i8}}"
            );
            std::process::exit(2);
        }
    }
}

fn parse_model(args: &Args) -> ModelArch {
    let name = args.get_or("model", "resnet50");
    ModelArch::parse(&name).unwrap_or_else(|| {
        eprintln!("unknown model {name:?}; available: {:?}", model_names());
        std::process::exit(2);
    })
}

fn parse_pool(args: &Args) -> Arc<ThreadPool> {
    // One persistent pool per process: `--threads N` pins the size
    // (N = 0 clamps to 1, i.e. serial, matching the seed CLI); with the
    // flag absent, the global pool (NMPRUNE_THREADS or all hardware
    // threads) serves the process. `--pin` always builds a fresh
    // core-pinned pool of the requested size — it bypasses the
    // memoised shared()/global() registry, whose pools honour
    // NMPRUNE_PIN=1 instead.
    match (args.get("threads"), args.has_flag("pin")) {
        (None, false) => ThreadPool::global(),
        (None, true) => {
            // Same sizing rule as the global pool: --pin changes
            // placement only, never the worker count.
            Arc::new(ThreadPool::new_pinned(ThreadPool::default_size()))
        }
        (Some(_), false) => ThreadPool::shared(args.get_parsed("threads", 1)),
        (Some(_), true) => Arc::new(ThreadPool::new_pinned(args.get_parsed("threads", 1))),
    }
}

/// `--dtype {f32|i8}`: the default per-layer compute dtype for ops
/// built online (pack/run/serve without an artifact). Tuned per-layer
/// cache entries still override it layer-by-layer, and NMPRUNE_DTYPE
/// forces it process-wide at executor build time.
fn parse_dtype(args: &Args) -> Dtype {
    let name = args.get_or("dtype", "f32");
    Dtype::from_name(name.trim()).unwrap_or_else(|| {
        eprintln!("unknown dtype {name:?} (f32|i8)");
        std::process::exit(2);
    })
}

fn parse_exec(args: &Args) -> ExecConfig {
    let pool = parse_pool(args);
    let sparsity = args.get_parsed("sparsity", 0.5f64);
    let mut cfg = match args.get_or("path", "sparse").as_str() {
        "nhwc" => ExecConfig::dense_nhwc(pool),
        "cnhw" => ExecConfig::dense_cnhw(pool),
        "sparse" => ExecConfig::sparse_cnhw(pool, sparsity),
        p => {
            eprintln!("unknown path {p:?} (nhwc|cnhw|sparse)");
            std::process::exit(2);
        }
    };
    cfg.default_choice.dtype = parse_dtype(args);
    cfg
}

fn cmd_models() {
    println!(
        "{:<14} {:>8} {:>12} {:>10}",
        "model", "convs", "conv GMACs", "params(M)"
    );
    for &name in model_names() {
        let arch = ModelArch::parse(name).unwrap();
        let g = build_model(arch, 1, 224);
        println!(
            "{:<14} {:>8} {:>12.2} {:>10.1}",
            name,
            g.conv_shapes().len(),
            g.conv_macs() as f64 / 1e9,
            g.conv_params() as f64 / 1e6,
        );
    }
}

/// Load a packed artifact or exit with its validation error.
fn load_artifact(ctx: &str, path: &str) -> PackedArtifact {
    PackedArtifact::load(Path::new(path)).unwrap_or_else(|e| {
        eprintln!("{ctx}: {e}");
        std::process::exit(1);
    })
}

/// Resolve the arch name recorded in an artifact to a zoo entry.
fn artifact_arch(name: &str) -> ModelArch {
    ModelArch::parse(name).unwrap_or_else(|| {
        eprintln!("artifact names unknown arch {name:?}; available: {:?}", model_names());
        std::process::exit(1);
    })
}

/// AOT-pack a model's pruned conv weights and tuned per-layer choices
/// into a versioned binary artifact. Tuned choices are picked up from
/// the tune cache (`nmprune tune` writes it) keyed exactly as the tuner
/// keys them; layers without a cache entry keep the default choice.
fn cmd_pack(args: &Args) {
    let arch = parse_model(args);
    let batch = args.get_parsed("batch", 1usize);
    let res = args.get_parsed("res", 224usize);
    let mut cfg = parse_exec(args);
    let out = args.get_or("out", "artifacts/model.nmpk");
    let cache_path = args.get_or("cache", "artifacts/tune_cache.tsv");
    let cache = tuner::TuneCache::load(&cache_path);
    let g = build_model(arch, batch, res);
    let sparsity = (cfg.path == ConvPath::SparseCnhw).then_some(cfg.sparsity);
    let mut tuned = 0usize;
    for (name, shape) in g.conv_shapes() {
        if let Some(c) = cache.entries.get(&tuner::cache_key(&shape, sparsity)) {
            cfg.per_layer.insert(name, *c);
            tuned += 1;
        }
    }
    println!(
        "packing {} batch={batch} res={res} path={:?} ({tuned} tuned layers from {cache_path})",
        arch.name(),
        cfg.path
    );
    let t0 = Instant::now();
    let exec = Executor::new(g, cfg);
    let art = exec.to_artifact();
    art.save(Path::new(&out)).unwrap_or_else(|e| {
        eprintln!("pack: {e}");
        std::process::exit(1);
    });
    println!(
        "packed {} conv layers ({:.1} MiB weights) -> {out} in {:.1} ms",
        art.layers.len(),
        art.weight_bytes() as f64 / (1 << 20) as f64,
        t0.elapsed().as_secs_f64() * 1e3,
    );
}

fn cmd_run(args: &Args) {
    let batch = args.get_parsed("batch", 1usize);
    let (exec, res) = if let Some(p) = args.get("artifact") {
        // AOT path: arch, resolution, weights, and tuning all come from
        // the artifact; model load is a validation pass, not a re-pack.
        let t0 = Instant::now();
        let art = load_artifact("run", p);
        let arch = artifact_arch(&art.arch);
        let g = build_model(arch, batch, art.res);
        let exec = Executor::from_artifact(g, parse_pool(args), &art).unwrap_or_else(|e| {
            eprintln!("run: {e}");
            std::process::exit(1);
        });
        println!(
            "loaded {} batch={batch} res={} path={:?} from {p} in {:.1} ms",
            art.arch,
            art.res,
            art.path,
            t0.elapsed().as_secs_f64() * 1e3,
        );
        (exec, art.res)
    } else {
        let arch = parse_model(args);
        let res = args.get_parsed("res", 224usize);
        let cfg = parse_exec(args);
        let path = cfg.path;
        println!(
            "building {} batch={batch} res={res} path={path:?}",
            arch.name()
        );
        let t0 = Instant::now();
        let exec = Executor::new(build_model(arch, batch, res), cfg);
        println!("compile: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
        (exec, res)
    };
    let mut rng = XorShiftRng::new(1);
    let x = Tensor::random(&[batch, res, res, 3], &mut rng, 0.0, 1.0);
    // One warmup + one timed run, both inside a preallocated scratch
    // arena (the serving configuration's memory plane).
    let mut arena = exec.scratch();
    exec.run_in(&x, &mut arena);
    let t1 = Instant::now();
    let y = exec.run_in(&x, &mut arena);
    let dt = t1.elapsed();
    let top: usize = (0..1000)
        .max_by(|&a, &b| y.data[a].total_cmp(&y.data[b]))
        .unwrap();
    println!(
        "inference: {:.1} ms  ({:.1} img/s)  argmax={top}  weights={:.1} MiB  scratch={:.1} MiB",
        dt.as_secs_f64() * 1e3,
        batch as f64 / dt.as_secs_f64(),
        exec.conv_weight_bytes() as f64 / (1 << 20) as f64,
        arena.bytes() as f64 / (1 << 20) as f64,
    );
}

fn cmd_serve(args: &Args) {
    // With --artifact the model identity (arch, resolution, path) comes
    // from the packed file and startup is a validation pass; otherwise
    // the model is generated and packed online as before.
    let artifact = args.get("artifact").map(|p| load_artifact("serve", p));
    let (arch, res) = match &artifact {
        Some(art) => (artifact_arch(&art.arch), art.res),
        None => (parse_model(args), args.get_parsed("res", 224usize)),
    };
    let requests = args.get_parsed("requests", 32usize);
    let max_batch = args.get_parsed("max-batch", 4usize);
    // Mixed-traffic flags: --prio-mix F submits fraction F of requests
    // as Interactive and the rest as background Batch traffic (and
    // switches the intake to the priority/deadline discipline unless
    // --fifo keeps the baseline ordering for comparison);
    // --deadline-ms D attaches a D ms deadline to interactive requests.
    let prio_mix = args.get_parsed("prio-mix", 1.0f64).clamp(0.0, 1.0);
    let mixed = args.get("prio-mix").is_some() || args.get("deadline-ms").is_some();
    let deadline = args
        .get("deadline-ms")
        .map(|_| std::time::Duration::from_millis(args.get_parsed("deadline-ms", 50u64)));
    let discipline = if mixed && !args.has_flag("fifo") {
        QueueDiscipline::Priority
    } else {
        QueueDiscipline::Fifo
    };
    let scfg = ServerConfig {
        batch_sizes: (0..)
            .map(|i| 1usize << i)
            .take_while(|&b| b <= max_batch)
            .collect(),
        batch_window: std::time::Duration::from_millis(
            args.get_parsed("window-ms", 5u64),
        ),
        executors: args.get_parsed("executors", 1usize),
        adaptive: args.has_flag("adaptive"),
        discipline,
        ..ServerConfig::default()
    };
    let t0 = Instant::now();
    let server = match &artifact {
        Some(art) => {
            let server = Server::start_packed(
                |b| build_model(arch, b, res),
                parse_pool(args),
                art,
                scfg,
            )
            .unwrap_or_else(|e| {
                eprintln!("serve: {e}");
                std::process::exit(1);
            });
            println!(
                "model load (AOT artifact): {:.1} ms",
                t0.elapsed().as_secs_f64() * 1e3
            );
            server
        }
        None => {
            Server::start(|b| build_model(arch, b, res), parse_exec(args), res, scfg)
        }
    };
    println!(
        "serving {requests} requests on {} @{res} ({discipline:?} intake) ...",
        arch.name()
    );
    let mut rng = XorShiftRng::new(7);
    let mut handles = Vec::with_capacity(requests);
    let mut n_interactive = 0usize;
    for i in 0..requests {
        let image = Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0);
        // Deterministic interleave tracking the target mix: submit as
        // interactive whenever the running count is behind the quota.
        let interactive =
            !mixed || (n_interactive as f64) < (i + 1) as f64 * prio_mix;
        handles.push(if interactive {
            n_interactive += 1;
            server.submit_with(image, Priority::Interactive, deadline)
        } else {
            server.submit_with(image, Priority::Batch, None)
        });
    }
    for h in handles {
        h.recv().expect("reply");
    }
    let stats = server.shutdown();
    println!(
        "served={}  throughput={:.2} req/s  mean_batch={:.2}\n\
         latency: mean={:.1} ms  p50={:.1} ms  p95={:.1} ms",
        stats.served,
        stats.throughput_rps,
        stats.mean_batch,
        stats.latency.mean / 1e6,
        stats.latency.median / 1e6,
        stats.latency.p95 / 1e6,
    );
    for p in Priority::ALL {
        let cls = stats.class(p);
        if cls.served == 0 {
            continue;
        }
        println!(
            "  {:<12} served={:<4} p50={:.1} ms  p95={:.1} ms  deadline miss {}/{} ({:.0}%)",
            p.name(),
            cls.served,
            cls.latency.median / 1e6,
            cls.latency.p95 / 1e6,
            cls.deadline_missed,
            cls.deadline_total,
            cls.miss_rate() * 100.0,
        );
    }
    if !stats.batch_hist.is_empty() {
        let hist: Vec<String> = stats
            .batch_hist
            .iter()
            .map(|(b, n)| format!("{b}x{n}"))
            .collect();
        println!("batch sizes: {}", hist.join("  "));
    }
    if let Some((lo, hi)) = stats.cap_range {
        println!("adaptive caps: {lo}..{hi} workers per batch");
    }
}

fn cmd_tune(args: &Args) {
    let arch = parse_model(args);
    let batch = args.get_parsed("batch", 1usize);
    let res = args.get_parsed("res", 224usize);
    let sparsity = args.get_parsed("sparsity", 0.5f64);
    let tile_cap = args.get_parsed("tile-cap", 16usize);
    let use_sim = !args.has_flag("native");
    let cache_path = args.get_or("cache", "artifacts/tune_cache.tsv");
    let mut cache = tuner::TuneCache::load(&cache_path);
    let g = build_model(arch, batch, res);
    println!(
        "tuning {} layers of {} ({}); cache: {cache_path}",
        g.conv_shapes().len(),
        arch.name(),
        if use_sim { "sim cycles" } else { "native wall-clock" }
    );
    println!(
        "{:<16} {:>6} {:>6} {:>6} {:>8} {:>6} {:>14}",
        "layer", "LMUL", "T", "P", "kernel", "dtype", "score"
    );
    // Native profiling must run on the deployment-sized pool: the tuner
    // now also selects each layer's parallelism degree P, and a cap is
    // only meaningful relative to the pool it was measured on
    // (--threads N, NMPRUNE_THREADS, or all hardware threads).
    let profile_pool = match args.get("threads") {
        None => ThreadPool::global(),
        Some(_) => ThreadPool::shared(args.get_parsed("threads", 1)),
    };
    for (name, shape) in g.conv_shapes() {
        let key = tuner::cache_key(&shape, Some(sparsity));
        cache.get_or_tune(key, || {
            let r = if use_sim {
                tuner::tune_sim_colwise(&shape, sparsity, tile_cap)
            } else {
                tuner::tune_native(&shape, Some(sparsity), &profile_pool, tile_cap)
            };
            println!(
                "{:<16} {:>6} {:>6} {:>6} {:>8} {:>6} {:>14.0}",
                name,
                r.best.lmul,
                r.best.tile,
                r.best.threads,
                r.best.kernel.name(),
                r.best.dtype.name(),
                r.best.score
            );
            r.choice()
        });
    }
    cache.save(&cache_path).expect("save cache");
    println!("saved {} entries", cache.entries.len());
}

/// List the compiled-in micro-kernel backends and their availability on
/// this host. `--best` prints only the best available backend's name —
/// the scripting hook CI uses to force the native backend
/// (`NMPRUNE_KERNEL=$(nmprune kernels --best)`).
fn cmd_kernels(args: &Args) {
    use nmprune::gemm::kernels;

    let best = kernels::best_available();
    if args.has_flag("best") {
        println!("{}", best.name());
        return;
    }
    println!(
        "{:<10} {:>10} {:>6} {:>6}",
        "kernel", "available", "int8", "best"
    );
    for k in kernels::registry() {
        let id = k.id();
        println!(
            "{:<10} {:>10} {:>6} {:>6}",
            id.name(),
            if k.available() { "yes" } else { "no" },
            if k.i8_native() { "yes" } else { "no" },
            if id == best { "*" } else { "" },
        );
    }
    match kernels::forced() {
        Some(f) => println!("NMPRUNE_KERNEL forces: {}", f.name()),
        None => println!("no NMPRUNE_KERNEL override (auto -> {})", best.name()),
    }
}

fn cmd_sim(args: &Args) {
    use nmprune::im2col::pack_data_matrix;
    use nmprune::pruning::{prune_colwise_adaptive, prune_rownm};
    use nmprune::rvv::kernels::{
        sim_gemm_dense, sim_spmm_colwise, sim_spmm_outer_rownm,
    };
    use nmprune::rvv::RvvMachine;
    use nmprune::tensor::layout::oihw_to_filter_matrix;

    let layers = resnet50_fig5_layers(1);
    let li = args.get_parsed("layer", 1usize).min(layers.len() - 1);
    let l = &layers[li];
    let lmul = args.get_parsed("lmul", 2usize);
    let sparsity = args.get_parsed("sparsity", 0.5f64);
    let s = l.shape;
    println!(
        "simulating {} {} at sparsity {sparsity}, LMUL={lmul}",
        l.name, s
    );

    let mut rng = XorShiftRng::new(3);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
    let f = oihw_to_filter_matrix(&w);
    // Bounded columns for a quick CLI demo.
    let m0 = RvvMachine::k1();
    let v = m0.vlmax(lmul);
    let cols = s.gemm_cols().min(16 * v);
    let a = rng.normal_vec(s.k() * cols, 1.0);
    let packed = pack_data_matrix(&a, s.k(), cols, v);

    let n = nmprune::pruning::retained_for_sparsity(4, sparsity);
    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "kernel", "L1 loads", "instrs", "cycles"
    );
    let mut m = RvvMachine::k1();
    let (_, dense) = sim_gemm_dense(&mut m, &f.data, s.c_out, &packed, 8, lmul);
    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "dense", dense.l1_loads, dense.instructions, dense.cycles
    );
    let rp = prune_rownm(&f.data, s.c_out, s.k(), n, 4);
    let mut m = RvvMachine::k1();
    let (_, outer) = sim_spmm_outer_rownm(&mut m, &rp, &packed, lmul);
    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "conventional N:M", outer.l1_loads, outer.instructions, outer.cycles
    );
    let cp = prune_colwise_adaptive(&f.data, s.c_out, s.k(), 8, sparsity);
    let mut m = RvvMachine::k1();
    let (_, col) = sim_spmm_colwise(&mut m, &cp, &packed, lmul);
    println!(
        "{:<22} {:>12} {:>12} {:>12}",
        "column-wise (ours)", col.l1_loads, col.instructions, col.cycles
    );
    println!(
        "\nspeedup vs dense: conventional {:.2}x, column-wise {:.2}x",
        dense.cycles as f64 / outer.cycles as f64,
        dense.cycles as f64 / col.cycles as f64
    );
}

fn cmd_bench_diff(args: &Args) {
    use nmprune::benchlib::report::DiffStatus;
    use nmprune::benchlib::{diff_reports, Report, Table};

    let (Some(old_path), Some(new_path)) = (args.positional.get(1), args.positional.get(2))
    else {
        eprintln!("usage: nmprune bench-diff <old.json> <new.json> [--threshold-pct X]");
        std::process::exit(2);
    };
    let threshold = args.get_parsed("threshold-pct", 10.0f64);
    let load = |p: &str| {
        Report::load(std::path::Path::new(p)).unwrap_or_else(|e| {
            eprintln!("bench-diff: {e}");
            std::process::exit(2);
        })
    };
    let old = load(old_path);
    let new = load(new_path);
    println!(
        "comparing {} ({} records) -> {} ({} records), threshold {threshold:.0}%",
        old_path,
        old.records.len(),
        new_path,
        new.records.len()
    );

    let diff = diff_reports(&old, &new, threshold);
    let mut t = Table::new(
        "bench-diff",
        &["record", "metric", "old", "new", "delta", "status"],
    );
    for e in &diff.entries {
        // %-of-peak prints with a decimal; raw medians (ns/cycles) are
        // large integers.
        let fmt = |v: f64| {
            if e.metric == "%peak" || v.abs() < 100.0 {
                format!("{v:.1}")
            } else {
                format!("{v:.0}")
            }
        };
        let status = match e.status {
            DiffStatus::Regression if e.gated => "REGRESSION".to_string(),
            DiffStatus::Regression => "regression (ungated)".to_string(),
            DiffStatus::Improvement => "improvement".to_string(),
            DiffStatus::Unchanged => "ok".to_string(),
            DiffStatus::OnlyOld => "removed".to_string(),
            DiffStatus::OnlyNew => "added".to_string(),
        };
        t.row(&[
            e.key.clone(),
            e.metric.clone(),
            fmt(e.old),
            fmt(e.new),
            format!("{:+.1}%", e.delta_pct),
            status,
        ]);
    }
    t.print();
    println!(
        "{} records: {} gated regressions, {} improvements beyond {threshold:.0}%",
        diff.entries.len(),
        diff.regressions(),
        diff.improvements()
    );
    if diff.has_regressions() {
        eprintln!("bench-diff: FAIL — gated regressions beyond threshold");
        std::process::exit(1);
    }
}

fn cmd_lint(args: &Args) {
    use nmprune::analysis;

    // Default to the whole working tree so the CI gate also covers
    // tests, benches and examples — the invariants hold everywhere.
    // The arg parser binds `--json <path>` as an option whose value is
    // the path, so accept the path from either position.
    let json = args.has_flag("json") || args.get("json").is_some();
    let root = args
        .positional
        .get(1)
        .map(String::as_str)
        .or_else(|| args.get("json"))
        .unwrap_or(".")
        .to_string();
    let findings = analysis::lint_tree(Path::new(&root)).unwrap_or_else(|e| {
        eprintln!("lint: {e}");
        std::process::exit(2);
    });
    if json {
        println!("{}", analysis::render_json(&root, &findings));
    } else {
        print!("{}", analysis::render_text(&findings));
    }
    if !findings.is_empty() {
        std::process::exit(1);
    }
}

fn cmd_artifacts(args: &Args) {
    let manifest = args.get_or("manifest", "artifacts/manifest.tsv");
    let rt = nmprune::runtime::PjrtRuntime::cpu().expect("pjrt client");
    println!("platform: {}", rt.platform());
    let names = rt
        .load_manifest(std::path::Path::new(&manifest))
        .expect("load manifest (run `make artifacts` first)");
    println!("loaded {} artifacts: {names:?}", names.len());
}
