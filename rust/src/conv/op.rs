//! The three convolution execution paths of the evaluation (§4.4–4.6):
//!
//! 1. **Dense NHWC** — the SiFive-XNNPACK baseline: indirection buffer +
//!    dense GEMM over NHWC activations.
//! 2. **Dense CNHW** — fused im2col/pack + dense packed GEMM.
//! 3. **Sparse CNHW** — fused im2col/pack + column-wise N:M SpMM
//!    (Algorithm 1): the paper's full pipeline.
//!
//! Each operator is constructed once per layer (weights packed /
//! compressed ahead of time, off the hot path) and then invoked per
//! request with a caller-supplied persistent [`ThreadPool`] — the run
//! methods never spawn threads, and a pool of size 1 executes the
//! identical strip arithmetic serially on the calling thread.
//!
//! Every operator carries a per-layer parallelism cap `threads`
//! (0 = occupy the whole pool): the third knob the tuner selects, set
//! via [`Conv2dDenseCnhw::with_thread_cap`] and friends, and applied to
//! the pool dispatch on every `run`. Caps never change the strip
//! arithmetic, so outputs are identical across caps.

use std::cell::RefCell;

use super::shape::ConvShape;
use crate::gemm::threaded::{
    gemm_dense_i8_parallel_capped_into_with, gemm_dense_parallel_capped,
    gemm_dense_parallel_capped_into_with, spmm_colwise_i8_parallel_capped_into_with,
    spmm_colwise_parallel_capped_into_with,
};
use crate::gemm::KernelId;
use crate::im2col::{
    conv2d_indirect_nhwc_parallel_capped_into, fused_im2col_pack_cnhw_into, quantize_panel_into,
    IndirectionBuffer, PackedMatrix, QuantPanel,
};
use crate::pruning::{
    prune_colwise, prune_colwise_adaptive, ColwisePruned, ColwiseQuant, QuantDense,
};
use crate::tensor::layout::oihw_to_filter_matrix;
use crate::tensor::{Dtype, Tensor};
use crate::util::threadpool::ThreadPool;

thread_local! {
    /// Per-thread packed-matrix scratch reused across conv invocations
    /// (§Perf step 3): keeps the multi-MB strip buffer's pages resident
    /// instead of re-faulting a fresh allocation per layer.
    static PACK_SCRATCH: RefCell<PackedMatrix> = RefCell::new(PackedMatrix::zeros(1, 1, 1));
    /// Per-thread quantized-panel scratch for i8 layers, same reuse
    /// rationale (the arena path supplies its own instead).
    static QUANT_SCRATCH: RefCell<QuantPanel> = RefCell::new(QuantPanel::zeros(1, 1, 1));
}

/// Which execution path a layer uses (tuner output / config input).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvPath {
    DenseNhwc,
    DenseCnhw,
    SparseCnhw,
}

/// Compose the layer's tuned cap with a caller-supplied per-run cap
/// (both in the `0 = uncapped` encoding): the effective cap is the min
/// of whichever are set, so an adaptive server can only tighten — never
/// widen — what the tuner chose for a layer.
pub fn compose_caps(layer: usize, run: usize) -> Option<usize> {
    match (layer, run) {
        (0, 0) => None,
        (0, r) => Some(r),
        (l, 0) => Some(l),
        (l, r) => Some(l.min(r)),
    }
}

/// Dense NHWC conv (XNNPACK-style indirect convolution).
pub struct Conv2dDenseNhwc {
    pub shape: ConvShape,
    /// Parallelism cap (0 = whole pool).
    pub threads: usize,
    filter: Vec<f32>,
    ib: IndirectionBuffer,
}

impl Conv2dDenseNhwc {
    /// Pack weights (OIHW) and build the indirection buffer.
    pub fn new(shape: ConvShape, w_oihw: &Tensor) -> Self {
        assert_eq!(w_oihw.shape, vec![shape.c_out, shape.c_in, shape.kh, shape.kw]);
        Self::from_filter_matrix(shape, oihw_to_filter_matrix(w_oihw).data)
    }

    /// Build from an already-flattened `[C_out, K]` filter matrix
    /// (k-major/channel-inner rows) — the AOT-artifact load path, which
    /// must not re-derive weights.
    pub fn from_filter_matrix(shape: ConvShape, filter: Vec<f32>) -> Self {
        assert_eq!(filter.len(), shape.c_out * shape.k(), "filter matrix length");
        Self {
            shape,
            threads: 0,
            filter,
            ib: IndirectionBuffer::build(&shape),
        }
    }

    /// The flattened `[C_out, K]` filter matrix (artifact writer input).
    pub fn filter(&self) -> &[f32] {
        &self.filter
    }

    /// Set the per-layer parallelism cap (0 = whole pool).
    pub fn with_thread_cap(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Run on an NHWC input, producing NHWC output.
    pub fn run(&self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        self.run_capped(x, pool, 0)
    }

    /// [`Conv2dDenseNhwc::run`] with an additional per-run cap
    /// (0 = none) composed onto the layer cap via [`compose_caps`].
    pub fn run_capped(&self, x: &Tensor, pool: &ThreadPool, run_cap: usize) -> Tensor {
        let s = &self.shape;
        let mut out = Tensor::zeros(&[s.n, s.h_out(), s.w_out(), s.c_out]);
        self.run_capped_into(x, pool, run_cap, &mut out);
        out
    }

    /// [`Conv2dDenseNhwc::run_capped`] into a caller-provided output
    /// tensor shaped `[N, H_out, W_out, C_out]` (zero-alloc path).
    // nmprune: zero-alloc
    pub fn run_capped_into(&self, x: &Tensor, pool: &ThreadPool, run_cap: usize, out: &mut Tensor) {
        conv2d_indirect_nhwc_parallel_capped_into(
            x,
            &self.filter,
            &self.shape,
            &self.ib,
            pool,
            compose_caps(self.threads, run_cap),
            out,
        );
    }
}

/// Dense CNHW conv: fused im2col/pack + dense packed GEMM.
pub struct Conv2dDenseCnhw {
    pub shape: ConvShape,
    pub v: usize,
    pub tile: usize,
    /// Parallelism cap (0 = whole pool).
    pub threads: usize,
    /// Micro-kernel backend ([`KernelId::Auto`] = runtime dispatch):
    /// the fourth tuned knob.
    pub kernel: KernelId,
    /// Compute datatype — the fifth tuned knob. `I8` quantizes weights
    /// at construction ([`Conv2dDenseCnhw::with_dtype`]) and the packed
    /// panel per run; `F32` is the historical path, untouched.
    pub dtype: Dtype,
    filter: Vec<f32>,
    /// Quantized filter, present iff `dtype == I8` (derived from
    /// `filter` deterministically — never stored in artifacts).
    qfilter: Option<QuantDense>,
}

impl Conv2dDenseCnhw {
    pub fn new(shape: ConvShape, w_oihw: &Tensor, v: usize, tile: usize) -> Self {
        assert_eq!(w_oihw.shape, vec![shape.c_out, shape.c_in, shape.kh, shape.kw]);
        Self::from_filter_matrix(shape, oihw_to_filter_matrix(w_oihw).data, v, tile)
    }

    /// Build from an already-flattened `[C_out, K]` filter matrix
    /// (AOT-artifact load path).
    pub fn from_filter_matrix(shape: ConvShape, filter: Vec<f32>, v: usize, tile: usize) -> Self {
        assert_eq!(filter.len(), shape.c_out * shape.k(), "filter matrix length");
        Self {
            shape,
            v,
            tile,
            threads: 0,
            kernel: KernelId::Auto,
            dtype: Dtype::F32,
            filter,
            qfilter: None,
        }
    }

    /// The flattened `[C_out, K]` filter matrix (artifact writer input).
    pub fn filter(&self) -> &[f32] {
        &self.filter
    }

    /// Set the per-layer parallelism cap (0 = whole pool).
    pub fn with_thread_cap(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the micro-kernel backend (tuner/artifact choice).
    pub fn with_kernel(mut self, kernel: KernelId) -> Self {
        self.kernel = kernel;
        self
    }

    /// Set the compute datatype (tuner/artifact choice). Quantizes the
    /// filter here, at construction — off the hot path; the f32 master
    /// filter is kept as the source of truth for artifact writing.
    pub fn with_dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self.qfilter = match dtype {
            Dtype::I8 => Some(QuantDense::quantize(
                &self.filter,
                self.shape.c_out,
                self.shape.k(),
            )),
            Dtype::F32 => None,
        };
        self
    }

    /// Run on a CNHW input, producing CNHW output
    /// `[C_out, N, H_out, W_out]`.
    pub fn run(&self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        self.run_capped(x, pool, 0)
    }

    /// [`Conv2dDenseCnhw::run`] with an additional per-run cap
    /// (0 = none) composed onto the layer cap via [`compose_caps`].
    pub fn run_capped(&self, x: &Tensor, pool: &ThreadPool, run_cap: usize) -> Tensor {
        let s = &self.shape;
        let mut out = Tensor::zeros(&[s.c_out, s.n, s.h_out(), s.w_out()]);
        PACK_SCRATCH.with(|pack| {
            QUANT_SCRATCH.with(|quant| {
                self.run_capped_into(
                    x,
                    pool,
                    run_cap,
                    &mut pack.borrow_mut(),
                    &mut quant.borrow_mut(),
                    &mut out,
                );
            });
        });
        out
    }

    /// [`Conv2dDenseCnhw::run_capped`] packing into a caller-provided
    /// [`PackedMatrix`] (plus a [`QuantPanel`], used only on i8 layers)
    /// and writing a caller-provided CNHW output tensor — the
    /// arena-driven zero-alloc path. Bitwise identical to `run_capped`,
    /// which routes through this body.
    // nmprune: zero-alloc
    pub fn run_capped_into(
        &self,
        x: &Tensor,
        pool: &ThreadPool,
        run_cap: usize,
        packed: &mut PackedMatrix,
        qpanel: &mut QuantPanel,
        out: &mut Tensor,
    ) {
        let s = &self.shape;
        assert_eq!(out.shape, [s.c_out, s.n, s.h_out(), s.w_out()], "output tensor shape");
        fused_im2col_pack_cnhw_into(x, s, self.v, packed);
        match self.dtype {
            Dtype::F32 => gemm_dense_parallel_capped_into_with(
                &self.filter,
                s.c_out,
                packed,
                self.tile,
                pool,
                compose_caps(self.threads, run_cap),
                self.kernel,
                &mut out.data,
            ),
            Dtype::I8 => {
                quantize_panel_into(packed, qpanel);
                let qf = self
                    .qfilter
                    .as_ref()
                    .expect("i8 dtype always carries a quantized filter (with_dtype)");
                gemm_dense_i8_parallel_capped_into_with(
                    qf,
                    qpanel,
                    self.tile,
                    pool,
                    compose_caps(self.threads, run_cap),
                    self.kernel,
                    &mut out.data,
                );
            }
        }
    }
}

/// Dense NCHW conv — the §5 alternative layout (Elsen et al. [13]):
/// per-image fused im2col/pack (strips cannot span batches) + one dense
/// packed GEMM per image. Exists so §5's CNHW-vs-NCHW discussion is
/// *measured* (ablation C) rather than asserted.
pub struct Conv2dDenseNchw {
    pub shape: ConvShape,
    pub v: usize,
    pub tile: usize,
    /// Parallelism cap (0 = whole pool).
    pub threads: usize,
    filter: Vec<f32>,
}

impl Conv2dDenseNchw {
    pub fn new(shape: ConvShape, w_oihw: &Tensor, v: usize, tile: usize) -> Self {
        assert_eq!(w_oihw.shape, vec![shape.c_out, shape.c_in, shape.kh, shape.kw]);
        Self {
            shape,
            v,
            tile,
            threads: 0,
            filter: oihw_to_filter_matrix(w_oihw).data,
        }
    }

    /// Set the per-layer parallelism cap (0 = whole pool).
    pub fn with_thread_cap(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Run on an NCHW input `[N, C_in, H, W]`, producing NCHW output
    /// `[N, C_out, H_out, W_out]`.
    pub fn run(&self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        self.run_capped(x, pool, 0)
    }

    /// [`Conv2dDenseNchw::run`] with an additional per-run cap
    /// (0 = none) composed onto the layer cap via [`compose_caps`].
    pub fn run_capped(&self, x: &Tensor, pool: &ThreadPool, run_cap: usize) -> Tensor {
        let s = &self.shape;
        let (ho, wo) = (s.h_out(), s.w_out());
        let per_image = crate::im2col::fused_im2col_pack_nchw(x, s, self.v);
        let img_out = s.c_out * ho * wo;
        let mut out = Tensor::zeros(&[s.n, s.c_out, ho, wo]);
        for (n, p) in per_image.iter().enumerate() {
            let y = gemm_dense_parallel_capped(
                &self.filter,
                s.c_out,
                p,
                self.tile,
                pool,
                compose_caps(self.threads, run_cap),
            );
            out.data[n * img_out..(n + 1) * img_out].copy_from_slice(&y);
        }
        out
    }
}

/// Sparse CNHW conv — the paper's pipeline: column-wise N:M weights +
/// fused im2col/pack + Algorithm-1 SpMM.
pub struct Conv2dSparseCnhw {
    pub shape: ConvShape,
    pub v: usize,
    /// Parallelism cap (0 = whole pool).
    pub threads: usize,
    /// Micro-kernel backend ([`KernelId::Auto`] = runtime dispatch):
    /// the fourth tuned knob.
    pub kernel: KernelId,
    /// Compute datatype — the fifth tuned knob (see
    /// [`Conv2dSparseCnhw::with_dtype`]).
    pub dtype: Dtype,
    pub weights: ColwisePruned,
    /// Quantized weights, present iff `dtype == I8` (derived from
    /// `weights` deterministically — never stored in artifacts).
    qweights: Option<ColwiseQuant>,
}

impl Conv2dSparseCnhw {
    /// Compress OIHW weights column-wise with explicit N:M groups.
    /// `m` must divide `shape.k()` (see [`prune_colwise`]'s contract).
    pub fn new(shape: ConvShape, w_oihw: &Tensor, v: usize, tile: usize, n: usize, m: usize) -> Self {
        assert_eq!(w_oihw.shape, vec![shape.c_out, shape.c_in, shape.kh, shape.kw]);
        let f = oihw_to_filter_matrix(w_oihw);
        let weights = prune_colwise(&f.data, shape.c_out, shape.k(), tile, n, m);
        Self::from_pruned(shape, weights, v)
    }

    /// Build from already-compressed column-wise N:M weights (the
    /// AOT-artifact load path — no re-pruning, the stored compressed
    /// form is used verbatim so logits stay bitwise identical).
    pub fn from_pruned(shape: ConvShape, weights: ColwisePruned, v: usize) -> Self {
        assert_eq!(weights.rows, shape.c_out, "pruned rows != C_out");
        assert_eq!(weights.cols, shape.k(), "pruned cols != K");
        Self {
            shape,
            v,
            threads: 0,
            kernel: KernelId::Auto,
            dtype: Dtype::F32,
            weights,
            qweights: None,
        }
    }

    /// Adaptive-M variant: M = K (whole reduction dim), N from sparsity.
    pub fn new_adaptive(
        shape: ConvShape,
        w_oihw: &Tensor,
        v: usize,
        tile: usize,
        sparsity: f64,
    ) -> Self {
        let f = oihw_to_filter_matrix(w_oihw);
        Self {
            shape,
            v,
            threads: 0,
            kernel: KernelId::Auto,
            dtype: Dtype::F32,
            weights: prune_colwise_adaptive(&f.data, shape.c_out, shape.k(), tile, sparsity),
            qweights: None,
        }
    }

    /// Set the per-layer parallelism cap (0 = whole pool).
    pub fn with_thread_cap(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the micro-kernel backend (tuner/artifact choice).
    pub fn with_kernel(mut self, kernel: KernelId) -> Self {
        self.kernel = kernel;
        self
    }

    /// Set the compute datatype (tuner/artifact choice). Quantizes the
    /// compressed weights here, at construction — off the hot path; the
    /// f32 compressed form stays the source of truth for artifacts.
    pub fn with_dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self.qweights = match dtype {
            Dtype::I8 => Some(ColwiseQuant::quantize(&self.weights)),
            Dtype::F32 => None,
        };
        self
    }

    /// Run on a CNHW input, producing CNHW output.
    pub fn run(&self, x: &Tensor, pool: &ThreadPool) -> Tensor {
        self.run_capped(x, pool, 0)
    }

    /// [`Conv2dSparseCnhw::run`] with an additional per-run cap
    /// (0 = none) composed onto the layer cap via [`compose_caps`].
    pub fn run_capped(&self, x: &Tensor, pool: &ThreadPool, run_cap: usize) -> Tensor {
        let s = &self.shape;
        let mut out = Tensor::zeros(&[s.c_out, s.n, s.h_out(), s.w_out()]);
        PACK_SCRATCH.with(|pack| {
            QUANT_SCRATCH.with(|quant| {
                self.run_capped_into(
                    x,
                    pool,
                    run_cap,
                    &mut pack.borrow_mut(),
                    &mut quant.borrow_mut(),
                    &mut out,
                );
            });
        });
        out
    }

    /// [`Conv2dSparseCnhw::run_capped`] packing into a caller-provided
    /// [`PackedMatrix`] (plus a [`QuantPanel`], used only on i8 layers)
    /// and writing a caller-provided CNHW output tensor — the
    /// arena-driven zero-alloc path.
    // nmprune: zero-alloc
    pub fn run_capped_into(
        &self,
        x: &Tensor,
        pool: &ThreadPool,
        run_cap: usize,
        packed: &mut PackedMatrix,
        qpanel: &mut QuantPanel,
        out: &mut Tensor,
    ) {
        let s = &self.shape;
        assert_eq!(out.shape, [s.c_out, s.n, s.h_out(), s.w_out()], "output tensor shape");
        fused_im2col_pack_cnhw_into(x, s, self.v, packed);
        match self.dtype {
            Dtype::F32 => spmm_colwise_parallel_capped_into_with(
                &self.weights,
                packed,
                pool,
                compose_caps(self.threads, run_cap),
                self.kernel,
                &mut out.data,
            ),
            Dtype::I8 => {
                quantize_panel_into(packed, qpanel);
                let qw = self
                    .qweights
                    .as_ref()
                    .expect("i8 dtype always carries quantized weights (with_dtype)");
                spmm_colwise_i8_parallel_capped_into_with(
                    qw,
                    qpanel,
                    pool,
                    compose_caps(self.threads, run_cap),
                    self.kernel,
                    &mut out.data,
                );
            }
        }
    }

    /// Effective sparsity of the compressed weights.
    pub fn sparsity(&self) -> f64 {
        self.weights.sparsity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::naive::conv2d_direct_cnhw;
    use crate::tensor::layout::{cnhw_to_nhwc, nhwc_to_cnhw};
    use crate::util::{allclose, XorShiftRng};

    fn rand_case(seed: u64, s: ConvShape) -> (Tensor, Tensor) {
        let mut r = XorShiftRng::new(seed);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
        (x, w)
    }

    #[test]
    fn dense_cnhw_matches_direct() {
        for (seed, s) in [
            (1, ConvShape::square(1, 3, 8, 5, 3, 1, 1)),
            (2, ConvShape::square(2, 4, 9, 6, 3, 2, 1)),
            (3, ConvShape::square(1, 2, 12, 4, 7, 2, 3)),
            (4, ConvShape::square(2, 8, 5, 7, 1, 1, 0)),
        ] {
            let (x, w) = rand_case(seed, s);
            let want = conv2d_direct_cnhw(&x, &w, &s);
            for threads in [1, 4] {
                let pool = ThreadPool::new(threads);
                let op = Conv2dDenseCnhw::new(s, &w, 16, 8);
                let got = op.run(&x, &pool);
                assert!(
                    allclose(&got.data, &want.data, 1e-4, 1e-5),
                    "{s} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn dense_nhwc_matches_dense_cnhw_modulo_layout() {
        let s = ConvShape::square(2, 3, 7, 5, 3, 1, 1);
        let (x_cnhw, w) = rand_case(9, s);
        let pool = ThreadPool::new(1);
        let cnhw_op = Conv2dDenseCnhw::new(s, &w, 8, 4);
        let nhwc_op = Conv2dDenseNhwc::new(s, &w);
        let y_cnhw = cnhw_op.run(&x_cnhw, &pool);
        let y_nhwc = nhwc_op.run(&cnhw_to_nhwc(&x_cnhw), &pool);
        let y_roundtrip = nhwc_to_cnhw(&y_nhwc);
        assert!(allclose(&y_cnhw.data, &y_roundtrip.data, 1e-4, 1e-5));
    }

    #[test]
    fn sparse_matches_direct_on_masked_weights() {
        let s = ConvShape::square(1, 4, 8, 8, 3, 1, 1);
        let (x, w) = rand_case(11, s);
        let op = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4);
        // Oracle: decompress the mask back to OIHW and conv directly.
        let masked_filter = op.weights.decompress();
        let k = s.k();
        // filter row k-major/channel-inner -> OIHW
        let mut w_masked = Tensor::zeros(&[s.c_out, s.c_in, s.kh, s.kw]);
        for o in 0..s.c_out {
            for kh in 0..s.kh {
                for kw in 0..s.kw {
                    for c in 0..s.c_in {
                        let kk = (kh * s.kw + kw) * s.c_in + c;
                        *w_masked.at_mut(&[o, c, kh, kw]) = masked_filter[o * k + kk];
                    }
                }
            }
        }
        let want = conv2d_direct_cnhw(&x, &w_masked, &s);
        for threads in [1, 3] {
            let pool = ThreadPool::new(threads);
            let got = op.run(&x, &pool);
            assert!(allclose(&got.data, &want.data, 1e-4, 1e-5), "threads={threads}");
        }
        assert!((op.sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn compose_caps_takes_the_min_of_set_caps() {
        assert_eq!(compose_caps(0, 0), None);
        assert_eq!(compose_caps(0, 3), Some(3));
        assert_eq!(compose_caps(2, 0), Some(2));
        assert_eq!(compose_caps(2, 3), Some(2));
        assert_eq!(compose_caps(4, 1), Some(1));
    }

    /// A per-run cap is the same scheduling-only knob as the layer cap:
    /// outputs stay bitwise identical for every composition.
    #[test]
    fn run_capped_never_changes_conv_outputs() {
        let s = ConvShape::square(1, 4, 8, 8, 3, 1, 1);
        let (x, w) = rand_case(23, s);
        let pool = ThreadPool::new(4);
        let sp = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4).with_thread_cap(3);
        let de = Conv2dDenseCnhw::new(s, &w, 16, 4).with_thread_cap(3);
        let base_sparse = sp.run(&x, &pool);
        let base_dense = de.run(&x, &pool);
        for run_cap in [0usize, 1, 2, 4, 7] {
            assert_eq!(
                sp.run_capped(&x, &pool, run_cap).data,
                base_sparse.data,
                "sparse run_cap={run_cap}"
            );
            assert_eq!(
                de.run_capped(&x, &pool, run_cap).data,
                base_dense.data,
                "dense run_cap={run_cap}"
            );
        }
    }

    #[test]
    fn thread_caps_never_change_conv_outputs() {
        let s = ConvShape::square(1, 4, 8, 8, 3, 1, 1);
        let (x, w) = rand_case(17, s);
        let pool = ThreadPool::new(4);
        let base_sparse = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4).run(&x, &pool);
        let base_dense = Conv2dDenseCnhw::new(s, &w, 16, 4).run(&x, &pool);
        let base_nhwc = Conv2dDenseNhwc::new(s, &w).run(&cnhw_to_nhwc(&x), &pool);
        for cap in [1usize, 2, 3, 4, 7] {
            let sp = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4).with_thread_cap(cap);
            assert_eq!(sp.run(&x, &pool).data, base_sparse.data, "sparse cap={cap}");
            let de = Conv2dDenseCnhw::new(s, &w, 16, 4).with_thread_cap(cap);
            assert_eq!(de.run(&x, &pool).data, base_dense.data, "dense cap={cap}");
            let nh = Conv2dDenseNhwc::new(s, &w).with_thread_cap(cap);
            // NHWC accumulates in the same order per output position
            // regardless of worker count, so this is bitwise too.
            assert_eq!(
                nh.run(&cnhw_to_nhwc(&x), &pool).data,
                base_nhwc.data,
                "nhwc cap={cap}"
            );
        }
    }

    /// The arena path: one packed-matrix scratch and one output tensor
    /// shared across repeated runs of different ops must reproduce the
    /// allocating path bitwise every time.
    #[test]
    fn run_capped_into_reuses_scratch_bitwise() {
        let s = ConvShape::square(1, 4, 8, 8, 3, 1, 1);
        let (x, w) = rand_case(29, s);
        let pool = ThreadPool::new(2);
        let sp = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4);
        let de = Conv2dDenseCnhw::new(s, &w, 16, 4);
        let want_sp = sp.run(&x, &pool);
        let want_de = de.run(&x, &pool);
        let mut packed = PackedMatrix::zeros(1, 1, 1);
        let mut qpanel = QuantPanel::zeros(1, 1, 1);
        let mut out = Tensor::zeros(&want_sp.shape);
        for round in 0..3 {
            sp.run_capped_into(&x, &pool, 0, &mut packed, &mut qpanel, &mut out);
            assert_eq!(out.data, want_sp.data, "sparse round {round}");
            de.run_capped_into(&x, &pool, 0, &mut packed, &mut qpanel, &mut out);
            assert_eq!(out.data, want_de.data, "dense round {round}");
        }
    }

    /// The i8 dtype knob: outputs approximate the f32 path within the
    /// quantization budget, are bitwise identical across thread caps
    /// and kernels, and the arena path reproduces the thread-local
    /// scratch path exactly.
    #[test]
    fn i8_dtype_tracks_f32_and_is_deterministic() {
        use crate::tensor::Dtype;
        let s = ConvShape::square(1, 4, 8, 8, 3, 1, 1);
        let (x, w) = rand_case(37, s);
        let pool = ThreadPool::new(4);
        let sp_f32 = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4);
        let de_f32 = Conv2dDenseCnhw::new(s, &w, 16, 4);
        let sp_i8 = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4).with_dtype(Dtype::I8);
        let de_i8 = Conv2dDenseCnhw::new(s, &w, 16, 4).with_dtype(Dtype::I8);
        let want_sp = sp_i8.run(&x, &pool);
        let want_de = de_i8.run(&x, &pool);
        // Approximation: inputs in [-1,1], weights in [-0.5,0.5],
        // k = 36 — the worst-case bound is far below this tolerance.
        assert!(allclose(&want_sp.data, &sp_f32.run(&x, &pool).data, 0.0, 0.2));
        assert!(allclose(&want_de.data, &de_f32.run(&x, &pool).data, 0.0, 0.2));
        // Determinism across caps and backends (i8 is bitwise across
        // kernels, stronger than the f32 per-kernel contract).
        for cap in [1usize, 2, 3, 7] {
            let spc = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4)
                .with_dtype(Dtype::I8)
                .with_thread_cap(cap);
            assert_eq!(spc.run(&x, &pool).data, want_sp.data, "sparse cap={cap}");
        }
        for id in crate::gemm::kernels::available_ids() {
            let spk = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4)
                .with_dtype(Dtype::I8)
                .with_kernel(id);
            let dek = Conv2dDenseCnhw::new(s, &w, 16, 4)
                .with_dtype(Dtype::I8)
                .with_kernel(id);
            assert_eq!(spk.run(&x, &pool).data, want_sp.data, "sparse {id}");
            assert_eq!(dek.run(&x, &pool).data, want_de.data, "dense {id}");
        }
        // Arena path bitwise-matches the thread-local scratch path.
        let mut packed = PackedMatrix::zeros(1, 1, 1);
        let mut qpanel = QuantPanel::zeros(1, 1, 1);
        let mut out = Tensor::zeros(&want_sp.shape);
        sp_i8.run_capped_into(&x, &pool, 0, &mut packed, &mut qpanel, &mut out);
        assert_eq!(out.data, want_sp.data);
        de_i8.run_capped_into(&x, &pool, 0, &mut packed, &mut qpanel, &mut out);
        assert_eq!(out.data, want_de.data);
    }

    /// Every available micro-kernel backend is a drop-in on the conv
    /// ops (strict parity lives in rust/tests/conv_fuzz.rs).
    #[test]
    fn explicit_kernel_choices_agree_across_backends() {
        let s = ConvShape::square(1, 4, 8, 8, 3, 1, 1);
        let (x, w) = rand_case(31, s);
        let pool = ThreadPool::new(2);
        let want_sp = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4)
            .with_kernel(KernelId::Scalar)
            .run(&x, &pool);
        let want_de = Conv2dDenseCnhw::new(s, &w, 16, 4)
            .with_kernel(KernelId::Scalar)
            .run(&x, &pool);
        for id in crate::gemm::kernels::available_ids() {
            let got_sp = Conv2dSparseCnhw::new(s, &w, 16, 4, 2, 4)
                .with_kernel(id)
                .run(&x, &pool);
            let got_de = Conv2dDenseCnhw::new(s, &w, 16, 4).with_kernel(id).run(&x, &pool);
            assert!(allclose(&got_sp.data, &want_sp.data, 1e-4, 1e-5), "sparse {id}");
            assert!(allclose(&got_de.data, &want_de.data, 1e-4, 1e-5), "dense {id}");
        }
    }

    #[test]
    fn adaptive_sparsity_levels() {
        let s = ConvShape::square(1, 8, 6, 16, 3, 1, 1);
        let (x, w) = rand_case(13, s);
        let pool = ThreadPool::new(1);
        for sp in [0.25, 0.5, 0.75] {
            let op = Conv2dSparseCnhw::new_adaptive(s, &w, 8, 8, sp);
            assert!((op.sparsity() - sp).abs() < 0.03, "target {sp} got {}", op.sparsity());
            let y = op.run(&x, &pool);
            assert_eq!(y.shape, vec![16, 1, 6, 6]);
            assert!(y.data.iter().any(|&v| v != 0.0));
        }
    }
}
