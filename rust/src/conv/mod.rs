//! Convolution operators: the three execution paths the paper compares.

pub mod shape;
pub mod op;

pub use op::{
    compose_caps, Conv2dDenseCnhw, Conv2dDenseNchw, Conv2dDenseNhwc, Conv2dSparseCnhw, ConvPath,
};
pub use shape::ConvShape;
