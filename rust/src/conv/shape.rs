//! Convolution geometry shared by im2col, GEMM and the model zoo.

/// Shape of one 2-D convolution layer instance (single dtype: f32).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvShape {
    /// Batch size.
    pub n: usize,
    /// Input channels.
    pub c_in: usize,
    /// Input spatial dims.
    pub h_in: usize,
    pub w_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Kernel spatial dims.
    pub kh: usize,
    pub kw: usize,
    /// Stride (same both dims, as in all the paper's networks).
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
}

impl ConvShape {
    /// Output height.
    pub fn h_out(&self) -> usize {
        (self.h_in + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output width.
    pub fn w_out(&self) -> usize {
        (self.w_in + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// GEMM reduction dimension K = K_h·K_w·C_in.
    pub fn k(&self) -> usize {
        self.kh * self.kw * self.c_in
    }

    /// GEMM output columns = N·H_out·W_out (batch-spanning, CNHW §5).
    pub fn gemm_cols(&self) -> usize {
        self.n * self.h_out() * self.w_out()
    }

    /// Dense MACs of this layer.
    pub fn macs(&self) -> usize {
        self.c_out * self.k() * self.gemm_cols()
    }

    /// Dense FLOPs (2·MACs).
    pub fn flops(&self) -> usize {
        2 * self.macs()
    }

    /// Weight element count (dense OIHW).
    pub fn weight_len(&self) -> usize {
        self.c_out * self.c_in * self.kh * self.kw
    }

    /// Pointwise (1×1) convolution?
    pub fn is_pointwise(&self) -> bool {
        self.kh == 1 && self.kw == 1
    }

    /// Convenience constructor with square kernel / input.
    pub fn square(
        n: usize,
        c_in: usize,
        hw: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Self {
            n,
            c_in,
            h_in: hw,
            w_in: hw,
            c_out,
            kh: k,
            kw: k,
            stride,
            pad,
        }
    }
}

impl std::fmt::Display for ConvShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}x{}x{}x{} -> {} ({}x{} s{} p{})",
            self.n, self.c_in, self.h_in, self.w_in, self.c_out, self.kh, self.kw, self.stride,
            self.pad
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_stem_shape() {
        // ResNet stem: 224x224x3 -> 7x7/2 pad 3 -> 112x112x64.
        let s = ConvShape::square(1, 3, 224, 64, 7, 2, 3);
        assert_eq!(s.h_out(), 112);
        assert_eq!(s.w_out(), 112);
        assert_eq!(s.k(), 7 * 7 * 3);
        assert_eq!(s.gemm_cols(), 112 * 112);
    }

    #[test]
    fn same_padding_3x3() {
        let s = ConvShape::square(2, 64, 56, 64, 3, 1, 1);
        assert_eq!((s.h_out(), s.w_out()), (56, 56));
        assert_eq!(s.gemm_cols(), 2 * 56 * 56);
    }

    #[test]
    fn pointwise() {
        let s = ConvShape::square(1, 256, 14, 1024, 1, 1, 0);
        assert!(s.is_pointwise());
        assert_eq!(s.k(), 256);
        assert_eq!(s.macs(), 1024 * 256 * 14 * 14);
    }

    #[test]
    fn strided_no_pad() {
        let s = ConvShape::square(1, 8, 10, 16, 3, 2, 0);
        assert_eq!((s.h_out(), s.w_out()), (4, 4));
    }
}
