//! Quantized activation panel: the i8 twin of [`super::PackedMatrix`].
//!
//! Activations are quantized **per panel** (one scale for the whole
//! packed data matrix of one conv invocation): `sa = max|a| / 127`,
//! `q = round(a / sa)` clamped to `[-127, 127]`. The layout is exactly
//! the f32 strip layout — `[strips, k, v]` row-major, tail strip
//! zero-padded — so the i8 micro-kernels reuse the same strip walk and
//! the quantization pass is a single linear sweep over the already
//! packed buffer (no second im2col).
//!
//! Clamping to ±127 on *both* operands is load-bearing: it keeps every
//! AVX2 `_mm256_madd_epi16` pair-sum within i16·i16 exact range (see
//! [`crate::pruning::quant`]).

use super::pack::{PackedMatrix, MAX_STRIP_WIDTH};

/// Packed data matrix quantized to i8 with one panel-wide scale.
/// `data` layout matches [`PackedMatrix`]: `[strips, k, v]` row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantPanel {
    /// Strip width in lanes.
    pub v: usize,
    /// Reduction rows (K).
    pub k: usize,
    /// Logical (unpadded) column count.
    pub cols: usize,
    /// Number of strips = ceil(cols / v).
    pub strips: usize,
    pub data: Vec<i8>,
    /// Panel-wide dequantization scale (`0.0` for an all-zero panel).
    pub scale: f32,
}

impl QuantPanel {
    /// Zero-initialised panel.
    pub fn zeros(k: usize, cols: usize, v: usize) -> Self {
        assert!(
            (1..=MAX_STRIP_WIDTH).contains(&v),
            "strip width {v} outside 1..={MAX_STRIP_WIDTH} (accumulator capacity)"
        );
        let strips = cols.div_ceil(v).max(1);
        Self {
            v,
            k,
            cols,
            strips,
            data: vec![0; strips * k * v],
            scale: 0.0,
        }
    }

    /// Re-shape for reuse, zero-filling in place; keeps the allocation
    /// when capacity suffices (same contract as `PackedMatrix::reset`).
    pub fn reset(&mut self, k: usize, cols: usize, v: usize) {
        assert!(
            (1..=MAX_STRIP_WIDTH).contains(&v),
            "strip width {v} outside 1..={MAX_STRIP_WIDTH} (accumulator capacity)"
        );
        let strips = cols.div_ceil(v).max(1);
        self.v = v;
        self.k = k;
        self.cols = cols;
        self.strips = strips;
        self.scale = 0.0;
        let len = strips * k * v;
        self.data.clear();
        self.data.resize(len, 0);
    }

    /// Element at (strip, row, lane).
    #[inline]
    pub fn at(&self, strip: usize, row: usize, lane: usize) -> i8 {
        self.data[(strip * self.k + row) * self.v + lane]
    }

    /// Contiguous `[k, v]` slice of one strip.
    #[inline]
    pub fn strip(&self, strip: usize) -> &[i8] {
        &self.data[strip * self.k * self.v..(strip + 1) * self.k * self.v]
    }

    /// Valid (unpadded) lane count of a strip.
    #[inline]
    pub fn strip_valid(&self, strip: usize) -> usize {
        if (strip + 1) * self.v <= self.cols {
            self.v
        } else {
            self.cols - strip * self.v
        }
    }
}

/// Quantize a packed f32 panel into caller-provided i8 storage. The
/// panel is `reset` in place (keeping its allocation when capacity
/// suffices), so a warmed buffer makes repeated quantization
/// allocation-free — this is the per-inference activation-quantization
/// pass of the i8 path, and it must not touch the allocator.
// nmprune: zero-alloc
pub fn quantize_panel_into(p: &PackedMatrix, q: &mut QuantPanel) {
    q.reset(p.k, p.cols, p.v);
    let mut maxabs = 0.0f32;
    for &x in &p.data {
        maxabs = maxabs.max(x.abs());
    }
    if maxabs == 0.0 {
        // All-zero panel: scale 0, data already zero from reset.
        return;
    }
    q.scale = maxabs / 127.0;
    let inv = 127.0 / maxabs;
    for (dst, &x) in q.data.iter_mut().zip(&p.data) {
        *dst = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::pack_data_matrix;
    use crate::util::XorShiftRng;

    #[test]
    fn quantize_roundtrip_error_within_half_step() {
        let mut r = XorShiftRng::new(0x2B01);
        let (k, cols, v) = (6, 21, 8);
        let a = r.normal_vec(k * cols, 1.0);
        let p = pack_data_matrix(&a, k, cols, v);
        let mut q = QuantPanel::zeros(1, 1, 1);
        quantize_panel_into(&p, &mut q);
        assert_eq!((q.strips, q.k, q.v, q.cols), (p.strips, p.k, p.v, p.cols));
        let half_step = q.scale * 0.5 + 1e-6;
        for (i, (&qi, &xi)) in q.data.iter().zip(&p.data).enumerate() {
            let d = (qi as f32 * q.scale - xi).abs();
            assert!(d <= half_step, "elem {i}: err {d} > {half_step}");
            assert!(qi >= -127, "elem {i} hit -128");
        }
    }

    #[test]
    fn extreme_values_saturate_at_127_not_128() {
        // A panel whose max element is exactly representable: ±max maps
        // to ±127, everything else scales proportionally.
        let a = vec![8.0f32, -8.0, 4.0, 0.0];
        let p = pack_data_matrix(&a, 2, 2, 2);
        let mut q = QuantPanel::zeros(2, 2, 2);
        quantize_panel_into(&p, &mut q);
        assert_eq!(q.at(0, 0, 0), 127);
        assert_eq!(q.at(0, 0, 1), -127);
        assert_eq!(q.at(0, 1, 0), 64); // round(4/8 * 127) = 64
        assert_eq!(q.at(0, 1, 1), 0);
    }

    #[test]
    fn all_zero_panel_gets_zero_scale() {
        let p = pack_data_matrix(&vec![0.0f32; 3 * 5], 3, 5, 4);
        let mut q = QuantPanel::zeros(1, 1, 1);
        quantize_panel_into(&p, &mut q);
        assert_eq!(q.scale, 0.0);
        assert!(q.data.iter().all(|&x| x == 0));
    }

    #[test]
    fn reset_within_capacity_does_not_reallocate() {
        let mut q = QuantPanel::zeros(8, 64, 16);
        let cap = q.data.capacity();
        let mut r = XorShiftRng::new(0x2B02);
        for (k, cols, v) in [(3, 10, 4), (8, 64, 16), (5, 32, 32)] {
            let a = r.normal_vec(k * cols, 1.0);
            let p = pack_data_matrix(&a, k, cols, v);
            quantize_panel_into(&p, &mut q);
            assert_eq!(q.strips, p.strips);
        }
        assert_eq!(q.data.capacity(), cap, "in-capacity reuse must not reallocate");
    }

    #[test]
    #[should_panic(expected = "accumulator capacity")]
    fn oversized_strip_width_rejected() {
        QuantPanel::zeros(2, 128, 65);
    }
}
