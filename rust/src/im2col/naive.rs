//! Reference (unfused) im2col over CNHW inputs.

use crate::conv::ConvShape;
use crate::tensor::Tensor;

/// im2col over a CNHW input `[C_in, N, H_in, W_in]` producing the dense
/// data matrix `A[K, cols]`, K = K_h·K_w·C_in rows ordered (k_h, k_w)
/// outer / channel inner; cols = N·H_out·W_out ordered (n, h_out, w_out).
/// Out-of-bounds (padding) reads contribute 0.
pub fn im2col_cnhw(x: &Tensor, s: &ConvShape) -> Vec<f32> {
    assert_eq!(
        x.shape,
        vec![s.c_in, s.n, s.h_in, s.w_in],
        "input must be CNHW for {s}"
    );
    let (h_out, w_out) = (s.h_out(), s.w_out());
    let cols = s.n * h_out * w_out;
    let k = s.k();
    let mut a = vec![0.0f32; k * cols];
    for kh in 0..s.kh {
        for kw in 0..s.kw {
            for c in 0..s.c_in {
                let row = (kh * s.kw + kw) * s.c_in + c;
                for n in 0..s.n {
                    for ho in 0..h_out {
                        let hi = (ho * s.stride + kh) as isize - s.pad as isize;
                        if hi < 0 || hi >= s.h_in as isize {
                            continue; // whole row of w_out stays zero
                        }
                        let hi = hi as usize;
                        let in_base = ((c * s.n + n) * s.h_in + hi) * s.w_in;
                        let out_base = row * cols + (n * h_out + ho) * w_out;
                        for wo in 0..w_out {
                            let wi = (wo * s.stride + kw) as isize - s.pad as isize;
                            if wi < 0 || wi >= s.w_in as isize {
                                continue;
                            }
                            a[out_base + wo] = x.data[in_base + wi as usize];
                        }
                    }
                }
            }
        }
    }
    a
}

/// Fully naive direct convolution over CNHW input and OIHW weights —
/// the ground-truth oracle every GEMM path is checked against.
/// Returns output in CNHW `[C_out, N, H_out, W_out]`.
pub fn conv2d_direct_cnhw(x: &Tensor, w_oihw: &Tensor, s: &ConvShape) -> Tensor {
    assert_eq!(x.shape, vec![s.c_in, s.n, s.h_in, s.w_in]);
    assert_eq!(w_oihw.shape, vec![s.c_out, s.c_in, s.kh, s.kw]);
    let (h_out, w_out) = (s.h_out(), s.w_out());
    let mut out = Tensor::zeros(&[s.c_out, s.n, h_out, w_out]);
    for o in 0..s.c_out {
        for n in 0..s.n {
            for ho in 0..h_out {
                for wo in 0..w_out {
                    let mut acc = 0.0f32;
                    for c in 0..s.c_in {
                        for kh in 0..s.kh {
                            let hi = (ho * s.stride + kh) as isize - s.pad as isize;
                            if hi < 0 || hi >= s.h_in as isize {
                                continue;
                            }
                            for kw in 0..s.kw {
                                let wi = (wo * s.stride + kw) as isize - s.pad as isize;
                                if wi < 0 || wi >= s.w_in as isize {
                                    continue;
                                }
                                acc += x.at(&[c, n, hi as usize, wi as usize])
                                    * w_oihw.at(&[o, c, kh, kw]);
                            }
                        }
                    }
                    *out.at_mut(&[o, n, ho, wo]) = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::layout::oihw_to_filter_matrix;
    use crate::util::{allclose, XorShiftRng};

    /// A[K, cols] × filter must reproduce direct convolution:
    /// out[o, col] = Σ_k W_f[o,k] · A[k,col].
    #[test]
    fn im2col_gemm_matches_direct_conv() {
        let mut r = XorShiftRng::new(31);
        for s in [
            ConvShape::square(1, 2, 5, 3, 3, 1, 1),
            ConvShape::square(2, 3, 7, 4, 3, 2, 1),
            ConvShape::square(1, 4, 6, 2, 1, 1, 0),
            ConvShape::square(1, 2, 9, 3, 7, 2, 3),
        ] {
            let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -1.0, 1.0);
            let a = im2col_cnhw(&x, &s);
            let f = oihw_to_filter_matrix(&w);
            let cols = s.gemm_cols();
            let k = s.k();
            let mut got = vec![0.0f32; s.c_out * cols];
            for o in 0..s.c_out {
                for kk in 0..k {
                    let wv = f.data[o * k + kk];
                    for c in 0..cols {
                        got[o * cols + c] += wv * a[kk * cols + c];
                    }
                }
            }
            let want = conv2d_direct_cnhw(&x, &w, &s);
            assert!(
                allclose(&got, &want.data, 1e-4, 1e-5),
                "mismatch for {s}: max diff {}",
                crate::util::max_abs_diff(&got, &want.data)
            );
        }
    }

    #[test]
    fn padding_region_is_zero() {
        // All-ones input; padded corners of the data matrix must be 0.
        let s = ConvShape::square(1, 1, 3, 1, 3, 1, 1);
        let x = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let a = im2col_cnhw(&x, &s);
        // Row (kh=0,kw=0,c=0) column (ho=0,wo=0) reads x[-1,-1] -> 0.
        assert_eq!(a[0], 0.0);
        // Row (kh=1,kw=1) is the centre tap: all 9 entries are 1.
        let centre = (1 * 3 + 1) * 1;
        let cols = s.gemm_cols();
        assert!(a[centre * cols..(centre + 1) * cols].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn stride_two_samples_correct_pixels() {
        // 1x1 kernel stride 2 picks even-indexed pixels.
        let s = ConvShape::square(1, 1, 4, 1, 1, 2, 0);
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let a = im2col_cnhw(&x, &s);
        assert_eq!(a, vec![0.0, 2.0, 8.0, 10.0]);
    }
}
