//! Fused im2col + data packing (Algorithm 2, Fig. 4).
//!
//! Moves data straight from the CNHW feature map into vector-aligned
//! strips in one pass: no intermediate `A` matrix is ever materialised.
//! Because W is innermost in CNHW, each (strip, kernel-tap, channel)
//! transfer is a contiguous run along the input row for stride 1 (a
//! single vector load/store on RVV), and a strided gather otherwise.
//! Padding regions are *skipped*, not copied: the strip buffer starts
//! zeroed and only valid elements are written — the paper's trick for the
//! stride-2 stem layer (§4.3) where avoiding padded copies makes fusion
//! faster than even a standalone im2col.

use super::pack::PackedMatrix;
use crate::conv::ConvShape;
use crate::tensor::Tensor;

/// Fused im2col+pack over a CNHW input, producing strips of width `v`.
/// Equivalent to `pack_data_matrix(im2col_cnhw(x, s), s.k(), cols, v)`.
pub fn fused_im2col_pack_cnhw(x: &Tensor, s: &ConvShape, v: usize) -> PackedMatrix {
    let mut p = PackedMatrix::zeros(s.k(), s.gemm_cols(), v);
    fill_fused(x, s, v, &mut p);
    p
}

/// In-place variant: reuses `p`'s buffer (§Perf step 3 — avoids the
/// multi-MB allocation + page-fault churn per conv invocation).
// nmprune: zero-alloc
pub fn fused_im2col_pack_cnhw_into(x: &Tensor, s: &ConvShape, v: usize, p: &mut PackedMatrix) {
    p.reset(s.k(), s.gemm_cols(), v);
    fill_fused(x, s, v, p);
}

fn fill_fused(x: &Tensor, s: &ConvShape, v: usize, p: &mut PackedMatrix) {
    // Array compare, not vec![] — this assert runs on the zero-alloc
    // hot path (once per conv invocation).
    assert_eq!(x.shape, [s.c_in, s.n, s.h_in, s.w_in], "input must be CNHW for {s}");
    let (h_out, w_out) = (s.h_out(), s.w_out());
    let k = s.k();

    // Walk output columns strip by strip; inside a strip, split the lane
    // range into segments that stay within one (n, h_out) output row so
    // every segment maps to one contiguous (or constant-stride) input run.
    for strip in 0..p.strips {
        let strip_base = strip * v;
        let valid = p.strip_valid(strip);
        let mut lane = 0usize;
        while lane < valid {
            let col = strip_base + lane;
            let n = col / (h_out * w_out);
            let rem = col % (h_out * w_out);
            let ho = rem / w_out;
            let wo0 = rem % w_out;
            // Segment length: to end of this output row or end of strip.
            let seg = (w_out - wo0).min(valid - lane);
            for kh in 0..s.kh {
                let hi = (ho * s.stride + kh) as isize - s.pad as isize;
                if hi < 0 || hi >= s.h_in as isize {
                    continue; // zero padding row: leave zeros in place
                }
                let hi = hi as usize;
                for kw in 0..s.kw {
                    // Input column for lane j of the segment:
                    //   wi(j) = (wo0 + j)·stride + kw − pad
                    let wi0 = (wo0 * s.stride + kw) as isize - s.pad as isize;
                    // Valid j range: 0 <= wi(j) < w_in.
                    let j_lo = if wi0 >= 0 {
                        0
                    } else {
                        ((-wi0) as usize).div_ceil(s.stride)
                    };
                    let j_hi_excl = if wi0 >= s.w_in as isize {
                        0
                    } else {
                        // wi(j) <= w_in-1  →  j <= (w_in-1-wi0)/stride
                        (((s.w_in as isize - 1 - wi0) / s.stride as isize) + 1).max(0) as usize
                    };
                    let j_hi_excl = j_hi_excl.min(seg);
                    if j_lo >= j_hi_excl {
                        continue;
                    }
                    for c in 0..s.c_in {
                        let row = (kh * s.kw + kw) * s.c_in + c;
                        let in_base = ((c * s.n + n) * s.h_in + hi) * s.w_in;
                        let dst_base = (strip * k + row) * v + lane;
                        if s.stride == 1 {
                            // Contiguous run: one vector move (vle/vse).
                            let src0 = (in_base as isize + wi0 + j_lo as isize) as usize;
                            let len = j_hi_excl - j_lo;
                            let (dst0, src_end) = (dst_base + j_lo, src0 + len);
                            p.data[dst0..dst0 + len]
                                .copy_from_slice(&x.data[src0..src_end]);
                        } else {
                            // Strided gather (vlse on RVV).
                            for j in j_lo..j_hi_excl {
                                let wi = (wi0 + (j * s.stride) as isize) as usize;
                                p.data[dst_base + j] = x.data[in_base + wi];
                            }
                        }
                    }
                }
            }
            lane += seg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::{naive::im2col_cnhw, pack::pack_data_matrix};
    use crate::util::XorShiftRng;

    fn check(s: ConvShape, v: usize, seed: u64) {
        let mut r = XorShiftRng::new(seed);
        let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
        let want = pack_data_matrix(&im2col_cnhw(&x, &s), s.k(), s.gemm_cols(), v);
        let got = fused_im2col_pack_cnhw(&x, &s, v);
        assert_eq!(got.data, want.data, "{s} v={v}");
    }

    #[test]
    fn matches_separate_stride1_pad1() {
        check(ConvShape::square(1, 3, 8, 4, 3, 1, 1), 8, 1);
        check(ConvShape::square(2, 2, 7, 4, 3, 1, 1), 16, 2);
    }

    #[test]
    fn matches_separate_stem_stride2_pad3() {
        // ResNet stem geometry (downscaled): 7x7 stride 2 pad 3.
        check(ConvShape::square(1, 3, 20, 4, 7, 2, 3), 32, 3);
    }

    #[test]
    fn matches_separate_pointwise() {
        check(ConvShape::square(2, 6, 9, 4, 1, 1, 0), 8, 4);
    }

    #[test]
    fn matches_separate_width_not_multiple_of_v() {
        // w_out=56-like tail handling: strip crosses output-row borders.
        check(ConvShape::square(1, 2, 13, 4, 3, 1, 1), 32, 5);
        check(ConvShape::square(3, 1, 5, 2, 3, 1, 1), 64, 6);
    }

    #[test]
    fn matches_separate_stride2_no_pad() {
        check(ConvShape::square(1, 2, 11, 4, 3, 2, 0), 8, 7);
        check(ConvShape::square(1, 2, 11, 4, 3, 2, 1), 8, 8);
    }

    #[test]
    fn v_larger_than_cols() {
        check(ConvShape::square(1, 2, 4, 3, 3, 1, 1), 64, 9);
    }
}
