//! Data packing: reorganise the data matrix into vector-aligned strips
//! (Fig. 2). Separate from im2col here; [`super::fused`] does both in one
//! pass (Algorithm 2).

/// Maximum supported strip width in f32 lanes. The GEMM micro-kernels
/// hold one strip row in fixed `[f32; MAX_STRIP_WIDTH]` accumulators
/// (the VLMAX of LMUL=8 on the 256-bit target), so wider strips would
/// silently truncate in release builds — every packing entry point
/// rejects them up front.
pub const MAX_STRIP_WIDTH: usize = 64;

/// Data matrix packed into strips of `v` columns: `data` has layout
/// `[strips, k, v]` row-major; the tail strip is zero-padded.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedMatrix {
    /// Strip width (vector length in elements = VLEN·LMUL / 32).
    pub v: usize,
    /// Reduction rows (K).
    pub k: usize,
    /// Logical (unpadded) column count.
    pub cols: usize,
    /// Number of strips = ceil(cols / v).
    pub strips: usize,
    pub data: Vec<f32>,
}

impl PackedMatrix {
    /// Zero-initialised packed matrix.
    pub fn zeros(k: usize, cols: usize, v: usize) -> Self {
        assert!(
            (1..=MAX_STRIP_WIDTH).contains(&v),
            "strip width {v} outside 1..={MAX_STRIP_WIDTH} (accumulator capacity)"
        );
        let strips = cols.div_ceil(v).max(1);
        Self {
            v,
            k,
            cols,
            strips,
            data: vec![0.0; strips * k * v],
        }
    }

    /// Re-shape an existing packed matrix for reuse, zero-filling its
    /// buffer in place. Keeps the allocation (and its resident pages)
    /// across conv invocations — §Perf step 3.
    pub fn reset(&mut self, k: usize, cols: usize, v: usize) {
        assert!(
            (1..=MAX_STRIP_WIDTH).contains(&v),
            "strip width {v} outside 1..={MAX_STRIP_WIDTH} (accumulator capacity)"
        );
        let strips = cols.div_ceil(v).max(1);
        self.v = v;
        self.k = k;
        self.cols = cols;
        self.strips = strips;
        let len = strips * k * v;
        self.data.clear();
        self.data.resize(len, 0.0);
    }

    /// Element at (strip, row, lane).
    #[inline]
    pub fn at(&self, strip: usize, row: usize, lane: usize) -> f32 {
        self.data[(strip * self.k + row) * self.v + lane]
    }

    /// Contiguous `[k, v]` slice of one strip.
    #[inline]
    pub fn strip(&self, strip: usize) -> &[f32] {
        &self.data[strip * self.k * self.v..(strip + 1) * self.k * self.v]
    }

    /// Mutable strip slice.
    #[inline]
    pub fn strip_mut(&mut self, strip: usize) -> &mut [f32] {
        &mut self.data[strip * self.k * self.v..(strip + 1) * self.k * self.v]
    }

    /// Valid (unpadded) lane count of a strip.
    #[inline]
    pub fn strip_valid(&self, strip: usize) -> usize {
        if (strip + 1) * self.v <= self.cols {
            self.v
        } else {
            self.cols - strip * self.v
        }
    }

    /// Unpack back to the dense `[k, cols]` matrix (testing only).
    pub fn unpack(&self) -> Vec<f32> {
        let mut a = vec![0.0f32; self.k * self.cols];
        for s in 0..self.strips {
            let valid = self.strip_valid(s);
            for r in 0..self.k {
                for j in 0..valid {
                    a[r * self.cols + s * self.v + j] = self.at(s, r, j);
                }
            }
        }
        a
    }
}

/// Pack a dense data matrix `a[k, cols]` into strips of width `v`.
/// This is the *separate* packing pass the paper's baseline performs
/// after a standalone im2col.
pub fn pack_data_matrix(a: &[f32], k: usize, cols: usize, v: usize) -> PackedMatrix {
    let mut p = PackedMatrix::zeros(k, cols, v);
    pack_data_matrix_into(a, k, cols, v, &mut p);
    p
}

/// [`pack_data_matrix`] writing into caller-provided storage: the packed
/// matrix is `reset` in place (keeping its allocation when capacity
/// suffices), so a warmed buffer makes repeated packing allocation-free.
// nmprune: zero-alloc
pub fn pack_data_matrix_into(a: &[f32], k: usize, cols: usize, v: usize, p: &mut PackedMatrix) {
    assert_eq!(a.len(), k * cols, "data matrix shape");
    p.reset(k, cols, v);
    for s in 0..p.strips {
        let valid = p.strip_valid(s);
        for r in 0..k {
            let src = &a[r * cols + s * v..r * cols + s * v + valid];
            let dst_base = (s * k + r) * v;
            p.data[dst_base..dst_base + valid].copy_from_slice(src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, XorShiftRng};

    #[test]
    fn pack_unpack_roundtrip() {
        let mut r = XorShiftRng::new(41);
        for (k, cols, v) in [(3, 10, 4), (1, 1, 8), (5, 32, 32), (4, 33, 16)] {
            let a = r.normal_vec(k * cols, 1.0);
            let p = pack_data_matrix(&a, k, cols, v);
            assert_eq!(p.unpack(), a, "k={k} cols={cols} v={v}");
        }
    }

    #[test]
    fn tail_strip_is_zero_padded() {
        let a = vec![1.0f32; 2 * 5]; // k=2, cols=5
        let p = pack_data_matrix(&a, 2, 5, 4);
        assert_eq!(p.strips, 2);
        assert_eq!(p.strip_valid(0), 4);
        assert_eq!(p.strip_valid(1), 1);
        // lanes 1..4 of strip 1 are padding zeros
        for r in 0..2 {
            assert_eq!(p.at(1, r, 0), 1.0);
            for j in 1..4 {
                assert_eq!(p.at(1, r, j), 0.0);
            }
        }
    }

    #[test]
    fn strip_rows_are_contiguous() {
        // The GEMM kernel indexes strip memory as [k, v] row-major; verify.
        let a: Vec<f32> = (0..3 * 8).map(|i| i as f32).collect(); // k=3, cols=8
        let p = pack_data_matrix(&a, 3, 8, 4);
        assert_eq!(p.strip(0), &[0., 1., 2., 3., 8., 9., 10., 11., 16., 17., 18., 19.]);
        assert_eq!(p.strip(1), &[4., 5., 6., 7., 12., 13., 14., 15., 20., 21., 22., 23.]);
    }

    #[test]
    #[should_panic(expected = "accumulator capacity")]
    fn strip_width_beyond_accumulators_rejected() {
        // v = 128 > MAX_STRIP_WIDTH: in the seed this was only a
        // debug_assert at kernel level and release builds overflowed the
        // fixed accumulator block; now packing rejects it outright.
        let a = vec![0.0f32; 2 * 128];
        pack_data_matrix(&a, 2, 128, 128);
    }

    #[test]
    #[should_panic(expected = "accumulator capacity")]
    fn reset_rejects_oversized_strip_width() {
        let mut p = PackedMatrix::zeros(1, 1, 1);
        p.reset(2, 256, 65);
    }

    #[test]
    fn pack_into_reuses_buffer_and_matches_fresh_pack() {
        let mut r = XorShiftRng::new(43);
        // Warm with the largest case so later resets stay in capacity.
        let mut p = PackedMatrix::zeros(8, 64, 16);
        let cap = p.data.capacity();
        for (k, cols, v) in [(3, 10, 4), (8, 64, 16), (5, 32, 32), (4, 33, 16)] {
            let a = r.normal_vec(k * cols, 1.0);
            pack_data_matrix_into(&a, k, cols, v, &mut p);
            assert_eq!(p, pack_data_matrix(&a, k, cols, v), "k={k} cols={cols} v={v}");
        }
        assert_eq!(p.data.capacity(), cap, "in-capacity reuse must not reallocate");
    }

    #[test]
    fn prop_roundtrip_arbitrary_shapes() {
        prop::check_seeded(
            0x9ACC,
            |r, size| {
                let k = 1 + size % 12;
                let cols = 1 + r.below(100);
                let v = [1, 2, 4, 8, 16, 32, 64][r.below(7)];
                (r.normal_vec(k * cols, 1.0), k, cols, v)
            },
            |(a, k, cols, v)| {
                let p = pack_data_matrix(a, *k, *cols, *v);
                p.unpack() == *a && p.strips == cols.div_ceil(*v)
            },
        );
    }
}
