//! NCHW-layout im2col — the §5 alternative (Elsen et al. [13]).
//!
//! NCHW also has W innermost, so vectorised im2col works per image; the
//! difference from CNHW is *batch-level packing*: each image yields its
//! own `[K, H_out·W_out]` data matrix, so strips cannot span batch
//! boundaries. With small per-image column counts this under-fills
//! vector lanes (§5 point 2) and runs `N` separate GEMMs. The paper
//! keeps CNHW; this module exists so the discussion's claim can be
//! *measured* rather than asserted (ablation C / fig12).

use super::fused::fused_im2col_pack_cnhw_into;
use super::pack::PackedMatrix;
use crate::conv::ConvShape;
use crate::tensor::Tensor;

/// Per-image fused im2col+pack over an NCHW input `[N, C, H, W]`:
/// returns one packed matrix per image (strips never span batches).
pub fn fused_im2col_pack_nchw(x: &Tensor, s: &ConvShape, v: usize) -> Vec<PackedMatrix> {
    assert_eq!(
        x.shape,
        vec![s.n, s.c_in, s.h_in, s.w_in],
        "input must be NCHW for {s}"
    );
    let image_len = s.c_in * s.h_in * s.w_in;
    let mut single = *s;
    single.n = 1;
    (0..s.n)
        .map(|n| {
            // One image in NCHW is exactly CNHW with N=1.
            let img = Tensor::from_vec(
                &[s.c_in, 1, s.h_in, s.w_in],
                x.data[n * image_len..(n + 1) * image_len].to_vec(),
            );
            let mut p = PackedMatrix::zeros(1, 1, 1);
            fused_im2col_pack_cnhw_into(&img, &single, v, &mut p);
            p
        })
        .collect()
}

/// Total strips across the per-image matrices — the §5 utilisation
/// metric (CNHW needs `ceil(N·H_out·W_out / v)`, NCHW needs
/// `N · ceil(H_out·W_out / v)`).
pub fn nchw_total_strips(s: &ConvShape, v: usize) -> usize {
    s.n * (s.h_out() * s.w_out()).div_ceil(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::naive::im2col_cnhw;
    use crate::im2col::pack::pack_data_matrix;
    use crate::tensor::layout::{cnhw_to_nhwc, nhwc_to_nchw};
    use crate::util::XorShiftRng;

    #[test]
    fn per_image_matrices_match_single_image_cnhw() {
        let s = ConvShape::square(3, 4, 8, 5, 3, 1, 1);
        let mut r = XorShiftRng::new(31);
        let x_cnhw = Tensor::random(&[4, 3, 8, 8], &mut r, -1.0, 1.0);
        let x_nchw = nhwc_to_nchw(&cnhw_to_nhwc(&x_cnhw));
        let per_image = fused_im2col_pack_nchw(&x_nchw, &s, 8);
        assert_eq!(per_image.len(), 3);
        // Each image's matrix equals a batch-1 CNHW im2col of that image.
        let mut single = s;
        single.n = 1;
        for (n, p) in per_image.iter().enumerate() {
            let mut img = Tensor::zeros(&[4, 1, 8, 8]);
            for c in 0..4 {
                for i in 0..64 {
                    img.data[c * 64 + i] = x_cnhw.data[(c * 3 + n) * 64 + i];
                }
            }
            let want = pack_data_matrix(&im2col_cnhw(&img, &single), single.k(), 64, 8);
            assert_eq!(p.data, want.data, "image {n}");
        }
    }

    #[test]
    fn strip_count_never_beats_cnhw() {
        // NCHW can only waste lanes relative to batch-spanning CNHW.
        for (n, hw, v) in [(1, 7, 32), (2, 7, 32), (4, 7, 32), (4, 56, 16), (3, 5, 64)] {
            let s = ConvShape::square(n, 8, hw, 8, 3, 1, 1);
            let cnhw = s.gemm_cols().div_ceil(v);
            assert!(
                nchw_total_strips(&s, v) >= cnhw,
                "n={n} hw={hw} v={v}"
            );
        }
        // And is strictly worse when per-image cols don't fill a strip.
        let s = ConvShape::square(4, 8, 7, 8, 3, 1, 1); // 49 cols/image
        assert!(nchw_total_strips(&s, 32) > s.gemm_cols().div_ceil(32));
    }

    #[test]
    fn batch1_equals_cnhw_exactly() {
        let s = ConvShape::square(1, 2, 6, 3, 3, 1, 1);
        let mut r = XorShiftRng::new(32);
        let x_cnhw = Tensor::random(&[2, 1, 6, 6], &mut r, -1.0, 1.0);
        // CNHW [C,1,H,W] and NCHW [1,C,H,W] hold identical data at N=1.
        let x_nchw = Tensor::from_vec(&[1, 2, 6, 6], x_cnhw.data.clone());
        let per_image = fused_im2col_pack_nchw(&x_nchw, &s, 8);
        let whole = crate::im2col::fused_im2col_pack_cnhw(&x_cnhw, &s, 8);
        assert_eq!(per_image[0].data, whole.data);
    }
}
