//! im2col, data packing, their fusion (Algorithm 2), and the
//! XNNPACK-style indirection buffer used by the dense NHWC baseline.
//!
//! Data-matrix convention (Fig. 4): for a conv of shape `s`,
//! `A[K, cols]` with `K = K_h·K_w·C_in` rows ordered kernel-position-major
//! / input-channel-minor (matching [`crate::tensor::layout::oihw_to_filter_matrix`])
//! and `cols = N·H_out·W_out` columns ordered `(n, h_out, w_out)` with
//! `w_out` innermost — i.e. batch-spanning, which is the CNHW layout's
//! packing advantage (§5).
//!
//! Packing reorganises `A` into vector-aligned *strips*: strip `s` holds
//! columns `[s·V, (s+1)·V)` for all K rows, row-major `[K, V]`, so the
//! GEMM micro-kernel streams rows of one strip contiguously (Fig. 2).

pub mod naive;
pub mod pack;
pub mod fused;
pub mod indirection;
pub mod nchw;
pub mod quant;

pub use fused::{fused_im2col_pack_cnhw, fused_im2col_pack_cnhw_into};
pub use nchw::{fused_im2col_pack_nchw, nchw_total_strips};
pub use indirection::{
    conv2d_indirect_nhwc, conv2d_indirect_nhwc_into, conv2d_indirect_nhwc_parallel,
    conv2d_indirect_nhwc_parallel_capped, conv2d_indirect_nhwc_parallel_capped_into,
    IndirectionBuffer,
};
pub use naive::im2col_cnhw;
pub use pack::{pack_data_matrix, pack_data_matrix_into, PackedMatrix, MAX_STRIP_WIDTH};
pub use quant::{quantize_panel_into, QuantPanel};

use crate::conv::ConvShape;

/// Logical column count of the data matrix for shape `s`.
pub fn data_matrix_cols(s: &ConvShape) -> usize {
    s.gemm_cols()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::{allclose, prop, XorShiftRng};

    /// Cross-check: fused output must equal pack(im2col(x)) exactly,
    /// over randomized shapes including stride/pad/tails.
    #[test]
    fn prop_fused_equals_separate() {
        prop::check_seeded(
            0xF00D,
            |r, size| {
                let s = ConvShape {
                    n: 1 + size % 3,
                    c_in: 1 + r.below(5),
                    h_in: 3 + r.below(10),
                    w_in: 3 + r.below(10),
                    c_out: 1,
                    kh: 1 + r.below(3),
                    kw: 1 + r.below(3),
                    stride: 1 + r.below(2),
                    pad: r.below(2),
                };
                if s.h_in + 2 * s.pad < s.kh || s.w_in + 2 * s.pad < s.kw {
                    return (s, Tensor::zeros(&[1, 1, 1, 1]), 8);
                }
                let x = Tensor::random(
                    &[s.c_in, s.n, s.h_in, s.w_in],
                    r,
                    -1.0,
                    1.0,
                );
                let v = [4, 8, 16, 32][r.below(4)];
                (s, x, v)
            },
            |(s, x, v)| {
                if x.len() == 1 {
                    return true; // degenerate skip
                }
                let a = im2col_cnhw(x, s);
                let sep = pack_data_matrix(&a, s.k(), data_matrix_cols(s), *v);
                let fus = fused_im2col_pack_cnhw(x, s, *v);
                sep.data == fus.data && sep.strips == fus.strips
            },
        );
    }

    /// The packed matrix must contain exactly the im2col values at the
    /// strip positions, zero in the tail padding.
    #[test]
    fn packed_values_positionally_correct() {
        let mut r = XorShiftRng::new(21);
        let s = ConvShape::square(2, 3, 6, 4, 3, 1, 1);
        let x = Tensor::random(&[3, 2, 6, 6], &mut r, -1.0, 1.0);
        let a = im2col_cnhw(&x, &s);
        let v = 16;
        let p = pack_data_matrix(&a, s.k(), s.gemm_cols(), v);
        let cols = s.gemm_cols();
        for strip in 0..p.strips {
            for k in 0..s.k() {
                for j in 0..v {
                    let col = strip * v + j;
                    let want = if col < cols { a[k * cols + col] } else { 0.0 };
                    assert_eq!(p.at(strip, k, j), want, "strip {strip} k {k} j {j}");
                }
            }
        }
    }

    /// 1x1 stride-1 no-pad conv: the data matrix is just the reshaped input.
    #[test]
    fn pointwise_im2col_is_identity() {
        let mut r = XorShiftRng::new(22);
        let s = ConvShape::square(2, 5, 4, 7, 1, 1, 0);
        let x = Tensor::random(&[5, 2, 4, 4], &mut r, -1.0, 1.0);
        let a = im2col_cnhw(&x, &s);
        assert!(allclose(&a, &x.data, 0.0, 0.0));
    }
}
