//! XNNPACK-style indirection buffer for the dense NHWC baseline (§2.2).
//!
//! Instead of materialising the patch matrix, Indirect Convolution
//! [Dukhan 2019] stores, for every (output position, kernel tap), an
//! offset into the NHWC feature map pointing at a contiguous C_in-long
//! pixel vector (NHWC keeps channels innermost). The GEMM micro-kernel
//! then reads activations through this buffer. Padding taps point at a
//! shared zero buffer, modelled here as `None`.

use crate::conv::ConvShape;
use crate::tensor::Tensor;

/// Indirection buffer: `offsets[(out_pos, tap)]` = element offset of the
/// `[C_in]` pixel vector in the NHWC input, or `None` for padding.
#[derive(Clone, Debug)]
pub struct IndirectionBuffer {
    /// Output positions = N·H_out·W_out.
    pub out_positions: usize,
    /// Kernel taps = K_h·K_w.
    pub taps: usize,
    pub offsets: Vec<Option<usize>>,
}

impl IndirectionBuffer {
    /// Build for a conv shape over an NHWC input `[N, H_in, W_in, C_in]`.
    pub fn build(s: &ConvShape) -> Self {
        let (h_out, w_out) = (s.h_out(), s.w_out());
        let out_positions = s.n * h_out * w_out;
        let taps = s.kh * s.kw;
        let mut offsets = Vec::with_capacity(out_positions * taps);
        for n in 0..s.n {
            for ho in 0..h_out {
                for wo in 0..w_out {
                    for kh in 0..s.kh {
                        for kw in 0..s.kw {
                            let hi = (ho * s.stride + kh) as isize - s.pad as isize;
                            let wi = (wo * s.stride + kw) as isize - s.pad as isize;
                            if hi < 0
                                || hi >= s.h_in as isize
                                || wi < 0
                                || wi >= s.w_in as isize
                            {
                                offsets.push(None);
                            } else {
                                let off = ((n * s.h_in + hi as usize) * s.w_in
                                    + wi as usize)
                                    * s.c_in;
                                offsets.push(Some(off));
                            }
                        }
                    }
                }
            }
        }
        Self {
            out_positions,
            taps,
            offsets,
        }
    }

    /// Offset for (output position, tap).
    #[inline]
    pub fn at(&self, pos: usize, tap: usize) -> Option<usize> {
        self.offsets[pos * self.taps + tap]
    }

    /// Buffer size in bytes (8-byte pointers) — the memory-overhead
    /// metric the indirect approach trades against the patch matrix.
    pub fn bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
    }
}

/// Dense NHWC convolution through the indirection buffer: the
/// XNNPACK-baseline twin. Weights are the `[C_out, K]` filter matrix with
/// k-major/channel-inner rows (same as the CNHW path). Output NHWC
/// `[N, H_out, W_out, C_out]`.
pub fn conv2d_indirect_nhwc(
    x: &Tensor,
    filter: &[f32],
    s: &ConvShape,
    ib: &IndirectionBuffer,
) -> Tensor {
    let mut out = Tensor::zeros(&[s.n, s.h_out(), s.w_out(), s.c_out]);
    conv2d_indirect_nhwc_into(x, filter, s, ib, &mut out);
    out
}

/// [`conv2d_indirect_nhwc`] into a caller-provided output tensor. The
/// kernel accumulates tap-by-tap, so the (possibly reused) output is
/// zeroed first.
// nmprune: zero-alloc
pub fn conv2d_indirect_nhwc_into(
    x: &Tensor,
    filter: &[f32],
    s: &ConvShape,
    ib: &IndirectionBuffer,
    out: &mut Tensor,
) {
    assert_eq!(x.shape, [s.n, s.h_in, s.w_in, s.c_in]);
    let k = s.k();
    assert_eq!(filter.len(), s.c_out * k);
    let (h_out, w_out) = (s.h_out(), s.w_out());
    assert_eq!(out.shape, [s.n, h_out, w_out, s.c_out], "output tensor shape");
    out.data.fill(0.0);
    for pos in 0..ib.out_positions {
        let out_base = pos * s.c_out;
        for tap in 0..ib.taps {
            let Some(off) = ib.at(pos, tap) else {
                continue;
            };
            let pixel = &x.data[off..off + s.c_in];
            for o in 0..s.c_out {
                let wrow = &filter[o * k + tap * s.c_in..o * k + (tap + 1) * s.c_in];
                let mut acc = 0.0f32;
                for (wv, xv) in wrow.iter().zip(pixel) {
                    acc += wv * xv;
                }
                out.data[out_base + o] += acc;
            }
        }
    }
}

/// Multi-threaded variant parallelising over output positions (each
/// position writes a disjoint `[C_out]` slice). Runs on the persistent
/// worker pool — no threads are spawned per call.
pub fn conv2d_indirect_nhwc_parallel(
    x: &Tensor,
    filter: &[f32],
    s: &ConvShape,
    ib: &IndirectionBuffer,
    pool: &crate::util::threadpool::ThreadPool,
) -> Tensor {
    conv2d_indirect_nhwc_parallel_capped(x, filter, s, ib, pool, None)
}

/// [`conv2d_indirect_nhwc_parallel`] bounded to at most `max_workers`
/// pool participants (per-layer parallelism cap).
pub fn conv2d_indirect_nhwc_parallel_capped(
    x: &Tensor,
    filter: &[f32],
    s: &ConvShape,
    ib: &IndirectionBuffer,
    pool: &crate::util::threadpool::ThreadPool,
    max_workers: Option<usize>,
) -> Tensor {
    let mut out = Tensor::zeros(&[s.n, s.h_out(), s.w_out(), s.c_out]);
    conv2d_indirect_nhwc_parallel_capped_into(x, filter, s, ib, pool, max_workers, &mut out);
    out
}

/// [`conv2d_indirect_nhwc_parallel_capped`] into a caller-provided
/// output tensor (zeroed here — the kernel accumulates).
// nmprune: zero-alloc
pub fn conv2d_indirect_nhwc_parallel_capped_into(
    x: &Tensor,
    filter: &[f32],
    s: &ConvShape,
    ib: &IndirectionBuffer,
    pool: &crate::util::threadpool::ThreadPool,
    max_workers: Option<usize>,
    out: &mut Tensor,
) {
    if pool.size() <= 1 || max_workers == Some(1) {
        conv2d_indirect_nhwc_into(x, filter, s, ib, out);
        return;
    }
    assert_eq!(x.shape, [s.n, s.h_in, s.w_in, s.c_in]);
    let k = s.k();
    assert_eq!(filter.len(), s.c_out * k);
    let (h_out, w_out) = (s.h_out(), s.w_out());
    assert_eq!(out.shape, [s.n, h_out, w_out, s.c_out], "output tensor shape");
    out.data.fill(0.0);
    struct SendPtr(*mut f32);
    // SAFETY: workers write only their own position's disjoint [C_out]
    // range through the pointer, and `out` outlives the parallel_for
    // barrier below.
    unsafe impl Send for SendPtr {}
    // SAFETY: as above — concurrent access is disjoint-range writes
    // bounded by the pool barrier.
    unsafe impl Sync for SendPtr {}
    impl SendPtr {
        fn get(&self) -> *mut f32 {
            self.0
        }
    }
    let optr = SendPtr(out.data.as_mut_ptr());
    pool.parallel_for_capped(ib.out_positions, max_workers, |p0, p1| {
        for pos in p0..p1 {
            let out_base = pos * s.c_out;
            for tap in 0..ib.taps {
                let Some(off) = ib.at(pos, tap) else {
                    continue;
                };
                let pixel = &x.data[off..off + s.c_in];
                for o in 0..s.c_out {
                    let wrow = &filter[o * k + tap * s.c_in..o * k + (tap + 1) * s.c_in];
                    let mut acc = 0.0f32;
                    for (wv, xv) in wrow.iter().zip(pixel) {
                        acc += wv * xv;
                    }
                    // SAFETY: each output position owns its disjoint
                    // `[C_out]` range; writing through the raw pointer
                    // avoids overlapping `&mut` slices across workers.
                    unsafe { *optr.get().add(out_base + o) += acc };
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::naive::conv2d_direct_cnhw;
    use crate::tensor::layout::{nhwc_to_cnhw, cnhw_to_nhwc, oihw_to_filter_matrix};
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn indirect_conv_matches_direct() {
        let mut r = XorShiftRng::new(51);
        for s in [
            ConvShape::square(1, 3, 6, 4, 3, 1, 1),
            ConvShape::square(2, 2, 8, 3, 3, 2, 1),
            ConvShape::square(1, 5, 4, 2, 1, 1, 0),
        ] {
            let x_nhwc = Tensor::random(&[s.n, s.h_in, s.w_in, s.c_in], &mut r, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -1.0, 1.0);
            let ib = IndirectionBuffer::build(&s);
            let got = conv2d_indirect_nhwc(&x_nhwc, &oihw_to_filter_matrix(&w).data, &s, &ib);
            let want_cnhw = conv2d_direct_cnhw(&nhwc_to_cnhw(&x_nhwc), &w, &s);
            let want = cnhw_to_nhwc(&want_cnhw);
            assert!(
                allclose(&got.data, &want.data, 1e-4, 1e-5),
                "{s}: max diff {}",
                crate::util::max_abs_diff(&got.data, &want.data)
            );
        }
    }

    #[test]
    fn padding_taps_are_none() {
        let s = ConvShape::square(1, 1, 3, 1, 3, 1, 1);
        let ib = IndirectionBuffer::build(&s);
        // First output position (0,0): taps at kh=0 or kw=0 are padding.
        assert_eq!(ib.at(0, 0), None); // (-1,-1)
        assert_eq!(ib.at(0, 4), Some(0)); // centre tap -> pixel (0,0)
    }

    #[test]
    fn parallel_matches_serial() {
        use crate::util::ThreadPool;
        let mut r = XorShiftRng::new(52);
        let s = ConvShape::square(2, 4, 9, 6, 3, 2, 1);
        let x = Tensor::random(&[s.n, s.h_in, s.w_in, s.c_in], &mut r, -1.0, 1.0);
        let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -1.0, 1.0);
        let f = oihw_to_filter_matrix(&w).data;
        let ib = IndirectionBuffer::build(&s);
        let serial = conv2d_indirect_nhwc(&x, &f, &s, &ib);
        for threads in [2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let par = conv2d_indirect_nhwc_parallel(&x, &f, &s, &ib, &pool);
            assert_eq!(par.data, serial.data, "threads={threads}");
        }
    }

    #[test]
    fn buffer_bytes_grow_with_output() {
        let small = IndirectionBuffer::build(&ConvShape::square(1, 8, 8, 8, 3, 1, 1));
        let big = IndirectionBuffer::build(&ConvShape::square(1, 8, 16, 8, 3, 1, 1));
        assert!(big.bytes() > small.bytes());
    }
}
