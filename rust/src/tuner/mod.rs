//! AITemplate-style auto-tuner (§3.3): enumerate micro-kernel template
//! candidates — tile size `T ∈ 1..=31`, `LMUL ∈ {1,2,4,8}`, and (for
//! native profiling) the per-layer parallelism degree `P` — profile
//! each on the target, and select the fastest per conv layer.
//!
//! Two profiling backends:
//! * **native** — wall-clock of the native Rust conv path on this host
//!   (what a deployment would use); sweeps `(LMUL, T, P, kernel, dtype)`
//!   with `P` over [`thread_candidates`] of the profiling pool, `dtype`
//!   over `{f32, i8}`, and `kernel`
//!   over the micro-kernel backends available on the host
//!   ([`crate::gemm::kernels::available_ids`]), so each layer also
//!   picks how many pool workers it is worth waking and which SIMD
//!   backend wins at its shape — hardware-shaped execution decisions
//!   are per layer, not global (Kang 2019; Chen et al. 2021);
//! * **sim** — deterministic cycle counts from the single-core RVV
//!   simulator (what reproduces the paper's K1 numbers; used by the
//!   figure benches). The simulator models one hart and its own RVV
//!   ISA, so sim candidates carry `threads = 0` and
//!   `kernel = Auto` (runtime dispatch on whatever host later loads
//!   the choice).
//!
//! Results are memoised in a [`TuneCache`] persisted as TSV, mirroring
//! AITemplate's profiling cache. The TSV is six columns
//! (`key  v  tile  threads  kernel  dtype`); legacy three-column (no
//! threads), four-column (no kernel) and five-column (no dtype) files
//! still load, defaulting the missing fields to 0 = uncapped, `auto`
//! and `f32`.

use std::collections::BTreeMap;
use std::io::Write;

use crate::benchlib::{bench, BenchConfig};
use crate::conv::{Conv2dDenseCnhw, Conv2dSparseCnhw, ConvShape};
use crate::engine::LayerChoice;
use crate::gemm::kernels;
use crate::gemm::KernelId;
use crate::im2col::pack_data_matrix;
use crate::pruning::prune_colwise_adaptive;
use crate::rvv::kernels::{max_tile_for_lmul, sim_spmm_colwise};
use crate::rvv::RvvMachine;
use crate::tensor::dtype::ALL_DTYPES;
use crate::tensor::{Dtype, Tensor};
use crate::util::threadpool::ThreadPool;
use crate::util::XorShiftRng;

/// The LMUL values the paper profiles (§3.3: fractional LMULs excluded).
pub const LMULS: [usize; 4] = [1, 2, 4, 8];

/// One profiled candidate.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    /// RVV register-group multiplier profiled (one of [`LMULS`]).
    pub lmul: usize,
    /// Strip width = VLMAX(lmul) on the 256-bit machine.
    pub v: usize,
    /// Micro-kernel tile height T (accumulator rows kept in registers).
    pub tile: usize,
    /// Parallelism degree profiled (0 = uncapped / not profiled).
    pub threads: usize,
    /// Micro-kernel backend profiled ([`KernelId::Auto`] = runtime
    /// dispatch; what sim candidates carry, since the simulator does
    /// not run the native backends).
    pub kernel: KernelId,
    /// Compute dtype profiled. Native sweeps cover both f32 and the
    /// quantized i8 path; the simulator models the f32 RVV kernel only,
    /// so sim candidates always carry [`Dtype::F32`].
    pub dtype: Dtype,
    /// Profiling score (ns for native, cycles for sim) — lower is better.
    pub score: f64,
}

/// Tuning outcome for one layer.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// The fastest profiled candidate.
    pub best: Candidate,
    /// Every profiled candidate, in sweep order (for reporting).
    pub candidates: Vec<Candidate>,
}

/// Candidate space for a 256-bit/32-register RVV machine: for each LMUL,
/// tiles 1..=min(cap, 32/LMUL − 1). `tile_cap` trims the sweep (the
/// paper profiles up to 32; most optima are ≤ 16).
pub fn candidate_space(tile_cap: usize) -> Vec<(usize, usize)> {
    let m = RvvMachine::k1();
    let mut out = Vec::new();
    for lmul in LMULS {
        let max_t = max_tile_for_lmul(&m, lmul).min(tile_cap);
        for t in 1..=max_t {
            out.push((lmul, t));
        }
    }
    out
}

/// Parallelism degrees worth profiling on a pool of `pool_size`
/// workers: powers of two up to the pool size, plus the pool size
/// itself — e.g. `[1, 2, 4, 6]` for a 6-worker pool. A size-1 pool
/// yields `[1]`, keeping the sweep (and test cost) identical to the
/// pre-threads tuner.
pub fn thread_candidates(pool_size: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut t = 1;
    while t < pool_size {
        out.push(t);
        t *= 2;
    }
    out.push(pool_size.max(1));
    out
}

/// Profile the *simulated* column-wise sparse kernel for `shape` at
/// `sparsity` across the candidate space; deterministic.
pub fn tune_sim_colwise(shape: &ConvShape, sparsity: f64, tile_cap: usize) -> TuneResult {
    let mut rng = XorShiftRng::new(0x7CE ^ shape.c_out as u64);
    let rows = shape.c_out;
    let k = shape.k();
    // Profile on a bounded column count: kernel cost per strip is
    // identical across strips, so a few strips suffice (and keep the
    // sweep fast) — same trick AITemplate uses with reduced problem
    // sizes.
    let w = rng.normal_vec(rows * k, 1.0);
    let a_cols_full = shape.gemm_cols();
    let mut candidates = Vec::new();
    for (lmul, tile) in candidate_space(tile_cap) {
        let mut m = RvvMachine::k1();
        let v = m.vlmax(lmul);
        let cols = a_cols_full.min(4 * v); // ≥ 4 strips worth (or all)
        let a = rng.normal_vec(k * cols, 1.0);
        let p = pack_data_matrix(&a, k, cols, v);
        let cp = prune_colwise_adaptive(&w, rows, k, tile, sparsity);
        let (_, rep) = sim_spmm_colwise(&mut m, &cp, &p, lmul);
        // Scale cycles to the full column count.
        let scale = a_cols_full as f64 / cols as f64;
        candidates.push(Candidate {
            lmul,
            v,
            tile,
            threads: 0, // single-hart simulator: no parallelism dimension
            // The simulator models its own RVV ISA, not this host's
            // SIMD: the choice stays Auto so the deployment host
            // dispatches its own best backend.
            kernel: KernelId::Auto,
            dtype: Dtype::F32, // the simulator profiles the f32 kernel only
            score: rep.cycles as f64 * scale,
        });
    }
    pick(candidates)
}

/// Profile the *native* conv operator (dense or sparse CNHW path) by
/// wall clock, running candidates on the caller's persistent pool so
/// profiling measures the same dispatch the deployment uses. The sweep
/// is the `(LMUL, T, P, kernel, dtype)` product — dtype over `{f32, i8}`
/// (quantized layers trade accuracy for int throughput, so the i8 side
/// only wins where the kernel is genuinely faster) — with `P` over
/// [`thread_candidates`]
/// of the pool size (trimmed to the caps that behave distinctly for
/// the layer's strip count): each layer profiles its own parallelism
/// degree, so small layers whose dispatch overhead dominates tune to
/// small caps. Pass the deployment-sized pool — caps are only
/// meaningful relative to the pool they were measured on.
pub fn tune_native(
    shape: &ConvShape,
    sparsity: Option<f64>,
    pool: &ThreadPool,
    tile_cap: usize,
) -> TuneResult {
    let mut rng = XorShiftRng::new(0xAA7 ^ shape.c_out as u64);
    let x = Tensor::random(
        &[shape.c_in, shape.n, shape.h_in, shape.w_in],
        &mut rng,
        -1.0,
        1.0,
    );
    let w = Tensor::random(
        &[shape.c_out, shape.c_in, shape.kh, shape.kw],
        &mut rng,
        -0.5,
        0.5,
    );
    let cfg = BenchConfig::tuning();
    let threads_space = thread_candidates(pool.size());
    // Fourth sweep dimension: every micro-kernel backend available on
    // this host (always includes the scalar oracle). Forced choices
    // (NMPRUNE_KERNEL) are honoured at run time by the dispatcher, so
    // the tuner still profiles the full space.
    let kernel_space = kernels::available_ids();
    let mut candidates = Vec::new();
    for (lmul, tile) in candidate_space(tile_cap) {
        let v = 8 * lmul;
        // Caps at or beyond the layer's strip count dispatch identically
        // (the pool clamps participants to min(cap, strips)), so profile
        // each distinct behaviour once: every cap below the strip count,
        // plus the smallest cap that saturates it. Small layers — the
        // very ones per-layer caps exist for — would otherwise re-run
        // the same serial dispatch once per candidate.
        let strips = shape.gemm_cols().div_ceil(v);
        let mut caps: Vec<usize> = threads_space.iter().copied().filter(|&t| t < strips).collect();
        if let Some(&t) = threads_space.iter().find(|&&t| t >= strips) {
            caps.push(t);
        }
        // Weight compression/packing (and, for i8, weight quantization)
        // happens once per (LMUL, T, dtype); the parallelism and kernel
        // sweeps only flip dispatch fields.
        for &dtype in &ALL_DTYPES {
            match sparsity {
                None => {
                    let mut op = Conv2dDenseCnhw::new(*shape, &w, v, tile).with_dtype(dtype);
                    for &kernel in &kernel_space {
                        op.kernel = kernel;
                        for &threads in &caps {
                            op.threads = threads;
                            let score = bench("cand", cfg, || op.run(&x, pool)).mean_ns();
                            candidates.push(Candidate {
                                lmul,
                                v,
                                tile,
                                threads,
                                kernel,
                                dtype,
                                score,
                            });
                        }
                    }
                }
                Some(s) => {
                    let mut op =
                        Conv2dSparseCnhw::new_adaptive(*shape, &w, v, tile, s).with_dtype(dtype);
                    for &kernel in &kernel_space {
                        op.kernel = kernel;
                        for &threads in &caps {
                            op.threads = threads;
                            let score = bench("cand", cfg, || op.run(&x, pool)).mean_ns();
                            candidates.push(Candidate {
                                lmul,
                                v,
                                tile,
                                threads,
                                kernel,
                                dtype,
                                score,
                            });
                        }
                    }
                }
            };
        }
    }
    pick(candidates)
}

/// Select the winning candidate. A non-finite score (a timer glitch or
/// an arithmetic accident upstream) must neither win nor crash the
/// sweep: `partial_cmp(...).unwrap()` on a NaN score would panic, so
/// non-finite candidates are filtered out of the ranking and ties are
/// settled by [`f64::total_cmp`]. If *every* score is non-finite the
/// first candidate wins deterministically — a degraded answer, never a
/// panic mid-tune.
fn pick(candidates: Vec<Candidate>) -> TuneResult {
    let best = *candidates
        .iter()
        .filter(|c| c.score.is_finite())
        .min_by(|a, b| a.score.total_cmp(&b.score))
        .or_else(|| candidates.first())
        .expect("empty candidate space");
    TuneResult { best, candidates }
}

impl TuneResult {
    /// The winner as an engine-facing per-layer execution choice.
    pub fn choice(&self) -> LayerChoice {
        LayerChoice {
            v: self.best.v,
            tile: self.best.tile,
            threads: self.best.threads,
            kernel: self.best.kernel,
            dtype: self.best.dtype,
        }
    }
}

// ----------------------------------------------------------------------
// Persistent tuning cache (AITemplate's profiling cache analogue)

/// Key → tuned choice, persisted as TSV at `path`.
#[derive(Clone, Debug, Default)]
pub struct TuneCache {
    /// [`cache_key`] → tuned per-layer choice.
    pub entries: BTreeMap<String, LayerChoice>,
}

/// Cache key for a layer configuration.
pub fn cache_key(shape: &ConvShape, sparsity: Option<f64>) -> String {
    format!(
        "{}x{}x{}x{}_co{}_k{}x{}_s{}_p{}_sp{}",
        shape.n,
        shape.c_in,
        shape.h_in,
        shape.w_in,
        shape.c_out,
        shape.kh,
        shape.kw,
        shape.stride,
        shape.pad,
        sparsity.map(|s| format!("{s:.2}")).unwrap_or_else(|| "dense".into())
    )
}

impl TuneCache {
    /// Load from a TSV file (missing file → empty cache). Accepts the
    /// current six-column format (`key  v  tile  threads  kernel
    /// dtype`) and all legacy layouts — three columns (no threads),
    /// four columns (no kernel) and five columns (no dtype). Missing
    /// fields default to `threads = 0` (uncapped), `kernel = auto`
    /// (runtime dispatch) and `dtype = f32`, so caches written before
    /// any of the dimensions existed keep working.
    ///
    /// Robust against a corrupted cache (satellite): truncated rows, a
    /// trailing partial write (a row cut mid-field by a crash), rows
    /// with non-numeric fields, empty keys, and overlong rows are
    /// *skipped*, never a panic or a half-parsed entry — and `save`
    /// round-trips exactly the rows that survived. A broken cache costs
    /// a re-tune, not an outage.
    pub fn load(path: &str) -> Self {
        let mut entries = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                if let Some((key, choice)) = Self::parse_row(line) {
                    entries.insert(key, choice);
                }
            }
        }
        Self { entries }
    }

    /// Parse one TSV row; `None` for anything malformed.
    fn parse_row(line: &str) -> Option<(String, LayerChoice)> {
        // Tolerate CRLF caches written on other platforms.
        let line = line.trim_end_matches('\r');
        if line.is_empty() {
            return None;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        let (k, v, t, threads, kernel, dtype) = match fields.as_slice() {
            [k, v, t] => (*k, *v, *t, None, None, None),
            [k, v, t, th] => (*k, *v, *t, Some(*th), None, None),
            [k, v, t, th, kn] => (*k, *v, *t, Some(*th), Some(*kn), None),
            [k, v, t, th, kn, dt] => (*k, *v, *t, Some(*th), Some(*kn), Some(*dt)),
            _ => return None, // truncated or overlong row
        };
        if k.is_empty() {
            return None;
        }
        let v: usize = v.trim().parse().ok()?;
        let tile: usize = t.trim().parse().ok()?;
        // A present-but-garbled threads, kernel or dtype column means
        // the row was cut mid-write: skip it entirely rather than
        // guessing.
        let threads: usize = match threads {
            None => 0,
            Some(th) => th.trim().parse().ok()?,
        };
        let kernel: KernelId = match kernel {
            None => KernelId::Auto,
            Some(kn) => KernelId::from_name(kn.trim())?,
        };
        let dtype: Dtype = match dtype {
            None => Dtype::F32,
            Some(dt) => Dtype::from_name(dt.trim())?,
        };
        Some((
            k.to_string(),
            LayerChoice {
                v,
                tile,
                threads,
                kernel,
                dtype,
            },
        ))
    }

    /// Persist as TSV (`key  v  tile  threads  kernel  dtype`).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for (k, c) in &self.entries {
            writeln!(
                f,
                "{k}\t{}\t{}\t{}\t{}\t{}",
                c.v,
                c.tile,
                c.threads,
                c.kernel.name(),
                c.dtype.name()
            )?;
        }
        Ok(())
    }

    /// Lookup or compute via `f`, inserting on miss.
    pub fn get_or_tune<F: FnOnce() -> LayerChoice>(
        &mut self,
        key: String,
        f: F,
    ) -> LayerChoice {
        if let Some(c) = self.entries.get(&key) {
            return *c;
        }
        let c = f();
        self.entries.insert(key, c);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_space_respects_register_file() {
        let space = candidate_space(31);
        // LMUL=8 allows at most 3 accumulators (+1 data reg = 4 logical).
        assert!(space.iter().filter(|(l, _)| *l == 8).count() == 3);
        assert!(space.iter().filter(|(l, _)| *l == 1).count() == 31);
        assert!(space.iter().all(|&(l, t)| t >= 1 && LMULS.contains(&l)));
    }

    #[test]
    fn sim_tuning_picks_minimum() {
        let shape = ConvShape::square(1, 16, 14, 32, 3, 1, 1);
        let r = tune_sim_colwise(&shape, 0.5, 8);
        for c in &r.candidates {
            assert!(r.best.score <= c.score);
        }
        assert!(r.best.tile >= 1);
    }

    #[test]
    fn sim_tuning_larger_tiles_amortise_loads() {
        // At fixed LMUL, tile 8 must beat tile 1 in cycles: the data row
        // is reused 8× per load (the core Algorithm-1 effect).
        let shape = ConvShape::square(1, 16, 14, 32, 3, 1, 1);
        let r = tune_sim_colwise(&shape, 0.5, 8);
        let score = |lmul: usize, tile: usize| {
            r.candidates
                .iter()
                .find(|c| c.lmul == lmul && c.tile == tile)
                .unwrap()
                .score
        };
        assert!(score(1, 8) < score(1, 1));
        assert!(score(2, 8) < score(2, 1));
    }

    #[test]
    fn native_tuning_runs_quickly_and_picks() {
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        let pool = ThreadPool::new(1);
        let r = tune_native(&shape, Some(0.5), &pool, 4);
        assert!(!r.candidates.is_empty());
        assert!(r.best.score > 0.0);
        let c = r.choice();
        assert_eq!(c.v, 8 * r.best.lmul);
        // A size-1 pool has exactly one parallelism candidate.
        assert_eq!(c.threads, 1);
        assert!(r.candidates.iter().all(|cand| cand.threads == 1));
        // Every backend available on this host was profiled, and the
        // winner is one of them (never Auto — the tuner picks concretely).
        for id in kernels::available_ids() {
            assert!(
                r.candidates.iter().any(|cand| cand.kernel == id),
                "backend {id} not profiled"
            );
        }
        assert_ne!(c.kernel, KernelId::Auto);
        // Both compute dtypes were profiled (the fifth sweep dimension).
        for dt in ALL_DTYPES {
            assert!(
                r.candidates.iter().any(|cand| cand.dtype == dt),
                "dtype {dt} not profiled"
            );
        }
    }

    #[test]
    fn native_tuning_emits_a_per_layer_thread_cap() {
        // On a multi-worker profiling pool the winner carries a concrete
        // parallelism degree, both degrees are profiled where they
        // behave distinctly, and caps that cannot differ (strip count 1
        // at LMUL=8: v = 64 covers the whole 8x8 output) are profiled
        // exactly once.
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        let pool = ThreadPool::new(2);
        let r = tune_native(&shape, Some(0.5), &pool, 2);
        assert!(r.best.threads == 1 || r.best.threads == 2);
        assert_eq!(r.choice().threads, r.best.threads);
        for th in [1usize, 2] {
            assert!(
                r.candidates.iter().any(|c| c.lmul == 1 && c.threads == th),
                "thread degree {th} not profiled at LMUL=1 (8 strips)"
            );
        }
        let lmul8: Vec<_> = r.candidates.iter().filter(|c| c.lmul == 8).collect();
        assert!(!lmul8.is_empty());
        assert!(
            lmul8.iter().all(|c| c.threads == 1),
            "single-strip layers must not re-profile redundant caps"
        );
        // No duplicate (lmul, tile, threads, kernel, dtype)
        // configurations anywhere.
        let mut keys: Vec<_> = r
            .candidates
            .iter()
            .map(|c| (c.lmul, c.tile, c.threads, c.kernel.code(), c.dtype.code()))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), r.candidates.len(), "duplicate candidates profiled");
    }

    #[test]
    fn thread_candidates_cover_pool_sizes() {
        assert_eq!(thread_candidates(1), vec![1]);
        assert_eq!(thread_candidates(2), vec![1, 2]);
        assert_eq!(thread_candidates(6), vec![1, 2, 4, 6]);
        assert_eq!(thread_candidates(8), vec![1, 2, 4, 8]);
        assert_eq!(thread_candidates(0), vec![1]);
    }

    #[test]
    fn cache_roundtrip() {
        let mut cache = TuneCache::default();
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        let key = cache_key(&shape, Some(0.5));
        let want = LayerChoice {
            v: 16,
            tile: 4,
            threads: 2,
            kernel: KernelId::Avx2,
            dtype: Dtype::I8,
        };
        let choice = cache.get_or_tune(key.clone(), || want);
        assert_eq!(choice, want);
        // hit path
        let hit = cache.get_or_tune(key.clone(), || panic!("must not re-tune"));
        assert_eq!(hit, choice);
        let path = "/tmp/nmprune_tune_cache_test.tsv";
        cache.save(path).unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(loaded.entries.get(&key), Some(&choice));
        std::fs::remove_file(path).ok();
    }

    /// Satellite: the five-column TSV (threads and kernel included)
    /// re-loads identically, for caps of every flavour (uncapped 0,
    /// small, large) and every kernel id, Auto included.
    #[test]
    fn cache_roundtrip_preserves_thread_caps() {
        use crate::gemm::kernels::ALL_KERNEL_IDS;
        let mut cache = TuneCache::default();
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        for (i, threads) in [0usize, 1, 2, 16].into_iter().enumerate() {
            let key = cache_key(&shape, Some(0.1 * (i + 1) as f64));
            cache.entries.insert(
                key,
                LayerChoice {
                    v: 8 << (i % 3),
                    tile: 1 + i,
                    threads,
                    kernel: ALL_KERNEL_IDS[i % ALL_KERNEL_IDS.len()],
                    dtype: ALL_DTYPES[i % ALL_DTYPES.len()],
                },
            );
        }
        let path = "/tmp/nmprune_tune_cache_threads_test.tsv";
        cache.save(path).unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(loaded.entries, cache.entries);
        std::fs::remove_file(path).ok();
    }

    /// Satellite: a legacy three-column TSV (written before the threads
    /// column existed) loads with the default uncapped degree instead
    /// of erroring or dropping rows.
    #[test]
    fn cache_loads_legacy_tsv_without_threads_column() {
        let path = "/tmp/nmprune_tune_cache_legacy_test.tsv";
        std::fs::write(path, "layerA\t16\t4\nlayerB\t32\t8\n").unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(loaded.entries.len(), 2);
        assert_eq!(
            loaded.entries.get("layerA"),
            Some(&LayerChoice {
                v: 16,
                tile: 4,
                threads: 0,
                kernel: KernelId::Auto,
                dtype: Dtype::F32
            })
        );
        assert_eq!(
            loaded.entries.get("layerB"),
            Some(&LayerChoice {
                v: 32,
                tile: 8,
                threads: 0,
                kernel: KernelId::Auto,
                dtype: Dtype::F32
            })
        );
        std::fs::remove_file(path).ok();
    }

    /// Satellite: a four-column TSV (written before the kernel column
    /// existed) loads with `kernel = auto` instead of erroring.
    #[test]
    fn cache_loads_legacy_tsv_without_kernel_column() {
        let path = "/tmp/nmprune_tune_cache_legacy_kernel_test.tsv";
        std::fs::write(path, "layerA\t16\t4\t2\n").unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(
            loaded.entries.get("layerA"),
            Some(&LayerChoice {
                v: 16,
                tile: 4,
                threads: 2,
                kernel: KernelId::Auto,
                dtype: Dtype::F32
            })
        );
        std::fs::remove_file(path).ok();
    }

    /// A five-column TSV (written before the dtype column existed)
    /// loads with `dtype = f32` instead of erroring, and a six-column
    /// row round-trips its dtype.
    #[test]
    fn cache_loads_legacy_tsv_without_dtype_column() {
        let path = "/tmp/nmprune_tune_cache_legacy_dtype_test.tsv";
        std::fs::write(path, "layerA\t16\t4\t2\tavx2\nlayerB\t32\t8\t0\tauto\ti8\n").unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(
            loaded.entries.get("layerA"),
            Some(&LayerChoice {
                v: 16,
                tile: 4,
                threads: 2,
                kernel: KernelId::Avx2,
                dtype: Dtype::F32
            })
        );
        assert_eq!(
            loaded.entries.get("layerB"),
            Some(&LayerChoice {
                v: 32,
                tile: 8,
                threads: 0,
                kernel: KernelId::Auto,
                dtype: Dtype::I8
            })
        );
        std::fs::remove_file(path).ok();
    }

    /// Satellite: a corrupted cache file — truncated rows, non-numeric
    /// fields, a trailing partial write, overlong rows, empty keys and
    /// blank lines — loads without panicking, keeps exactly the valid
    /// rows, and `save` round-trips what survived.
    #[test]
    fn cache_load_skips_malformed_rows_and_roundtrips_survivors() {
        let path = "/tmp/nmprune_tune_cache_malformed_test.tsv";
        let text = concat!(
            "good1\t16\t4\t2\n",                  // valid legacy 4-col → kernel auto
            "good2\t32\t8\n",                     // valid legacy 3-col → threads 0
            "good4\t16\t8\t1\tscalar\n",          // valid 5-col
            "truncated\t16\n",                    // too few columns
            "nonnum\tsixteen\t4\t2\n",            // non-numeric v
            "nonnum2\t16\tfour\t2\n",             // non-numeric tile
            "nonnum3\t16\t4\ttwo\n",              // non-numeric threads → skip, not 0
            "badkern\t16\t4\t2\twarp9\n",         // unknown kernel name → skip, not auto
            "badtype\t16\t4\t2\tscalar\tint4\n",  // unknown dtype name → skip, not f32
            "\t16\t4\t2\n",                       // empty key
            "overlong\t16\t4\t2\tscalar\tf32\textra\n", // too many columns
            "\n",                                 // blank line
            "good3\t8\t1\t0\n",                   // valid after the garbage
            "partial\t1"                          // trailing partial write (crash mid-row)
        );
        std::fs::write(path, text).unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(
            loaded.entries.keys().map(String::as_str).collect::<Vec<_>>(),
            vec!["good1", "good2", "good3", "good4"],
            "exactly the well-formed rows survive"
        );
        assert_eq!(
            loaded.entries.get("good1"),
            Some(&LayerChoice { v: 16, tile: 4, threads: 2, kernel: KernelId::Auto, dtype: Dtype::F32 })
        );
        assert_eq!(
            loaded.entries.get("good2"),
            Some(&LayerChoice { v: 32, tile: 8, threads: 0, kernel: KernelId::Auto, dtype: Dtype::F32 })
        );
        assert_eq!(
            loaded.entries.get("good4"),
            Some(&LayerChoice { v: 16, tile: 8, threads: 1, kernel: KernelId::Scalar, dtype: Dtype::F32 })
        );
        // Round-trip: saving the survivors and re-loading is identity.
        loaded.save(path).unwrap();
        let reloaded = TuneCache::load(path);
        assert_eq!(reloaded.entries, loaded.entries);
        std::fs::remove_file(path).ok();
    }

    /// Windows-style CRLF line endings parse identically to LF.
    #[test]
    fn cache_load_tolerates_crlf() {
        let path = "/tmp/nmprune_tune_cache_crlf_test.tsv";
        std::fs::write(path, "layerA\t16\t4\t1\tscalar\r\nlayerB\t32\t8\r\n").unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(
            loaded.entries.get("layerA"),
            Some(&LayerChoice { v: 16, tile: 4, threads: 1, kernel: KernelId::Scalar, dtype: Dtype::F32 })
        );
        assert_eq!(
            loaded.entries.get("layerB"),
            Some(&LayerChoice { v: 32, tile: 8, threads: 0, kernel: KernelId::Auto, dtype: Dtype::F32 })
        );
        std::fs::remove_file(path).ok();
    }

    /// Bugfix: a NaN score (a garbled probe) used to panic `pick` via
    /// `partial_cmp(...).unwrap()`. Non-finite scores must never win
    /// and never crash the sweep.
    #[test]
    fn pick_ignores_non_finite_scores() {
        let cand = |score: f64| Candidate {
            lmul: 1,
            v: 8,
            tile: 1,
            threads: 1,
            kernel: KernelId::Scalar,
            dtype: Dtype::F32,
            score,
        };
        let r = pick(vec![
            cand(5.0),
            cand(f64::NAN),
            cand(3.0),
            cand(f64::INFINITY),
            cand(4.0),
        ]);
        assert_eq!(r.best.score, 3.0);
        assert_eq!(r.candidates.len(), 5, "candidates are reported unfiltered");
    }

    /// Bugfix companion: an all-non-finite sweep degrades to the first
    /// candidate deterministically instead of panicking.
    #[test]
    fn pick_survives_all_non_finite_scores() {
        let cand = |tile: usize, score: f64| Candidate {
            lmul: 1,
            v: 8,
            tile,
            threads: 1,
            kernel: KernelId::Scalar,
            dtype: Dtype::F32,
            score,
        };
        let r = pick(vec![cand(1, f64::NAN), cand(2, f64::NAN)]);
        assert_eq!(r.best.tile, 1, "falls back to the first candidate");
        assert!(r.best.score.is_nan());
    }

    #[test]
    fn cache_key_distinguishes_sparsity() {
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        assert_ne!(cache_key(&shape, None), cache_key(&shape, Some(0.5)));
        assert_ne!(cache_key(&shape, Some(0.25)), cache_key(&shape, Some(0.5)));
    }
}
