//! AITemplate-style auto-tuner (§3.3): enumerate micro-kernel template
//! candidates — tile size `T ∈ 1..=31` and `LMUL ∈ {1,2,4,8}` — profile
//! each on the target, and select the fastest per conv layer.
//!
//! Two profiling backends:
//! * **native** — wall-clock of the native Rust conv path on this host
//!   (what a deployment would use);
//! * **sim** — deterministic cycle counts from the RVV simulator (what
//!   reproduces the paper's K1 numbers; used by the figure benches).
//!
//! Results are memoised in a [`TuneCache`] persisted as TSV, mirroring
//! AITemplate's profiling cache.

use std::collections::BTreeMap;
use std::io::Write;
use std::time::Duration;

use crate::benchlib::{bench, BenchConfig};
use crate::conv::{Conv2dDenseCnhw, Conv2dSparseCnhw, ConvShape};
use crate::engine::LayerChoice;
use crate::im2col::pack_data_matrix;
use crate::pruning::prune_colwise_adaptive;
use crate::rvv::kernels::{max_tile_for_lmul, sim_spmm_colwise};
use crate::rvv::RvvMachine;
use crate::tensor::Tensor;
use crate::util::threadpool::ThreadPool;
use crate::util::XorShiftRng;

/// The LMUL values the paper profiles (§3.3: fractional LMULs excluded).
pub const LMULS: [usize; 4] = [1, 2, 4, 8];

/// One profiled candidate.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub lmul: usize,
    /// Strip width = VLMAX(lmul) on the 256-bit machine.
    pub v: usize,
    pub tile: usize,
    /// Profiling score (ns for native, cycles for sim) — lower is better.
    pub score: f64,
}

/// Tuning outcome for one layer.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: Candidate,
    pub candidates: Vec<Candidate>,
}

/// Candidate space for a 256-bit/32-register RVV machine: for each LMUL,
/// tiles 1..=min(cap, 32/LMUL − 1). `tile_cap` trims the sweep (the
/// paper profiles up to 32; most optima are ≤ 16).
pub fn candidate_space(tile_cap: usize) -> Vec<(usize, usize)> {
    let m = RvvMachine::k1();
    let mut out = Vec::new();
    for lmul in LMULS {
        let max_t = max_tile_for_lmul(&m, lmul).min(tile_cap);
        for t in 1..=max_t {
            out.push((lmul, t));
        }
    }
    out
}

/// Profile the *simulated* column-wise sparse kernel for `shape` at
/// `sparsity` across the candidate space; deterministic.
pub fn tune_sim_colwise(shape: &ConvShape, sparsity: f64, tile_cap: usize) -> TuneResult {
    let mut rng = XorShiftRng::new(0x7CE ^ shape.c_out as u64);
    let rows = shape.c_out;
    let k = shape.k();
    // Profile on a bounded column count: kernel cost per strip is
    // identical across strips, so a few strips suffice (and keep the
    // sweep fast) — same trick AITemplate uses with reduced problem
    // sizes.
    let w = rng.normal_vec(rows * k, 1.0);
    let a_cols_full = shape.gemm_cols();
    let mut candidates = Vec::new();
    for (lmul, tile) in candidate_space(tile_cap) {
        let mut m = RvvMachine::k1();
        let v = m.vlmax(lmul);
        let cols = a_cols_full.min(4 * v); // ≥ 4 strips worth (or all)
        let a = rng.normal_vec(k * cols, 1.0);
        let p = pack_data_matrix(&a, k, cols, v);
        let cp = prune_colwise_adaptive(&w, rows, k, tile, sparsity);
        let (_, rep) = sim_spmm_colwise(&mut m, &cp, &p, lmul);
        // Scale cycles to the full column count.
        let scale = a_cols_full as f64 / cols as f64;
        candidates.push(Candidate {
            lmul,
            v,
            tile,
            score: rep.cycles as f64 * scale,
        });
    }
    pick(candidates)
}

/// Profile the *native* conv operator (dense or sparse CNHW path) by
/// wall clock, running candidates on the caller's persistent pool so
/// profiling measures the same dispatch the deployment uses.
pub fn tune_native(
    shape: &ConvShape,
    sparsity: Option<f64>,
    pool: &ThreadPool,
    tile_cap: usize,
) -> TuneResult {
    let mut rng = XorShiftRng::new(0xAA7 ^ shape.c_out as u64);
    let x = Tensor::random(
        &[shape.c_in, shape.n, shape.h_in, shape.w_in],
        &mut rng,
        -1.0,
        1.0,
    );
    let w = Tensor::random(
        &[shape.c_out, shape.c_in, shape.kh, shape.kw],
        &mut rng,
        -0.5,
        0.5,
    );
    let cfg = BenchConfig {
        warmup: Duration::from_millis(5),
        measure: Duration::from_millis(40),
        min_samples: 3,
        max_samples: 20,
    };
    let mut candidates = Vec::new();
    for (lmul, tile) in candidate_space(tile_cap) {
        let v = 8 * lmul;
        let score = match sparsity {
            None => {
                let op = Conv2dDenseCnhw::new(*shape, &w, v, tile);
                bench("cand", cfg, || op.run(&x, pool)).mean_ns()
            }
            Some(s) => {
                let op = Conv2dSparseCnhw::new_adaptive(*shape, &w, v, tile, s);
                bench("cand", cfg, || op.run(&x, pool)).mean_ns()
            }
        };
        candidates.push(Candidate {
            lmul,
            v,
            tile,
            score,
        });
    }
    pick(candidates)
}

fn pick(candidates: Vec<Candidate>) -> TuneResult {
    let best = *candidates
        .iter()
        .min_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
        .expect("empty candidate space");
    TuneResult { best, candidates }
}

impl TuneResult {
    pub fn choice(&self) -> LayerChoice {
        LayerChoice {
            v: self.best.v,
            tile: self.best.tile,
        }
    }
}

// ----------------------------------------------------------------------
// Persistent tuning cache (AITemplate's profiling cache analogue)

/// Key → tuned choice, persisted as TSV at `path`.
#[derive(Clone, Debug, Default)]
pub struct TuneCache {
    pub entries: BTreeMap<String, LayerChoice>,
}

/// Cache key for a layer configuration.
pub fn cache_key(shape: &ConvShape, sparsity: Option<f64>) -> String {
    format!(
        "{}x{}x{}x{}_co{}_k{}x{}_s{}_p{}_sp{}",
        shape.n,
        shape.c_in,
        shape.h_in,
        shape.w_in,
        shape.c_out,
        shape.kh,
        shape.kw,
        shape.stride,
        shape.pad,
        sparsity.map(|s| format!("{s:.2}")).unwrap_or_else(|| "dense".into())
    )
}

impl TuneCache {
    /// Load from a TSV file (missing file → empty cache).
    pub fn load(path: &str) -> Self {
        let mut entries = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                let mut parts = line.split('\t');
                if let (Some(k), Some(v), Some(t)) =
                    (parts.next(), parts.next(), parts.next())
                {
                    if let (Ok(v), Ok(t)) = (v.parse(), t.parse()) {
                        entries.insert(k.to_string(), LayerChoice { v, tile: t });
                    }
                }
            }
        }
        Self { entries }
    }

    /// Persist as TSV.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for (k, c) in &self.entries {
            writeln!(f, "{k}\t{}\t{}", c.v, c.tile)?;
        }
        Ok(())
    }

    /// Lookup or compute via `f`, inserting on miss.
    pub fn get_or_tune<F: FnOnce() -> LayerChoice>(
        &mut self,
        key: String,
        f: F,
    ) -> LayerChoice {
        if let Some(c) = self.entries.get(&key) {
            return *c;
        }
        let c = f();
        self.entries.insert(key, c);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_space_respects_register_file() {
        let space = candidate_space(31);
        // LMUL=8 allows at most 3 accumulators (+1 data reg = 4 logical).
        assert!(space.iter().filter(|(l, _)| *l == 8).count() == 3);
        assert!(space.iter().filter(|(l, _)| *l == 1).count() == 31);
        assert!(space.iter().all(|&(l, t)| t >= 1 && LMULS.contains(&l)));
    }

    #[test]
    fn sim_tuning_picks_minimum() {
        let shape = ConvShape::square(1, 16, 14, 32, 3, 1, 1);
        let r = tune_sim_colwise(&shape, 0.5, 8);
        for c in &r.candidates {
            assert!(r.best.score <= c.score);
        }
        assert!(r.best.tile >= 1);
    }

    #[test]
    fn sim_tuning_larger_tiles_amortise_loads() {
        // At fixed LMUL, tile 8 must beat tile 1 in cycles: the data row
        // is reused 8× per load (the core Algorithm-1 effect).
        let shape = ConvShape::square(1, 16, 14, 32, 3, 1, 1);
        let r = tune_sim_colwise(&shape, 0.5, 8);
        let score = |lmul: usize, tile: usize| {
            r.candidates
                .iter()
                .find(|c| c.lmul == lmul && c.tile == tile)
                .unwrap()
                .score
        };
        assert!(score(1, 8) < score(1, 1));
        assert!(score(2, 8) < score(2, 1));
    }

    #[test]
    fn native_tuning_runs_quickly_and_picks() {
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        let pool = ThreadPool::new(1);
        let r = tune_native(&shape, Some(0.5), &pool, 4);
        assert!(!r.candidates.is_empty());
        assert!(r.best.score > 0.0);
        let c = r.choice();
        assert_eq!(c.v, 8 * r.best.lmul);
    }

    #[test]
    fn cache_roundtrip() {
        let mut cache = TuneCache::default();
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        let key = cache_key(&shape, Some(0.5));
        let choice = cache.get_or_tune(key.clone(), || LayerChoice { v: 16, tile: 4 });
        assert_eq!(choice, LayerChoice { v: 16, tile: 4 });
        // hit path
        let hit = cache.get_or_tune(key.clone(), || panic!("must not re-tune"));
        assert_eq!(hit, choice);
        let path = "/tmp/nmprune_tune_cache_test.tsv";
        cache.save(path).unwrap();
        let loaded = TuneCache::load(path);
        assert_eq!(loaded.entries.get(&key), Some(&choice));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cache_key_distinguishes_sparsity() {
        let shape = ConvShape::square(1, 8, 8, 16, 3, 1, 1);
        assert_ne!(cache_key(&shape, None), cache_key(&shape, Some(0.5)));
        assert_ne!(cache_key(&shape, Some(0.25)), cache_key(&shape, Some(0.5)));
    }
}
