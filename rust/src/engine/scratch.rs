//! Plan-sized scratch memory for zero-alloc steady-state inference.
//!
//! [`MemoryPlan`] is computed once at executor build time from the
//! graph's static shapes: every node's output shape is known up front,
//! so activation storage can be colored onto a small set of reusable
//! slots by walking the graph in topological (= execution) order with a
//! free list. A node's output slot is claimed *before* its inputs'
//! slots are released, so an output never aliases a live input; after
//! that, plan order equals run order and no liveness bookkeeping is
//! needed at inference time.
//!
//! [`ScratchArena`] materialises a plan: one capacity-preallocated
//! [`Tensor`] per slot plus a single worst-case-sized [`PackedMatrix`]
//! panel shared by every conv layer (conv panels are consumed within
//! the layer, so one suffices). `Executor::run_capped_in` threads the
//! arena through the `_into` op kernels, making steady-state inference
//! allocation-free on the compute plane — the property
//! `rust/tests/zero_alloc.rs` proves with a counting allocator.

use crate::models::{Graph, Node, Op};
use crate::tensor::Tensor;

use crate::im2col::{PackedMatrix, QuantPanel};

/// Output shape of `node` given the executor's activation layout.
/// GAP and FC emit 2-D `[batch, features]`; everything else is 4-D
/// NHWC or CNHW according to the execution path.
fn node_out_shape(node: &Node, batch: usize, nhwc: bool) -> Vec<usize> {
    match node.op {
        Op::GlobalAvgPool | Op::Fc { .. } => vec![batch, node.out_c],
        _ => {
            if nhwc {
                vec![batch, node.out_h, node.out_w, node.out_c]
            } else {
                vec![node.out_c, batch, node.out_h, node.out_w]
            }
        }
    }
}

/// Static activation-memory plan for one graph + execution path:
/// which scratch slot each node writes, how big every slot must be,
/// and the worst-case conv panel size. Build once, reuse per arena.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    /// Node id → scratch slot index.
    pub node_slot: Vec<usize>,
    /// Node id → output shape under the planned layout.
    pub shapes: Vec<Vec<usize>>,
    /// Slot index → required capacity in elements (max over the nodes
    /// colored onto that slot).
    pub slot_elems: Vec<usize>,
    /// Worst-case packed-panel size in elements over all conv layers
    /// (0 on the NHWC path, which packs nothing).
    pub panel_elems: usize,
    /// Worst-case quantized-panel size in elements over the conv layers
    /// that run int8 (0 when every layer stays f32): the i8 staging
    /// buffer activations are quantized into before the int8 GEMM.
    pub qpanel_elems: usize,
}

impl MemoryPlan {
    /// Color the graph's activations onto reusable slots.
    ///
    /// Greedy free-list coloring in topo order: claim (or create) the
    /// output slot first, then release input slots whose consumer
    /// counts are exhausted. The final node's slot is never released —
    /// it holds the logits the caller borrows after a run.
    pub fn plan(graph: &Graph, nhwc: bool, panel_elems: usize, qpanel_elems: usize) -> Self {
        let n_nodes = graph.nodes.len();
        assert!(n_nodes > 0, "cannot plan an empty graph");
        let mut remaining = vec![0usize; n_nodes];
        for node in &graph.nodes {
            for &i in &node.inputs {
                remaining[i] += 1;
            }
        }
        // Keep the output alive past the walk.
        remaining[n_nodes - 1] += 1;

        let mut node_slot = vec![usize::MAX; n_nodes];
        let mut shapes = Vec::with_capacity(n_nodes);
        let mut slot_elems: Vec<usize> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        for node in &graph.nodes {
            let shape = node_out_shape(node, graph.batch, nhwc);
            let elems = shape.iter().product::<usize>();
            // Output slot before input release: never alias a live input.
            let slot = free.pop().unwrap_or_else(|| {
                slot_elems.push(0);
                slot_elems.len() - 1
            });
            slot_elems[slot] = slot_elems[slot].max(elems);
            node_slot[node.id] = slot;
            shapes.push(shape);
            for &i in &node.inputs {
                remaining[i] -= 1;
                if remaining[i] == 0 {
                    free.push(node_slot[i]);
                }
            }
        }
        Self {
            node_slot,
            shapes,
            slot_elems,
            panel_elems,
            qpanel_elems,
        }
    }

    /// Total activation footprint of the plan in bytes (slots + panel +
    /// the 1-byte-per-element quantized panel).
    pub fn bytes(&self) -> usize {
        4 * (self.slot_elems.iter().sum::<usize>() + self.panel_elems) + self.qpanel_elems
    }
}

/// Materialised scratch memory for one in-flight inference: owns the
/// slot tensors and the shared conv panel. One arena serves one request
/// at a time; a server keeps one per dispatcher thread.
pub struct ScratchArena {
    pub(crate) plan: MemoryPlan,
    pub(crate) slots: Vec<Tensor>,
    pub(crate) panel: PackedMatrix,
    pub(crate) qpanel: QuantPanel,
}

impl ScratchArena {
    /// Allocate every slot (and the conv panel) at full planned
    /// capacity up front. After construction, running inference through
    /// the arena performs no heap allocation: slot tensors are resized
    /// only within their preallocated capacity, and the panel is
    /// `reset` within its worst-case size.
    pub fn new(plan: MemoryPlan) -> Self {
        let slots = plan
            .slot_elems
            .iter()
            .map(|&cap| {
                let mut t = Tensor {
                    shape: Vec::with_capacity(4),
                    data: Vec::with_capacity(cap),
                };
                // Touch the pages now, not on first inference.
                t.data.resize(cap, 0.0);
                t
            })
            .collect();
        let panel = PackedMatrix::zeros(1, plan.panel_elems.max(1), 1);
        let qpanel = QuantPanel::zeros(1, plan.qpanel_elems.max(1), 1);
        Self {
            plan,
            slots,
            panel,
            qpanel,
        }
    }

    /// The plan this arena was built from.
    pub fn plan(&self) -> &MemoryPlan {
        &self.plan
    }

    /// Resident scratch footprint in bytes (slot + panel + qpanel
    /// capacity).
    pub fn bytes(&self) -> usize {
        4 * (self.slots.iter().map(|t| t.data.capacity()).sum::<usize>()
            + self.panel.data.capacity())
            + self.qpanel.data.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_model, ModelArch};

    fn plan_for(arch: ModelArch, nhwc: bool) -> (Graph, MemoryPlan) {
        let g = build_model(arch, 1, 32);
        let p = MemoryPlan::plan(&g, nhwc, 4096, 4096);
        (g, p)
    }

    /// A node's output slot must differ from every live input's slot,
    /// and two simultaneously-live nodes must never share a slot.
    #[test]
    fn no_output_aliases_a_live_input() {
        for arch in [ModelArch::ResNet18, ModelArch::MobileNetV2, ModelArch::DenseNet121] {
            for nhwc in [false, true] {
                let (g, p) = plan_for(arch, nhwc);
                let mut remaining = vec![0usize; g.nodes.len()];
                for node in &g.nodes {
                    for &i in &node.inputs {
                        remaining[i] += 1;
                    }
                }
                remaining[g.nodes.len() - 1] += 1;
                let mut live: Vec<usize> = Vec::new(); // live node ids
                for node in &g.nodes {
                    for &i in &live {
                        assert_ne!(
                            p.node_slot[node.id], p.node_slot[i],
                            "{arch:?}: node {} reuses live slot of node {i}",
                            node.id
                        );
                    }
                    live.push(node.id);
                    for &i in &node.inputs {
                        remaining[i] -= 1;
                        if remaining[i] == 0 {
                            live.retain(|&l| l != i);
                        }
                    }
                }
            }
        }
    }

    /// Slot capacities must cover every node colored onto the slot.
    #[test]
    fn slot_capacity_covers_every_colored_node() {
        let (g, p) = plan_for(ModelArch::DenseNet121, false);
        for node in &g.nodes {
            let elems = p.shapes[node.id].iter().product::<usize>();
            assert!(p.slot_elems[p.node_slot[node.id]] >= elems);
        }
        // Coloring actually shares: far fewer slots than nodes.
        assert!(
            p.slot_elems.len() < g.nodes.len() / 2,
            "{} slots for {} nodes",
            p.slot_elems.len(),
            g.nodes.len()
        );
    }

    /// The plan's byte figure bounds the arena's resident footprint,
    /// and slot tensors come back fully pre-faulted.
    #[test]
    fn arena_materialises_plan_capacity() {
        let (_, p) = plan_for(ModelArch::ResNet18, false);
        let planned = p.bytes();
        let arena = ScratchArena::new(p);
        assert!(arena.bytes() >= planned);
        for (i, t) in arena.slots.iter().enumerate() {
            assert_eq!(t.data.len(), arena.plan.slot_elems[i]);
        }
        assert!(arena.panel.data.len() >= arena.plan.panel_elems);
        assert!(arena.qpanel.data.len() >= arena.plan.qpanel_elems);
    }
}
