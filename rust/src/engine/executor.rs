//! Graph executor: materialises weights, prepares per-layer conv
//! operators according to the configured execution path and tuning
//! choices, and runs inference.
//!
//! Mirrors the paper's pipeline (§4.1.2): the NHWC input is converted to
//! CNHW before the first convolution, CNHW is kept throughout, and
//! weights of every conv except the first are pruned (the stem has 3
//! input channels and negligible cost).

use std::collections::HashMap;
use std::sync::Arc;

use crate::conv::{Conv2dDenseCnhw, Conv2dDenseNhwc, Conv2dSparseCnhw, ConvPath, ConvShape};
use crate::gemm::KernelId;
use crate::models::{Graph, Op};
use crate::runtime::artifact::{ArtifactLayer, LayerWeights, PackedArtifact};
use crate::runtime::RuntimeError;
use crate::tensor::layout::{nhwc_to_cnhw, nhwc_to_cnhw_into};
use crate::tensor::{Dtype, Tensor};
use crate::util::threadpool::ThreadPool;
use crate::util::XorShiftRng;

use super::ops;
use super::scratch::{MemoryPlan, ScratchArena};

/// Per-conv-layer micro-kernel parameters: strip width `v` (= VLMAX of
/// the chosen LMUL), register tile height `tile`, the parallelism
/// cap `threads`, the micro-kernel backend `kernel`, and the compute
/// `dtype` — the five knobs the tuner (§3.3, extended) selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerChoice {
    pub v: usize,
    pub tile: usize,
    /// Max pool participants this layer's GEMM may occupy per call;
    /// 0 = uncapped (whole pool). Small layers where dispatch overhead
    /// dominates tune to small caps.
    pub threads: usize,
    /// Micro-kernel backend ([`KernelId::Auto`] = runtime dispatch).
    /// Artifacts record the tuned backend; an unavailable choice on the
    /// loading host falls back to the best available one.
    pub kernel: KernelId,
    /// Compute dtype for this layer's GEMM ([`Dtype::F32`] = master
    /// weights as-is; [`Dtype::I8`] = symmetric per-output-channel
    /// weight quantization + per-panel activation quantization with an
    /// i32-accumulating kernel). CNHW paths only — the NHWC baseline
    /// always runs f32. `NMPRUNE_DTYPE` overrides this at executor
    /// *build* time (never on the zero-alloc run path).
    pub dtype: Dtype,
}

impl Default for LayerChoice {
    /// LMUL=4 (v = 32 lanes on a 256-bit machine) and T=8: the SiFive
    /// baseline's fixed configuration (§4.4); uncapped parallelism,
    /// runtime-dispatched backend.
    fn default() -> Self {
        Self {
            v: 32,
            tile: 8,
            threads: 0,
            kernel: KernelId::Auto,
            dtype: Dtype::F32,
        }
    }
}

/// Effective dtype for a layer: the configured choice unless
/// `NMPRUNE_DTYPE` forces one process-wide (applied when operators are
/// *built* — `new`/`from_artifact` — so the run path stays env-free and
/// zero-alloc). Artifacts always record the configured choice, not the
/// forced one.
fn effective_dtype(choice: &LayerChoice) -> Dtype {
    crate::tensor::dtype::forced().unwrap_or(choice.dtype)
}

/// Executor configuration. Pool-aware: instead of a raw `threads`
/// count, the config carries a shared handle to the persistent
/// [`ThreadPool`] every conv GEMM of this executor runs on. Cloning the
/// config (as the server does per batch-size executor) clones the
/// handle, so one pool serves the whole process.
///
/// Per-layer parallelism caps: set `default_choice.threads` to bound
/// every layer, or insert a `LayerChoice` into `per_layer` (keyed by
/// layer name) to override one layer — the tuner's `TuneResult::choice`
/// produces such entries, `threads` included. `threads == 0` means the
/// layer may occupy the whole pool.
#[derive(Clone, Debug)]
pub struct ExecConfig {
    /// Execution path for every conv layer.
    pub path: ConvPath,
    /// Column-wise adaptive sparsity ratio (SparseCnhw path only).
    pub sparsity: f64,
    /// Persistent worker pool for conv GEMMs.
    pub pool: Arc<ThreadPool>,
    /// Fallback micro-kernel parameters.
    pub default_choice: LayerChoice,
    /// Per-layer tuned parameters (layer name → choice).
    pub per_layer: HashMap<String, LayerChoice>,
    /// Weight-generation seed (stand-in for checkpoint loading).
    pub seed: u64,
}

impl ExecConfig {
    pub fn dense_nhwc(pool: Arc<ThreadPool>) -> Self {
        Self {
            path: ConvPath::DenseNhwc,
            sparsity: 0.0,
            pool,
            default_choice: LayerChoice::default(),
            per_layer: HashMap::new(),
            seed: 42,
        }
    }

    pub fn dense_cnhw(pool: Arc<ThreadPool>) -> Self {
        Self {
            path: ConvPath::DenseCnhw,
            ..Self::dense_nhwc(pool)
        }
    }

    pub fn sparse_cnhw(pool: Arc<ThreadPool>, sparsity: f64) -> Self {
        Self {
            path: ConvPath::SparseCnhw,
            sparsity,
            ..Self::dense_nhwc(pool)
        }
    }

    fn choice_for(&self, name: &str) -> LayerChoice {
        self.per_layer
            .get(name)
            .copied()
            .unwrap_or(self.default_choice)
    }
}

enum PreparedConv {
    Nhwc(Conv2dDenseNhwc),
    Cnhw(Conv2dDenseCnhw),
    Sparse(Conv2dSparseCnhw),
}

/// A compiled model: graph + materialised weights + prepared operators.
pub struct Executor {
    pub graph: Graph,
    pub cfg: ExecConfig,
    convs: HashMap<usize, PreparedConv>,
    dw_weights: HashMap<usize, Tensor>,
    fc_params: HashMap<usize, (Tensor, Vec<f32>)>,
    /// For each node, the ids of nodes that consume it (buffer freeing).
    consumers: Vec<usize>,
}

/// FNV-1a of a layer name, mixed into the weight seed so every layer
/// gets distinct deterministic weights.
fn name_hash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic OIHW conv weights for layer `name` (He-style scale
/// keeps activations bounded through deep graphs; pure numerics
/// hygiene — values don't affect timing). Shared by [`Executor::new`]
/// and the seed-derived layers of [`Executor::from_artifact`].
fn make_conv_weight(seed: u64, name: &str, shape: &ConvShape) -> Tensor {
    let mut rng = XorShiftRng::new(seed ^ name_hash(name));
    let scale = (2.0 / shape.k() as f32).sqrt();
    Tensor::from_vec(
        &[shape.c_out, shape.c_in, shape.kh, shape.kw],
        rng.normal_vec(shape.weight_len(), scale),
    )
}

/// Deterministic depthwise weights `[c, k, k]` for layer `name`.
fn make_dw_weight(seed: u64, name: &str, c: usize, k: usize) -> Tensor {
    let mut rng = XorShiftRng::new(seed ^ name_hash(name));
    let scale = (2.0 / (k * k) as f32).sqrt();
    Tensor::from_vec(&[c, k, k], rng.normal_vec(c * k * k, scale))
}

/// Deterministic FC weights `[out, in]` + bias for layer `name`.
fn make_fc_params(seed: u64, name: &str, fin: usize, fout: usize) -> (Tensor, Vec<f32>) {
    let mut rng = XorShiftRng::new(seed ^ name_hash(name));
    let scale = (1.0 / fin as f32).sqrt();
    let w = Tensor::from_vec(&[fout, fin], rng.normal_vec(fin * fout, scale));
    let b = rng.normal_vec(fout, 0.01);
    (w, b)
}

/// Per-node consumer counts (buffer freeing / liveness planning).
fn consumer_counts(graph: &Graph) -> Vec<usize> {
    let mut consumers = vec![0usize; graph.nodes.len()];
    for node in &graph.nodes {
        for &i in &node.inputs {
            consumers[i] += 1;
        }
    }
    consumers
}

impl Executor {
    /// Compile a graph: generate weights and prepare conv operators.
    pub fn new(graph: Graph, cfg: ExecConfig) -> Self {
        let mut convs = HashMap::new();
        let mut dw_weights = HashMap::new();
        let mut fc_params = HashMap::new();
        let mut first_conv_seen = false;
        for node in &graph.nodes {
            match &node.op {
                Op::Conv { shape, .. } => {
                    let w = make_conv_weight(cfg.seed, &node.name, shape);
                    let choice = cfg.choice_for(&node.name);
                    // The paper never prunes the first convolution.
                    let prune_this = cfg.path == ConvPath::SparseCnhw && first_conv_seen;
                    let prepared = match (cfg.path, prune_this) {
                        (ConvPath::DenseNhwc, _) => PreparedConv::Nhwc(
                            Conv2dDenseNhwc::new(*shape, &w).with_thread_cap(choice.threads),
                        ),
                        (_, false) => PreparedConv::Cnhw(
                            Conv2dDenseCnhw::new(*shape, &w, choice.v, choice.tile)
                                .with_thread_cap(choice.threads)
                                .with_kernel(choice.kernel)
                                .with_dtype(effective_dtype(&choice)),
                        ),
                        (_, true) => PreparedConv::Sparse(
                            Conv2dSparseCnhw::new_adaptive(
                                *shape,
                                &w,
                                choice.v,
                                choice.tile,
                                cfg.sparsity,
                            )
                            .with_thread_cap(choice.threads)
                            .with_kernel(choice.kernel)
                            .with_dtype(effective_dtype(&choice)),
                        ),
                    };
                    convs.insert(node.id, prepared);
                    first_conv_seen = true;
                }
                Op::DepthwiseConv { c, k, .. } => {
                    dw_weights.insert(node.id, make_dw_weight(cfg.seed, &node.name, *c, *k));
                }
                Op::Fc {
                    in_features,
                    out_features,
                } => {
                    fc_params.insert(
                        node.id,
                        make_fc_params(cfg.seed, &node.name, *in_features, *out_features),
                    );
                }
                _ => {}
            }
        }
        let consumers = consumer_counts(&graph);
        Self {
            graph,
            cfg,
            convs,
            dw_weights,
            fc_params,
            consumers,
        }
    }

    /// Run inference on an NHWC input `[N, H, W, C]`; returns logits
    /// `[N, classes]`. Activations flow CNHW internally unless the path
    /// is DenseNhwc (the paper's layout policy, §4.1.2).
    pub fn run(&self, input_nhwc: &Tensor) -> Tensor {
        self.run_capped(input_nhwc, 0)
    }

    /// [`Executor::run`] with a per-run parallelism cap (0 = none)
    /// applied on top of every layer's tuned cap — the effective cap per
    /// conv is the min of the two (see [`crate::conv::compose_caps`]).
    /// This is how a load-aware server tightens a batch's pool slice at
    /// dispatch time without recompiling executors or losing per-layer
    /// tuning; caps are pure scheduling and never change numerics.
    pub fn run_capped(&self, input_nhwc: &Tensor, run_cap: usize) -> Tensor {
        let nhwc = self.cfg.path == ConvPath::DenseNhwc;
        let pool = self.cfg.pool.as_ref();
        let mut acts: Vec<Option<Tensor>> = vec![None; self.graph.nodes.len()];
        let mut remaining = self.consumers.clone();
        // §Perf step 4: borrow input activations instead of cloning
        // them (the clones were tens of MB of memcpy per inference).
        fn fetch<'a>(acts: &'a [Option<Tensor>], inputs: &[usize], i: usize) -> &'a Tensor {
            acts[inputs[i]].as_ref().expect("input already freed")
        }
        // Per-node wall-clock trace for profiling (§Perf): set
        // NMPRUNE_TRACE=1 to print layer-by-layer timings to stderr.
        // Shared flag convention: ""/"0"/"false" are off (this site
        // used to test `is_ok()`, so NMPRUNE_TRACE=0 enabled tracing).
        let trace = crate::util::env::flag("NMPRUNE_TRACE");
        for node in &self.graph.nodes {
            let t_node = std::time::Instant::now();
            let out = match &node.op {
                Op::Input { c, h, w } => {
                    assert_eq!(
                        input_nhwc.shape,
                        vec![self.graph.batch, *h, *w, *c],
                        "input must be NHWC [N,H,W,C]"
                    );
                    if nhwc {
                        input_nhwc.clone()
                    } else {
                        nhwc_to_cnhw(input_nhwc)
                    }
                }
                Op::Conv { relu, .. } => {
                    let x = fetch(&acts, &node.inputs, 0);
                    let mut y = match self.convs.get(&node.id).unwrap() {
                        PreparedConv::Nhwc(op) => op.run_capped(x, pool, run_cap),
                        PreparedConv::Cnhw(op) => op.run_capped(x, pool, run_cap),
                        PreparedConv::Sparse(op) => op.run_capped(x, pool, run_cap),
                    };
                    if *relu {
                        ops::relu_inplace(&mut y);
                    }
                    y
                }
                Op::DepthwiseConv {
                    stride, pad, relu, ..
                } => {
                    let x = fetch(&acts, &node.inputs, 0);
                    let w = self.dw_weights.get(&node.id).unwrap();
                    if nhwc {
                        ops::depthwise_nhwc(x, w, *stride, *pad, *relu)
                    } else {
                        ops::depthwise_cnhw(x, w, *stride, *pad, *relu)
                    }
                }
                Op::MaxPool { k, stride, pad } => {
                    let x = fetch(&acts, &node.inputs, 0);
                    if nhwc {
                        ops::maxpool_nhwc(x, *k, *stride, *pad)
                    } else {
                        ops::maxpool_cnhw(x, *k, *stride, *pad)
                    }
                }
                Op::AvgPool { k, stride } => {
                    let x = fetch(&acts, &node.inputs, 0);
                    if nhwc {
                        ops::avgpool_nhwc(x, *k, *stride)
                    } else {
                        ops::avgpool_cnhw(x, *k, *stride)
                    }
                }
                Op::GlobalAvgPool => {
                    let x = fetch(&acts, &node.inputs, 0);
                    if nhwc {
                        ops::gap_nhwc(x)
                    } else {
                        ops::gap_cnhw(x)
                    }
                }
                Op::Add { relu } => {
                    ops::add(fetch(&acts, &node.inputs, 0), fetch(&acts, &node.inputs, 1), *relu)
                }
                Op::Concat => {
                    let refs: Vec<&Tensor> =
                        (0..node.inputs.len()).map(|i| fetch(&acts, &node.inputs, i)).collect();
                    if nhwc {
                        ops::concat_nhwc(&refs)
                    } else {
                        ops::concat_cnhw(&refs)
                    }
                }
                Op::Fc { .. } => {
                    let x = fetch(&acts, &node.inputs, 0);
                    let (w, b) = self.fc_params.get(&node.id).unwrap();
                    ops::fc(x, w, b)
                }
            };
            if trace {
                let dt = t_node.elapsed();
                eprintln!(
                    "[trace] {:<20} {:>8.2} ms  {:?}",
                    node.name,
                    dt.as_secs_f64() * 1e3,
                    std::mem::discriminant(&node.op)
                );
            }
            // Free inputs whose consumers are exhausted (bounds peak
            // memory on DenseNet's long concat chains).
            for &i in &node.inputs {
                remaining[i] -= 1;
                if remaining[i] == 0 {
                    acts[i] = None;
                }
            }
            acts[node.id] = Some(out);
        }
        acts.last_mut().take().unwrap().take().unwrap()
    }

    /// Input resolution the graph was built for (0 if no input node).
    fn input_res(graph: &Graph) -> usize {
        graph
            .nodes
            .iter()
            .find_map(|n| match n.op {
                Op::Input { h, .. } => Some(h),
                _ => None,
            })
            .unwrap_or(0)
    }

    /// Freeze this executor's conv weights and tuning choices into a
    /// packed artifact (the `nmprune pack` writer). Depthwise/FC
    /// parameters are omitted: they are seed-derived and regenerated
    /// identically on load.
    pub fn to_artifact(&self) -> PackedArtifact {
        let mut layers = Vec::new();
        for node in &self.graph.nodes {
            if let Op::Conv { shape, .. } = &node.op {
                let weights = match self.convs.get(&node.id).unwrap() {
                    PreparedConv::Nhwc(op) => LayerWeights::Dense(op.filter().to_vec()),
                    PreparedConv::Cnhw(op) => LayerWeights::Dense(op.filter().to_vec()),
                    PreparedConv::Sparse(op) => LayerWeights::Sparse(op.weights.clone()),
                };
                layers.push(ArtifactLayer {
                    name: node.name.clone(),
                    choice: self.cfg.choice_for(&node.name),
                    shape: *shape,
                    weights,
                });
            }
        }
        PackedArtifact {
            arch: self.graph.name.clone(),
            batch: self.graph.batch,
            res: Self::input_res(&self.graph),
            path: self.cfg.path,
            sparsity: self.cfg.sparsity,
            seed: self.cfg.seed,
            default_choice: self.cfg.default_choice,
            layers,
        }
    }

    /// Build an executor from an AOT-packed artifact: a validation
    /// pass, not a re-pack. Conv weights are taken verbatim from the
    /// artifact (the sparse layers' compressed form is used as stored,
    /// so logits stay bitwise identical to the executor that produced
    /// it); depthwise/FC parameters are regenerated from the recorded
    /// seed. Loading is batch-generic: weights and tuning choices do
    /// not depend on the batch dimension, so one artifact serves every
    /// compiled batch size (`art.batch` records the batch the tuning
    /// ran at). Any other mismatch between artifact and graph — arch,
    /// resolution, layer names, shapes, or weight kind vs path — is a
    /// [`RuntimeError`].
    pub fn from_artifact(
        graph: Graph,
        pool: Arc<ThreadPool>,
        art: &PackedArtifact,
    ) -> crate::runtime::Result<Self> {
        let e = RuntimeError;
        if art.arch != graph.name {
            return Err(e(format!(
                "artifact is for arch {:?}, graph is {:?}",
                art.arch, graph.name
            )));
        }
        let res = Self::input_res(&graph);
        if art.res != res {
            return Err(e(format!("artifact resolution {} != graph input {res}", art.res)));
        }
        let mut cfg = ExecConfig {
            path: art.path,
            sparsity: art.sparsity,
            pool,
            default_choice: art.default_choice,
            per_layer: HashMap::new(),
            seed: art.seed,
        };
        let mut convs = HashMap::new();
        let mut dw_weights = HashMap::new();
        let mut fc_params = HashMap::new();
        let mut li = 0usize;
        for node in &graph.nodes {
            match &node.op {
                Op::Conv { shape, .. } => {
                    let layer = art.layers.get(li).ok_or_else(|| {
                        e(format!(
                            "artifact has only {} conv layers; graph {:?} has more",
                            art.layers.len(),
                            graph.name
                        ))
                    })?;
                    li += 1;
                    if layer.name != node.name {
                        return Err(e(format!(
                            "artifact layer {li} is {:?}, graph expects {:?}",
                            layer.name, node.name
                        )));
                    }
                    // Compare everything except the batch dimension:
                    // the filter (c_out × k) is batch-independent, and
                    // the executor is built with the graph's own shape.
                    let want = ConvShape {
                        n: shape.n,
                        ..layer.shape
                    };
                    if want != *shape {
                        return Err(e(format!(
                            "artifact layer {:?} shape {} != graph {}",
                            layer.name, layer.shape, shape
                        )));
                    }
                    let choice = layer.choice;
                    cfg.per_layer.insert(node.name.clone(), choice);
                    let prepared = match (&layer.weights, art.path) {
                        (LayerWeights::Dense(f), ConvPath::DenseNhwc) => PreparedConv::Nhwc(
                            Conv2dDenseNhwc::from_filter_matrix(*shape, f.clone())
                                .with_thread_cap(choice.threads),
                        ),
                        (LayerWeights::Dense(f), _) => PreparedConv::Cnhw(
                            Conv2dDenseCnhw::from_filter_matrix(
                                *shape,
                                f.clone(),
                                choice.v,
                                choice.tile,
                            )
                            .with_thread_cap(choice.threads)
                            .with_kernel(choice.kernel)
                            .with_dtype(effective_dtype(&choice)),
                        ),
                        (LayerWeights::Sparse(p), ConvPath::SparseCnhw) => PreparedConv::Sparse(
                            Conv2dSparseCnhw::from_pruned(*shape, p.clone(), choice.v)
                                .with_thread_cap(choice.threads)
                                .with_kernel(choice.kernel)
                                .with_dtype(effective_dtype(&choice)),
                        ),
                        (LayerWeights::Sparse(_), _) => {
                            return Err(e(format!(
                                "artifact layer {:?} has sparse weights but the \
                                 artifact path is {:?}",
                                layer.name, art.path
                            )));
                        }
                    };
                    convs.insert(node.id, prepared);
                }
                Op::DepthwiseConv { c, k, .. } => {
                    dw_weights.insert(node.id, make_dw_weight(art.seed, &node.name, *c, *k));
                }
                Op::Fc {
                    in_features,
                    out_features,
                } => {
                    fc_params.insert(
                        node.id,
                        make_fc_params(art.seed, &node.name, *in_features, *out_features),
                    );
                }
                _ => {}
            }
        }
        if li != art.layers.len() {
            return Err(e(format!(
                "artifact has {} conv layers, graph {:?} has {li}",
                art.layers.len(),
                graph.name
            )));
        }
        let consumers = consumer_counts(&graph);
        Ok(Self {
            graph,
            cfg,
            convs,
            dw_weights,
            fc_params,
            consumers,
        })
    }

    /// Static activation-memory plan for this executor's graph and
    /// execution path, including the worst-case conv panel size and the
    /// worst-case i8 staging panel over the layers that run quantized.
    pub fn memory_plan(&self) -> MemoryPlan {
        let nhwc = self.cfg.path == ConvPath::DenseNhwc;
        let mut panel_elems = 0usize;
        let mut qpanel_elems = 0usize;
        if !nhwc {
            for node in &self.graph.nodes {
                if let Op::Conv { shape, .. } = &node.op {
                    let choice = self.cfg.choice_for(&node.name);
                    let strips = shape.gemm_cols().div_ceil(choice.v).max(1);
                    let elems = strips * choice.v * shape.k();
                    panel_elems = panel_elems.max(elems);
                    if effective_dtype(&choice) == Dtype::I8 {
                        qpanel_elems = qpanel_elems.max(elems);
                    }
                }
            }
        }
        MemoryPlan::plan(&self.graph, nhwc, panel_elems, qpanel_elems)
    }

    /// Allocate a scratch arena sized for this executor's plan.
    pub fn scratch(&self) -> ScratchArena {
        ScratchArena::new(self.memory_plan())
    }

    /// [`Executor::run`] inside a preallocated arena (uncapped).
    // nmprune: zero-alloc
    pub fn run_in<'a>(&self, input_nhwc: &Tensor, arena: &'a mut ScratchArena) -> &'a Tensor {
        self.run_capped_in(input_nhwc, 0, arena)
    }

    /// [`Executor::run_capped`] executed entirely inside `arena`'s
    /// preallocated scratch memory: in steady state the compute plane
    /// performs no heap allocation (proven by `rust/tests/zero_alloc.rs`
    /// with a counting global allocator). Logits are bitwise identical
    /// to the allocating path — same kernels in the same order,
    /// different storage. Returns a borrow of the logits slot, valid
    /// until the next run on the same arena.
    ///
    /// Unlike [`Executor::run_capped`] this path never consults
    /// `NMPRUNE_TRACE`: reading an env var allocates a `CString` per
    /// call, which would break the zero-alloc guarantee.
    // nmprune: zero-alloc
    pub fn run_capped_in<'a>(
        &self,
        input_nhwc: &Tensor,
        run_cap: usize,
        arena: &'a mut ScratchArena,
    ) -> &'a Tensor {
        let nhwc = self.cfg.path == ConvPath::DenseNhwc;
        let pool = self.cfg.pool.as_ref();
        assert_eq!(
            arena.plan.node_slot.len(),
            self.graph.nodes.len(),
            "arena was planned for a different graph"
        );
        for node in &self.graph.nodes {
            let oslot = arena.plan.node_slot[node.id];
            // Move the output tensor out of its slot so its buffer can
            // be borrowed mutably alongside shared borrows of the input
            // slots (`Vec::new()` does not allocate). The plan
            // guarantees an output slot never aliases a live input.
            let mut out = std::mem::replace(
                &mut arena.slots[oslot],
                Tensor {
                    shape: Vec::new(), // nmprune-lint: allow(Z1) -- Vec::new is alloc-free
                    data: Vec::new(),
                },
            );
            let plan_shape = &arena.plan.shapes[node.id];
            out.shape.clear();
            out.shape.extend_from_slice(plan_shape);
            // Within preallocated capacity: shrink/regrow, no realloc.
            out.data.resize(plan_shape.iter().product(), 0.0);
            match &node.op {
                Op::Input { c, h, w } => {
                    assert_eq!(
                        input_nhwc.shape,
                        [self.graph.batch, *h, *w, *c],
                        "input must be NHWC [N,H,W,C]"
                    );
                    if nhwc {
                        out.data.copy_from_slice(&input_nhwc.data);
                    } else {
                        nhwc_to_cnhw_into(input_nhwc, &mut out);
                    }
                }
                Op::Conv { relu, .. } => {
                    let x = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    match self.convs.get(&node.id).unwrap() {
                        PreparedConv::Nhwc(op) => {
                            op.run_capped_into(x, pool, run_cap, &mut out)
                        }
                        PreparedConv::Cnhw(op) => op.run_capped_into(
                            x,
                            pool,
                            run_cap,
                            &mut arena.panel,
                            &mut arena.qpanel,
                            &mut out,
                        ),
                        PreparedConv::Sparse(op) => op.run_capped_into(
                            x,
                            pool,
                            run_cap,
                            &mut arena.panel,
                            &mut arena.qpanel,
                            &mut out,
                        ),
                    }
                    if *relu {
                        ops::relu_inplace(&mut out);
                    }
                }
                Op::DepthwiseConv {
                    stride, pad, relu, ..
                } => {
                    let x = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    let w = self.dw_weights.get(&node.id).unwrap();
                    if nhwc {
                        ops::depthwise_nhwc_into(x, w, *stride, *pad, *relu, &mut out);
                    } else {
                        ops::depthwise_cnhw_into(x, w, *stride, *pad, *relu, &mut out);
                    }
                }
                Op::MaxPool { k, stride, pad } => {
                    let x = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    if nhwc {
                        ops::maxpool_nhwc_into(x, *k, *stride, *pad, &mut out);
                    } else {
                        ops::maxpool_cnhw_into(x, *k, *stride, *pad, &mut out);
                    }
                }
                Op::AvgPool { k, stride } => {
                    let x = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    if nhwc {
                        ops::avgpool_nhwc_into(x, *k, *stride, &mut out);
                    } else {
                        ops::avgpool_cnhw_into(x, *k, *stride, &mut out);
                    }
                }
                Op::GlobalAvgPool => {
                    let x = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    if nhwc {
                        ops::gap_nhwc_into(x, &mut out);
                    } else {
                        ops::gap_cnhw_into(x, &mut out);
                    }
                }
                Op::Add { relu } => {
                    let a = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    let b = &arena.slots[arena.plan.node_slot[node.inputs[1]]];
                    ops::add_into(a, b, *relu, &mut out);
                }
                Op::Concat => {
                    // Per-part copies at explicit channel offsets: no
                    // `Vec<&Tensor>` collect on the zero-alloc path.
                    let mut c_off = 0;
                    for &i in &node.inputs {
                        let x = &arena.slots[arena.plan.node_slot[i]];
                        if nhwc {
                            ops::concat_nhwc_part_into(x, c_off, &mut out);
                            c_off += x.shape[3];
                        } else {
                            ops::concat_cnhw_part_into(x, c_off, &mut out);
                            c_off += x.shape[0];
                        }
                    }
                }
                Op::Fc { .. } => {
                    let x = &arena.slots[arena.plan.node_slot[node.inputs[0]]];
                    let (w, b) = self.fc_params.get(&node.id).unwrap();
                    ops::fc_into(x, w, b, &mut out);
                }
            }
            arena.slots[oslot] = out;
        }
        &arena.slots[arena.plan.node_slot[self.graph.nodes.len() - 1]]
    }

    /// Sum of conv weight memory after compression (bytes), for the
    /// memory-footprint comparisons.
    pub fn conv_weight_bytes(&self) -> usize {
        self.convs
            .values()
            .map(|p| match p {
                PreparedConv::Nhwc(op) => op.shape.weight_len() * 4,
                PreparedConv::Cnhw(op) => op.shape.weight_len() * 4,
                PreparedConv::Sparse(op) => op
                    .weights
                    .tiles
                    .iter()
                    .map(|t| t.values.len() * 4 + t.indices.len() * 4)
                    .sum(),
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_model, ModelArch};
    use crate::util::allclose;

    fn input(batch: usize, res: usize, seed: u64) -> Tensor {
        let mut r = XorShiftRng::new(seed);
        Tensor::random(&[batch, res, res, 3], &mut r, 0.0, 1.0)
    }

    #[test]
    fn resnet18_small_runs_all_paths_and_agrees_dense() {
        let res = 32;
        let x = input(1, res, 1);
        let g = build_model(ModelArch::ResNet18, 1, res);
        let e_nhwc = Executor::new(g.clone(), ExecConfig::dense_nhwc(ThreadPool::shared(1)));
        let e_cnhw = Executor::new(g.clone(), ExecConfig::dense_cnhw(ThreadPool::shared(2)));
        let y1 = e_nhwc.run(&x);
        let y2 = e_cnhw.run(&x);
        assert_eq!(y1.shape, vec![1, 1000]);
        // Same weights (same seed), different layouts → same logits.
        assert!(
            allclose(&y1.data, &y2.data, 1e-2, 1e-3),
            "max diff {}",
            crate::util::max_abs_diff(&y1.data, &y2.data)
        );
    }

    #[test]
    fn sparse_path_runs_and_differs_bounded() {
        let res = 32;
        let x = input(1, res, 2);
        let g = build_model(ModelArch::ResNet18, 1, res);
        let dense = Executor::new(g.clone(), ExecConfig::dense_cnhw(ThreadPool::shared(1))).run(&x);
        let sparse =
            Executor::new(g, ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5)).run(&x);
        assert_eq!(sparse.shape, vec![1, 1000]);
        // Pruned logits differ from dense but remain finite.
        assert!(sparse.data.iter().all(|v| v.is_finite()));
        assert!(!allclose(&dense.data, &sparse.data, 1e-6, 1e-6));
    }

    #[test]
    fn sparse_weights_smaller_than_dense() {
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let dense = Executor::new(g.clone(), ExecConfig::dense_cnhw(ThreadPool::shared(1)));
        let sparse = Executor::new(g, ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.75));
        assert!(
            (sparse.conv_weight_bytes() as f64)
                < 0.6 * dense.conv_weight_bytes() as f64,
            "sparse {} dense {}",
            sparse.conv_weight_bytes(),
            dense.conv_weight_bytes()
        );
    }

    #[test]
    fn mobilenet_and_densenet_run_small() {
        let res = 32;
        let x = input(1, res, 3);
        for arch in [ModelArch::MobileNetV2, ModelArch::DenseNet121] {
            let g = build_model(arch, 1, res);
            let y = Executor::new(g, ExecConfig::dense_cnhw(ThreadPool::shared(2))).run(&x);
            assert_eq!(y.shape, vec![1, 1000], "{arch:?}");
            assert!(y.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn batch_two_consistent_with_two_singles() {
        let res = 32;
        let mut r = XorShiftRng::new(7);
        let a = Tensor::random(&[1, res, res, 3], &mut r, 0.0, 1.0);
        let b = Tensor::random(&[1, res, res, 3], &mut r, 0.0, 1.0);
        let mut batched = Tensor::zeros(&[2, res, res, 3]);
        batched.data[..a.data.len()].copy_from_slice(&a.data);
        batched.data[a.data.len()..].copy_from_slice(&b.data);

        let g1 = build_model(ModelArch::ResNet18, 1, res);
        let g2 = build_model(ModelArch::ResNet18, 2, res);
        let e1 = Executor::new(g1, ExecConfig::dense_cnhw(ThreadPool::shared(1)));
        let e2 = Executor::new(g2, ExecConfig::dense_cnhw(ThreadPool::shared(1)));
        let ya = e1.run(&a);
        let yb = e1.run(&b);
        let yab = e2.run(&batched);
        assert!(allclose(&yab.data[..1000], &ya.data, 1e-2, 1e-3));
        assert!(allclose(&yab.data[1000..], &yb.data, 1e-2, 1e-3));
    }

    #[test]
    fn per_layer_choice_applied() {
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let mut cfg = ExecConfig::dense_cnhw(ThreadPool::shared(1));
        cfg.per_layer.insert(
            "s1b0-conv1".into(),
            LayerChoice {
                v: 8,
                tile: 4,
                ..LayerChoice::default()
            },
        );
        let x = input(1, 32, 4);
        let y = Executor::new(g.clone(), cfg).run(&x);
        let y_default =
            Executor::new(g, ExecConfig::dense_cnhw(ThreadPool::shared(1))).run(&x);
        // Tuning changes execution parameters, never numerics.
        assert!(allclose(&y.data, &y_default.data, 1e-4, 1e-5));
    }

    /// A tuned backend choice is part of the per-layer configuration:
    /// every available backend yields logits close to the scalar
    /// oracle's, and the choice survives the artifact roundtrip.
    #[test]
    fn kernel_choice_applied_and_roundtrips() {
        use crate::gemm::kernels::available_ids;
        use crate::runtime::PackedArtifact;
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let x = input(1, 32, 9);
        let run_with_kernel = |kernel: KernelId| {
            let mut cfg = ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5);
            cfg.default_choice.kernel = kernel;
            Executor::new(g.clone(), cfg)
        };
        let want = run_with_kernel(KernelId::Scalar).run(&x);
        for id in available_ids() {
            let e = run_with_kernel(id);
            let y = e.run(&x);
            assert!(
                allclose(&y.data, &want.data, 1e-2, 1e-3),
                "{id} diverged from scalar, max diff {}",
                crate::util::max_abs_diff(&y.data, &want.data)
            );
            // The choice is recorded into the artifact and restored.
            let art = PackedArtifact::decode(&e.to_artifact().encode()).unwrap();
            assert_eq!(art.default_choice.kernel, id);
            let e2 = Executor::from_artifact(g.clone(), ThreadPool::shared(1), &art).unwrap();
            assert_eq!(e2.cfg.default_choice.kernel, id);
            assert_eq!(e2.run(&x).data, y.data, "{id} artifact run diverged");
        }
    }

    /// An i8 dtype choice runs end-to-end on both CNHW paths: logits
    /// stay finite and close to the f32 executor's (the precise
    /// per-element quantization bound is asserted at the GEMM layer and
    /// in the conv fuzz harness), the arena path stays bitwise identical
    /// to the allocating path, and the choice survives the artifact
    /// roundtrip bitwise.
    #[test]
    fn i8_dtype_runs_end_to_end_and_roundtrips() {
        use crate::runtime::PackedArtifact;
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let x = input(1, 32, 21);
        let cfgs = [
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
        ];
        for mut cfg in cfgs {
            let path = cfg.path;
            let f32_logits = Executor::new(g.clone(), cfg.clone()).run(&x);
            cfg.default_choice.dtype = Dtype::I8;
            let e = Executor::new(g.clone(), cfg);
            let y = e.run(&x);
            assert!(y.data.iter().all(|v| v.is_finite()), "{path:?}");
            // The i8 path actually engaged (quantization perturbs
            // *something*) yet stays coarsely close to f32.
            assert_ne!(y.data, f32_logits.data, "{path:?} i8 ran as f32");
            assert!(
                allclose(&y.data, &f32_logits.data, 0.0, 2.0),
                "{path:?} i8 diverged, max diff {}",
                crate::util::max_abs_diff(&y.data, &f32_logits.data)
            );
            // The arena path is bitwise identical to the allocating one.
            let mut arena = e.scratch();
            assert_eq!(e.run_in(&x, &mut arena).data, y.data, "{path:?} arena diverged");
            // Dtype rides the artifact: re-quantizing the stored f32
            // master weights on load is deterministic, so logits stay
            // bitwise across the roundtrip.
            let art = PackedArtifact::decode(&e.to_artifact().encode()).unwrap();
            assert_eq!(art.default_choice.dtype, Dtype::I8);
            let e2 = Executor::from_artifact(g.clone(), ThreadPool::shared(1), &art).unwrap();
            assert_eq!(e2.run(&x).data, y.data, "{path:?} artifact run diverged");
        }
    }

    /// Per-run caps (the adaptive server's dispatch-time knob) compose
    /// with per-layer tuned caps as a min and never change numerics:
    /// every composition is bitwise equal to the uncapped run.
    #[test]
    fn per_run_cap_composes_with_layer_caps_bitwise() {
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let x = input(1, 32, 6);
        let mut cfg = ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5);
        cfg.default_choice.threads = 3;
        let e = Executor::new(g, cfg);
        let base = e.run(&x);
        for run_cap in [0usize, 1, 2, 4, 9] {
            assert_eq!(
                e.run_capped(&x, run_cap).data,
                base.data,
                "run cap {run_cap} changed numerics"
            );
        }
    }

    /// The arena path must be bitwise identical to the allocating path
    /// on every architecture and execution path, including when one
    /// arena is reused across runs (stale values must never leak).
    #[test]
    fn arena_run_bitwise_matches_allocating_run() {
        let res = 32;
        let x = input(1, res, 11);
        for arch in [ModelArch::ResNet18, ModelArch::MobileNetV2, ModelArch::DenseNet121] {
            let g = build_model(arch, 1, res);
            let cfgs = [
                ExecConfig::dense_nhwc(ThreadPool::shared(2)),
                ExecConfig::dense_cnhw(ThreadPool::shared(2)),
                ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
            ];
            for cfg in cfgs {
                let path = cfg.path;
                let e = Executor::new(g.clone(), cfg);
                let want = e.run(&x);
                let mut arena = e.scratch();
                for round in 0..3 {
                    let got = e.run_in(&x, &mut arena);
                    assert_eq!(
                        got.data, want.data,
                        "{arch:?} {path:?} round {round} diverged"
                    );
                }
            }
        }
    }

    /// Per-run caps compose identically inside the arena path.
    #[test]
    fn arena_run_caps_bitwise_equal_uncapped() {
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let x = input(1, 32, 13);
        let e = Executor::new(g, ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5));
        let base = e.run(&x);
        let mut arena = e.scratch();
        for run_cap in [0usize, 1, 2, 4] {
            assert_eq!(
                e.run_capped_in(&x, run_cap, &mut arena).data,
                base.data,
                "run cap {run_cap} changed numerics in the arena path"
            );
        }
    }

    /// Executor → artifact → executor must preserve logits bitwise on
    /// every path: loading is a validation pass, not a re-pack.
    #[test]
    fn artifact_roundtrip_preserves_logits_bitwise() {
        use crate::runtime::PackedArtifact;
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let x = input(1, 32, 12);
        let cfgs = [
            ExecConfig::dense_nhwc(ThreadPool::shared(1)),
            ExecConfig::dense_cnhw(ThreadPool::shared(1)),
            ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
        ];
        for cfg in cfgs {
            let path = cfg.path;
            let e = Executor::new(g.clone(), cfg);
            let want = e.run(&x);
            // Through the full binary encode/decode, not just memory.
            let art = PackedArtifact::decode(&e.to_artifact().encode()).unwrap();
            let e2 = Executor::from_artifact(g.clone(), ThreadPool::shared(2), &art).unwrap();
            assert_eq!(e2.run(&x).data, want.data, "{path:?} artifact run diverged");
            let mut arena = e2.scratch();
            assert_eq!(
                e2.run_in(&x, &mut arena).data,
                want.data,
                "{path:?} artifact arena run diverged"
            );
        }
    }

    /// Loading an artifact into the wrong graph must error, not panic.
    #[test]
    fn from_artifact_rejects_mismatched_graph() {
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let e = Executor::new(g.clone(), ExecConfig::dense_cnhw(ThreadPool::shared(1)));
        let art = e.to_artifact();
        // Wrong architecture.
        let g2 = build_model(ModelArch::MobileNetV2, 1, 32);
        let err = Executor::from_artifact(g2, ThreadPool::shared(1), &art).unwrap_err();
        assert!(err.to_string().contains("arch"), "{err}");
        // A *different batch* is not a mismatch: weights are
        // batch-independent, so one artifact serves every compiled
        // batch size — and bitwise so (per-sample logits don't depend
        // on batch packing).
        let gb = build_model(ModelArch::ResNet18, 2, 32);
        let eb = Executor::from_artifact(gb, ThreadPool::shared(1), &art).expect("batch-generic");
        let x1 = input(1, 32, 77);
        let mut x2 = input(2, 32, 0);
        x2.data[..x1.data.len()].copy_from_slice(&x1.data);
        x2.data[x1.data.len()..].copy_from_slice(&x1.data);
        let want = Executor::from_artifact(
            build_model(ModelArch::ResNet18, 1, 32),
            ThreadPool::shared(1),
            &art,
        )
        .unwrap()
        .run(&x1);
        let got = eb.run(&x2);
        assert_eq!(&got.data[..1000], &want.data[..], "row 0");
        assert_eq!(&got.data[1000..], &want.data[..], "row 1");
        // Wrong resolution.
        let gr = build_model(ModelArch::ResNet18, 1, 64);
        let err = Executor::from_artifact(gr, ThreadPool::shared(1), &art).unwrap_err();
        assert!(err.to_string().contains("resolution"), "{err}");
        // The matching graph still loads.
        assert!(Executor::from_artifact(g, ThreadPool::shared(1), &art).is_ok());
    }

    #[test]
    fn per_layer_thread_caps_bitwise_equal_uncapped() {
        // Per-layer parallelism caps are a scheduling decision only:
        // the same graph with every layer capped to 1, capped to 2, or
        // uncapped on a 4-worker pool produces identical logits.
        let g = build_model(ModelArch::ResNet18, 1, 32);
        let x = input(1, 32, 5);
        let run_with_cap = |threads: usize| {
            let mut cfg = ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5);
            cfg.default_choice.threads = threads;
            Executor::new(g.clone(), cfg).run(&x)
        };
        let uncapped = run_with_cap(0);
        for cap in [1usize, 2, 4, 9] {
            assert_eq!(
                run_with_cap(cap).data,
                uncapped.data,
                "cap {cap} changed numerics"
            );
        }
    }
}
