//! Non-conv operator implementations for both activation layouts.
//!
//! CNHW tensors are `[C, N, H, W]`, NHWC tensors `[N, H, W, C]`.
//! Pooling/GAP/FC/depthwise are direct implementations — they are a few
//! percent of runtime in all seven networks, so clarity wins; conv is
//! where the paper's optimisations (and ours) live.
//!
//! These ops run serially on the calling thread and deliberately take
//! no pool handle or parallelism cap: they sit below the dispatch
//! break-even the per-layer thread-cap tuning exists to avoid, so
//! parallelising them would re-create exactly the small-kernel
//! oversubscription the capped scheduler removes from the conv path.
//!
//! Every op has an `_into` twin writing into a caller-provided tensor
//! of the correct output shape — the zero-alloc path the executor's
//! [`super::scratch::ScratchArena`] drives. The allocating versions are
//! thin wrappers (zeros + `_into`), so both paths share one kernel body
//! and stay bitwise identical by construction.

use crate::tensor::Tensor;

/// In-place ReLU.
pub fn relu_inplace(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Elementwise add (same shape), optionally fused ReLU.
pub fn add(a: &Tensor, b: &Tensor, relu: bool) -> Tensor {
    let mut out = Tensor::zeros(&a.shape);
    add_into(a, b, relu, &mut out);
    out
}

/// [`add`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn add_into(a: &Tensor, b: &Tensor, relu: bool, out: &mut Tensor) {
    assert_eq!(a.shape, b.shape, "residual add shape mismatch");
    assert_eq!(out.shape, a.shape, "output tensor shape");
    for ((o, &av), &bv) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
        *o = av + bv;
        if relu && *o < 0.0 {
            *o = 0.0;
        }
    }
}

/// Max pooling over CNHW.
pub fn maxpool_cnhw(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[c, n, ho, wo]);
    maxpool_cnhw_into(x, k, stride, pad, &mut out);
    out
}

/// [`maxpool_cnhw`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn maxpool_cnhw_into(x: &Tensor, k: usize, stride: usize, pad: usize, out: &mut Tensor) {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    assert_eq!(out.shape, [c, n, ho, wo], "output tensor shape");
    // Flat-offset inner loops (§Perf step 5: `Tensor::at` index math per
    // element made the stem pool the single slowest op in the graph).
    for ci in 0..c {
        for ni in 0..n {
            let in_base = (ci * n + ni) * h * w;
            let out_base = (ci * n + ni) * ho * wo;
            for oy in 0..ho {
                let orow = &mut out.data[out_base + oy * wo..out_base + (oy + 1) * wo];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let irow = &x.data[in_base + iy as usize * w..in_base + (iy as usize + 1) * w];
                    for (ox, o) in orow.iter_mut().enumerate() {
                        let ix0 = (ox * stride) as isize - pad as isize;
                        let lo = ix0.max(0) as usize;
                        let hi = ((ix0 + k as isize).min(w as isize)) as usize;
                        for &v in &irow[lo..hi] {
                            if v > *o {
                                *o = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Average pooling (no padding) over CNHW.
pub fn avgpool_cnhw(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    let mut out = Tensor::zeros(&[c, n, ho, wo]);
    avgpool_cnhw_into(x, k, stride, &mut out);
    out
}

/// [`avgpool_cnhw`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn avgpool_cnhw_into(x: &Tensor, k: usize, stride: usize, out: &mut Tensor) {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    assert_eq!(out.shape, [c, n, ho, wo], "output tensor shape");
    let inv = 1.0 / (k * k) as f32;
    for ci in 0..c {
        for ni in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut sum = 0.0;
                    for ky in 0..k {
                        for kx in 0..k {
                            sum += x.at(&[ci, ni, oy * stride + ky, ox * stride + kx]);
                        }
                    }
                    *out.at_mut(&[ci, ni, oy, ox]) = sum * inv;
                }
            }
        }
    }
}

/// Global average pool CNHW → `[N, C]`.
pub fn gap_cnhw(x: &Tensor) -> Tensor {
    let (n, c) = (x.shape[1], x.shape[0]);
    let mut out = Tensor::zeros(&[n, c]);
    gap_cnhw_into(x, &mut out);
    out
}

/// [`gap_cnhw`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn gap_cnhw_into(x: &Tensor, out: &mut Tensor) {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(out.shape, [n, c], "output tensor shape");
    let inv = 1.0 / (h * w) as f32;
    for ci in 0..c {
        for ni in 0..n {
            let base = ((ci * n + ni) * h) * w;
            let sum: f32 = x.data[base..base + h * w].iter().sum();
            *out.at_mut(&[ni, ci]) = sum * inv;
        }
    }
}

/// Depthwise k×k conv over CNHW; weights `[C, k, k]`.
pub fn depthwise_cnhw(x: &Tensor, wt: &Tensor, stride: usize, pad: usize, relu: bool) -> Tensor {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let k = wt.shape[1];
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[c, n, ho, wo]);
    depthwise_cnhw_into(x, wt, stride, pad, relu, &mut out);
    out
}

/// [`depthwise_cnhw`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn depthwise_cnhw_into(
    x: &Tensor,
    wt: &Tensor,
    stride: usize,
    pad: usize,
    relu: bool,
    out: &mut Tensor,
) {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let k = wt.shape[1];
    assert_eq!(wt.shape, [c, k, k]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    assert_eq!(out.shape, [c, n, ho, wo], "output tensor shape");
    for ci in 0..c {
        for ni in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc += x.at(&[ci, ni, iy as usize, ix as usize])
                                * wt.at(&[ci, ky, kx]);
                        }
                    }
                    if relu && acc < 0.0 {
                        acc = 0.0;
                    }
                    *out.at_mut(&[ci, ni, oy, ox]) = acc;
                }
            }
        }
    }
}

/// Channel concat in CNHW: channels are the outermost axis, so this is
/// a plain buffer concatenation — one of CNHW's conveniences.
pub fn concat_cnhw(xs: &[&Tensor]) -> Tensor {
    assert!(!xs.is_empty());
    let (n, h, w) = (xs[0].shape[1], xs[0].shape[2], xs[0].shape[3]);
    let c_total: usize = xs.iter().map(|x| x.shape[0]).sum();
    let mut out = Tensor::zeros(&[c_total, n, h, w]);
    concat_cnhw_into(xs, &mut out);
    out
}

/// [`concat_cnhw`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn concat_cnhw_into(xs: &[&Tensor], out: &mut Tensor) {
    assert!(!xs.is_empty());
    let (n, h, w) = (xs[0].shape[1], xs[0].shape[2], xs[0].shape[3]);
    let c_total: usize = xs.iter().map(|x| x.shape[0]).sum();
    assert_eq!(out.shape, [c_total, n, h, w], "output tensor shape");
    let mut off = 0;
    for x in xs {
        assert_eq!(&x.shape[1..], &[n, h, w], "concat spatial mismatch");
        out.data[off..off + x.data.len()].copy_from_slice(&x.data);
        off += x.data.len();
    }
}

/// Copy one CNHW concat input into `out` at channel offset `c_off`.
/// Per-part form so the arena executor can concatenate without
/// collecting a `Vec<&Tensor>` per run (that collect is a heap
/// allocation on the zero-alloc path).
// nmprune: zero-alloc
pub fn concat_cnhw_part_into(x: &Tensor, c_off: usize, out: &mut Tensor) {
    let (c, n, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(&out.shape[1..], &[n, h, w], "concat spatial mismatch");
    assert!(c_off + c <= out.shape[0], "concat channel overflow");
    let off = c_off * n * h * w;
    out.data[off..off + x.data.len()].copy_from_slice(&x.data);
}

/// Fully connected: `x[N, in] · W[out, in]ᵀ + b[out]` → `[N, out]`.
pub fn fc(x: &Tensor, wt: &Tensor, bias: &[f32]) -> Tensor {
    let mut out = Tensor::zeros(&[x.shape[0], wt.shape[0]]);
    fc_into(x, wt, bias, &mut out);
    out
}

/// [`fc`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn fc_into(x: &Tensor, wt: &Tensor, bias: &[f32], out: &mut Tensor) {
    let (n, fin) = (x.shape[0], x.shape[1]);
    let fout = wt.shape[0];
    assert_eq!(wt.shape, [fout, fin]);
    assert_eq!(bias.len(), fout);
    assert_eq!(out.shape, [n, fout], "output tensor shape");
    for ni in 0..n {
        for o in 0..fout {
            let mut acc = bias[o];
            let xr = &x.data[ni * fin..(ni + 1) * fin];
            let wr = &wt.data[o * fin..(o + 1) * fin];
            for (xv, wv) in xr.iter().zip(wr) {
                acc += xv * wv;
            }
            *out.at_mut(&[ni, o]) = acc;
        }
    }
}

// ---------------------------------------------------------------------
// NHWC twins (dense-NHWC baseline path)

/// Max pooling over NHWC.
pub fn maxpool_nhwc(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, ho, wo, c]);
    maxpool_nhwc_into(x, k, stride, pad, &mut out);
    out
}

/// [`maxpool_nhwc`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn maxpool_nhwc_into(x: &Tensor, k: usize, stride: usize, pad: usize, out: &mut Tensor) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    assert_eq!(out.shape, [n, ho, wo, c], "output tensor shape");
    // Flat-offset channel-vector inner loop (§Perf step 5, NHWC twin —
    // the baseline gets the same treatment for a fair comparison).
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let out_base = ((ni * ho + oy) * wo + ox) * c;
                let orow = &mut out.data[out_base..out_base + c];
                orow.fill(f32::NEG_INFINITY);
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let in_base = ((ni * h + iy as usize) * w + ix as usize) * c;
                        let irow = &x.data[in_base..in_base + c];
                        for (o, &v) in orow.iter_mut().zip(irow) {
                            if v > *o {
                                *o = v;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Average pooling (no padding) over NHWC.
pub fn avgpool_nhwc(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, ho, wo, c]);
    avgpool_nhwc_into(x, k, stride, &mut out);
    out
}

/// [`avgpool_nhwc`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn avgpool_nhwc_into(x: &Tensor, k: usize, stride: usize, out: &mut Tensor) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    assert_eq!(out.shape, [n, ho, wo, c], "output tensor shape");
    let inv = 1.0 / (k * k) as f32;
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ci in 0..c {
                    let mut sum = 0.0;
                    for ky in 0..k {
                        for kx in 0..k {
                            sum += x.at(&[ni, oy * stride + ky, ox * stride + kx, ci]);
                        }
                    }
                    *out.at_mut(&[ni, oy, ox, ci]) = sum * inv;
                }
            }
        }
    }
}

/// Global average pool NHWC → `[N, C]`.
pub fn gap_nhwc(x: &Tensor) -> Tensor {
    let (n, c) = (x.shape[0], x.shape[3]);
    let mut out = Tensor::zeros(&[n, c]);
    gap_nhwc_into(x, &mut out);
    out
}

/// [`gap_nhwc`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn gap_nhwc_into(x: &Tensor, out: &mut Tensor) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(out.shape, [n, c], "output tensor shape");
    // Accumulating op: clear the (possibly reused) output first.
    out.data.fill(0.0);
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for y in 0..h {
            for xw in 0..w {
                for ci in 0..c {
                    out.data[ni * c + ci] += x.at(&[ni, y, xw, ci]);
                }
            }
        }
    }
    for v in &mut out.data {
        *v *= inv;
    }
}

/// Depthwise conv over NHWC; weights `[C, k, k]`.
pub fn depthwise_nhwc(x: &Tensor, wt: &Tensor, stride: usize, pad: usize, relu: bool) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let k = wt.shape[1];
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, ho, wo, c]);
    depthwise_nhwc_into(x, wt, stride, pad, relu, &mut out);
    out
}

/// [`depthwise_nhwc`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn depthwise_nhwc_into(
    x: &Tensor,
    wt: &Tensor,
    stride: usize,
    pad: usize,
    relu: bool,
    out: &mut Tensor,
) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let k = wt.shape[1];
    assert_eq!(wt.shape, [c, k, k]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    assert_eq!(out.shape, [n, ho, wo, c], "output tensor shape");
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ci in 0..c {
                    let mut acc = 0.0;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            acc += x.at(&[ni, iy as usize, ix as usize, ci])
                                * wt.at(&[ci, ky, kx]);
                        }
                    }
                    if relu && acc < 0.0 {
                        acc = 0.0;
                    }
                    *out.at_mut(&[ni, oy, ox, ci]) = acc;
                }
            }
        }
    }
}

/// Channel concat in NHWC (innermost axis — requires interleaving).
pub fn concat_nhwc(xs: &[&Tensor]) -> Tensor {
    assert!(!xs.is_empty());
    let (n, h, w) = (xs[0].shape[0], xs[0].shape[1], xs[0].shape[2]);
    let c_total: usize = xs.iter().map(|x| x.shape[3]).sum();
    let mut out = Tensor::zeros(&[n, h, w, c_total]);
    concat_nhwc_into(xs, &mut out);
    out
}

/// [`concat_nhwc`] into a caller-provided output tensor.
// nmprune: zero-alloc
pub fn concat_nhwc_into(xs: &[&Tensor], out: &mut Tensor) {
    assert!(!xs.is_empty());
    let (n, h, w) = (xs[0].shape[0], xs[0].shape[1], xs[0].shape[2]);
    let c_total: usize = xs.iter().map(|x| x.shape[3]).sum();
    assert_eq!(out.shape, [n, h, w, c_total], "output tensor shape");
    let pixels = n * h * w;
    for p in 0..pixels {
        let mut co = 0;
        for x in xs {
            let c = x.shape[3];
            out.data[p * c_total + co..p * c_total + co + c]
                .copy_from_slice(&x.data[p * c..(p + 1) * c]);
            co += c;
        }
    }
}

/// Copy one NHWC concat input into `out` at channel offset `c_off`
/// (per-part twin of [`concat_cnhw_part_into`] for the arena executor).
// nmprune: zero-alloc
pub fn concat_nhwc_part_into(x: &Tensor, c_off: usize, out: &mut Tensor) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(&out.shape[..3], &[n, h, w], "concat spatial mismatch");
    let c_total = out.shape[3];
    assert!(c_off + c <= c_total, "concat channel overflow");
    for p in 0..n * h * w {
        out.data[p * c_total + c_off..p * c_total + c_off + c]
            .copy_from_slice(&x.data[p * c..(p + 1) * c]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::layout::{cnhw_to_nhwc, nhwc_to_cnhw};
    use crate::util::{allclose, XorShiftRng};

    #[test]
    fn relu_and_add() {
        let mut t = Tensor::from_vec(&[4], vec![-1.0, 2.0, -3.0, 4.0]);
        relu_inplace(&mut t);
        assert_eq!(t.data, vec![0.0, 2.0, 0.0, 4.0]);
        let s = add(&t, &t, false);
        assert_eq!(s.data, vec![0.0, 4.0, 0.0, 8.0]);
        let neg = Tensor::from_vec(&[4], vec![-5.0; 4]);
        let r = add(&t, &neg, true);
        assert_eq!(r.data, vec![0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn maxpool_cnhw_basic() {
        // 1 channel, 1 image, 4x4 ramp; 2x2/2 pool takes max of quads.
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let y = maxpool_cnhw(&x, 2, 2, 0);
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn maxpool_layout_twins_agree() {
        let mut r = XorShiftRng::new(301);
        let x_nhwc = Tensor::random(&[2, 7, 7, 5], &mut r, -1.0, 1.0);
        let a = maxpool_nhwc(&x_nhwc, 3, 2, 1);
        let b = maxpool_cnhw(&nhwc_to_cnhw(&x_nhwc), 3, 2, 1);
        assert!(allclose(&a.data, &cnhw_to_nhwc(&b).data, 0.0, 0.0));
    }

    #[test]
    fn avgpool_layout_twins_agree() {
        let mut r = XorShiftRng::new(302);
        let x_nhwc = Tensor::random(&[1, 6, 6, 4], &mut r, -1.0, 1.0);
        let a = avgpool_nhwc(&x_nhwc, 2, 2);
        let b = avgpool_cnhw(&nhwc_to_cnhw(&x_nhwc), 2, 2);
        assert!(allclose(&a.data, &cnhw_to_nhwc(&b).data, 1e-6, 1e-7));
    }

    #[test]
    fn gap_twins_agree_and_average() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 6.0]);
        let g = gap_cnhw(&x);
        assert_eq!(g.shape, vec![1, 1]);
        assert_eq!(g.data, vec![3.0]);
        let mut r = XorShiftRng::new(303);
        let x_nhwc = Tensor::random(&[3, 5, 4, 6], &mut r, -1.0, 1.0);
        let a = gap_nhwc(&x_nhwc);
        let b = gap_cnhw(&nhwc_to_cnhw(&x_nhwc));
        assert!(allclose(&a.data, &b.data, 1e-5, 1e-6));
    }

    #[test]
    fn depthwise_twins_agree() {
        let mut r = XorShiftRng::new(304);
        let x_nhwc = Tensor::random(&[2, 8, 8, 6], &mut r, -1.0, 1.0);
        let w = Tensor::random(&[6, 3, 3], &mut r, -0.5, 0.5);
        let a = depthwise_nhwc(&x_nhwc, &w, 2, 1, true);
        let b = depthwise_cnhw(&nhwc_to_cnhw(&x_nhwc), &w, 2, 1, true);
        assert!(allclose(&a.data, &cnhw_to_nhwc(&b).data, 1e-5, 1e-6));
    }

    #[test]
    fn depthwise_identity_kernel() {
        // 1x1 depthwise with weight 1.0 is identity.
        let mut r = XorShiftRng::new(305);
        let x = Tensor::random(&[3, 1, 4, 4], &mut r, -1.0, 1.0);
        let w = Tensor::from_vec(&[3, 1, 1], vec![1.0; 3]);
        let y = depthwise_cnhw(&x, &w, 1, 0, false);
        assert!(allclose(&x.data, &y.data, 0.0, 0.0));
    }

    #[test]
    fn concat_twins_agree() {
        let mut r = XorShiftRng::new(306);
        let a_nhwc = Tensor::random(&[2, 3, 3, 4], &mut r, -1.0, 1.0);
        let b_nhwc = Tensor::random(&[2, 3, 3, 6], &mut r, -1.0, 1.0);
        let cat_nhwc = concat_nhwc(&[&a_nhwc, &b_nhwc]);
        let cat_cnhw = concat_cnhw(&[&nhwc_to_cnhw(&a_nhwc), &nhwc_to_cnhw(&b_nhwc)]);
        assert_eq!(cat_nhwc.shape, vec![2, 3, 3, 10]);
        assert!(allclose(&cat_nhwc.data, &cnhw_to_nhwc(&cat_cnhw).data, 0.0, 0.0));
    }

    /// Concatenating part-by-part at explicit channel offsets (the
    /// arena executor's allocation-free form) must reproduce the
    /// slice-of-refs concat bitwise in both layouts.
    #[test]
    fn concat_part_into_matches_whole_concat() {
        let mut r = XorShiftRng::new(307);
        let a_nhwc = Tensor::random(&[2, 3, 3, 4], &mut r, -1.0, 1.0);
        let b_nhwc = Tensor::random(&[2, 3, 3, 6], &mut r, -1.0, 1.0);
        let want_nhwc = concat_nhwc(&[&a_nhwc, &b_nhwc]);
        let mut got_nhwc = Tensor::zeros(&[2, 3, 3, 10]);
        got_nhwc.data.fill(f32::NAN);
        concat_nhwc_part_into(&a_nhwc, 0, &mut got_nhwc);
        concat_nhwc_part_into(&b_nhwc, 4, &mut got_nhwc);
        assert_eq!(got_nhwc.data, want_nhwc.data);

        let (a, b) = (nhwc_to_cnhw(&a_nhwc), nhwc_to_cnhw(&b_nhwc));
        let want = concat_cnhw(&[&a, &b]);
        let mut got = Tensor::zeros(&[10, 2, 3, 3]);
        got.data.fill(f32::NAN);
        concat_cnhw_part_into(&a, 0, &mut got);
        concat_cnhw_part_into(&b, 4, &mut got);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn fc_computes_affine() {
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
        let y = fc(&x, &w, &[10.0, 20.0]);
        assert_eq!(y.data, vec![11.0, 25.0]);
    }

    /// `_into` twins must overwrite a dirty reused buffer completely:
    /// stale values from a previous occupant of the arena slot must
    /// never leak into the output (the accumulating ops zero first).
    #[test]
    fn into_variants_overwrite_dirty_buffers_bitwise() {
        let mut r = XorShiftRng::new(307);
        let x = Tensor::random(&[5, 2, 7, 7], &mut r, -1.0, 1.0); // CNHW
        let x_nhwc = cnhw_to_nhwc(&x);
        let wdw = Tensor::random(&[5, 3, 3], &mut r, -0.5, 0.5);
        let wfc = Tensor::random(&[4, 5], &mut r, -0.5, 0.5);
        let bias = vec![0.1f32; 4];
        let dirty = |shape: &[usize]| {
            let mut t = Tensor::zeros(shape);
            t.data.fill(f32::NAN);
            t
        };
        // (want, got) pairs across every op family.
        let checks: Vec<(Tensor, Tensor)> = {
            let mut v = Vec::new();
            let want = maxpool_cnhw(&x, 3, 2, 1);
            let mut got = dirty(&want.shape);
            maxpool_cnhw_into(&x, 3, 2, 1, &mut got);
            v.push((want, got));
            let want = avgpool_cnhw(&x, 2, 2);
            let mut got = dirty(&want.shape);
            avgpool_cnhw_into(&x, 2, 2, &mut got);
            v.push((want, got));
            let want = gap_cnhw(&x);
            let mut got = dirty(&want.shape);
            gap_cnhw_into(&x, &mut got);
            v.push((want, got));
            let want = depthwise_cnhw(&x, &wdw, 2, 1, true);
            let mut got = dirty(&want.shape);
            depthwise_cnhw_into(&x, &wdw, 2, 1, true, &mut got);
            v.push((want, got));
            let want = concat_cnhw(&[&x, &x]);
            let mut got = dirty(&want.shape);
            concat_cnhw_into(&[&x, &x], &mut got);
            v.push((want, got));
            let want = add(&x, &x, true);
            let mut got = dirty(&want.shape);
            add_into(&x, &x, true, &mut got);
            v.push((want, got));
            let gap = gap_cnhw(&x);
            let want = fc(&gap, &wfc, &bias);
            let mut got = dirty(&want.shape);
            fc_into(&gap, &wfc, &bias, &mut got);
            v.push((want, got));
            let want = maxpool_nhwc(&x_nhwc, 3, 2, 1);
            let mut got = dirty(&want.shape);
            maxpool_nhwc_into(&x_nhwc, 3, 2, 1, &mut got);
            v.push((want, got));
            let want = avgpool_nhwc(&x_nhwc, 2, 2);
            let mut got = dirty(&want.shape);
            avgpool_nhwc_into(&x_nhwc, 2, 2, &mut got);
            v.push((want, got));
            let want = gap_nhwc(&x_nhwc);
            let mut got = dirty(&want.shape);
            gap_nhwc_into(&x_nhwc, &mut got);
            v.push((want, got));
            let want = depthwise_nhwc(&x_nhwc, &wdw, 2, 1, false);
            let mut got = dirty(&want.shape);
            depthwise_nhwc_into(&x_nhwc, &wdw, 2, 1, false, &mut got);
            v.push((want, got));
            let want = concat_nhwc(&[&x_nhwc, &x_nhwc]);
            let mut got = dirty(&want.shape);
            concat_nhwc_into(&[&x_nhwc, &x_nhwc], &mut got);
            v.push((want, got));
            v
        };
        for (i, (want, got)) in checks.iter().enumerate() {
            assert_eq!(want.data, got.data, "op family {i} leaked stale data");
        }
    }
}
