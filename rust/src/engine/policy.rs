//! Pure, clock-free serving policies.
//!
//! Every scheduling decision the server makes per drain — traffic-class
//! ordering, starvation promotion, **batch size**, per-run thread cap,
//! and the number of actively draining dispatchers — lives here as a
//! pure function of an explicit [`QueueSnapshot`]. The threaded server
//! (`engine::server`) is a thin shell that assembles snapshots from its
//! intake queue and gauge; the policies themselves never read a clock,
//! never touch a thread, and are therefore unit-testable with virtual
//! time (a `Duration` in a snapshot is just a value).
//!
//! Decisions are pure scheduling: none of them may change numerics.
//! Logits stay bitwise identical between FIFO and priority/deadline
//! modes (`rust/tests/server_load.rs` enforces this end to end).

use std::time::Duration;

/// Traffic class of a request. `Interactive` outranks `Batch` in the
/// intake ordering (priority, then deadline, then FIFO); the `Batch`
/// class is protected from starvation by [`promote_background`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground traffic; served first.
    Interactive,
    /// Throughput-oriented background traffic; served when no
    /// interactive work is queued, or when starvation protection
    /// promotes it.
    Batch,
}

impl Priority {
    /// Dense index for per-class stats arrays.
    pub const COUNT: usize = 2;

    /// This class's slot in `[_; Priority::COUNT]` stats arrays.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    /// Human-readable class label for tables and trace lines.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Every class, in [`Priority::index`] order.
    pub const ALL: [Priority; Self::COUNT] = [Priority::Interactive, Priority::Batch];
}

/// How the intake queue orders requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Submission order only; classes and deadlines are recorded for
    /// stats but ignored for scheduling. The baseline every priority
    /// run is compared against (bitwise, for logits).
    Fifo,
    /// (priority, deadline, FIFO) ordering with starvation protection
    /// for the background class.
    Priority,
}

/// Static inputs of every policy decision, fixed at server start.
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Compiled batch sizes, ascending and non-empty.
    pub batch_sizes: Vec<usize>,
    /// Dispatcher (batch executor) thread count.
    pub n_exec: usize,
    /// Worker count of the shared compute pool.
    pub pool_size: usize,
    /// A queued background request older than this is served before
    /// interactive traffic (starvation protection).
    pub starvation_limit: Duration,
    /// Head-of-queue deadline slack below which the drain optimises for
    /// latency: smallest compiled batch, no window fill.
    pub slack_floor: Duration,
}

impl PolicyConfig {
    /// Largest compiled batch size.
    #[inline]
    pub fn max_batch(&self) -> usize {
        self.batch_sizes.last().copied().unwrap_or(1).max(1)
    }

    /// Smallest compiled batch size.
    #[inline]
    pub fn min_batch(&self) -> usize {
        self.batch_sizes.first().copied().unwrap_or(1).max(1)
    }
}

/// Point-in-time view of the intake queue and the dispatcher fleet —
/// everything a policy may look at. Built by the server under the
/// intake lock; built literally (virtual time) by the policy tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueSnapshot {
    /// Requests queued (submitted, not yet drained into a batch).
    pub depth: usize,
    /// Dispatchers currently computing a batch (excluding the caller).
    pub busy: usize,
    /// Deadline slack of the head request: `None` when the head has no
    /// deadline, `Some(ZERO)` when it is already late.
    pub head_slack: Option<Duration>,
    /// Age of the oldest queued background-class request, if any.
    pub oldest_background_wait: Option<Duration>,
}

/// Gauge-driven batch size for the drain about to happen: a tight head
/// deadline (or an already-late head) always takes the smallest
/// compiled batch — latency mode; otherwise the largest compiled size
/// the current queue depth can fill — throughput mode. A queue
/// shallower than the smallest compiled batch also yields the smallest
/// (the server zero-pads it).
pub fn choose_batch_size(cfg: &PolicyConfig, snap: &QueueSnapshot) -> usize {
    if snap.head_slack.is_some_and(|s| s < cfg.slack_floor) {
        return cfg.min_batch();
    }
    cfg.batch_sizes
        .iter()
        .rev()
        .copied()
        .find(|&b| b <= snap.depth)
        .unwrap_or_else(|| cfg.min_batch())
}

/// Whether the dispatcher should spend the batching window waiting for
/// the chosen batch to fill. With a tight head deadline the window wait
/// would burn the remaining slack, so the drain runs immediately with
/// whatever is pending (padded if below the smallest compiled batch).
pub fn fill_window(cfg: &PolicyConfig, snap: &QueueSnapshot) -> bool {
    !snap.head_slack.is_some_and(|s| s < cfg.slack_floor)
}

/// Whether starvation protection kicks in: the oldest queued
/// background request has waited at least `starvation_limit`, so it is
/// served ahead of interactive traffic this pop.
pub fn promote_background(cfg: &PolicyConfig, snap: &QueueSnapshot) -> bool {
    snap.oldest_background_wait
        .is_some_and(|w| w >= cfg.starvation_limit)
}

/// How many dispatchers are worth keeping awake: the ones already
/// computing a batch plus one per full `max_batch` of queued work — at
/// least one, at most all of them.
pub fn desired_active(cfg: &PolicyConfig, snap: &QueueSnapshot) -> usize {
    (snap.busy + snap.depth.div_ceil(cfg.max_batch())).clamp(1, cfg.n_exec.max(1))
}

/// Per-run thread cap for a batch about to execute: slice the pool by
/// the number of batches expected to overlap — the ones other
/// dispatchers are already computing (`snap.busy`), this one, and what
/// the remaining queue depth (`snap.depth`, *after* this batch's
/// requests were drained) can still fill — clamped to the dispatcher
/// count. An idle server yields the whole pool; a deep queue yields
/// `pool / n_exec`.
pub fn run_cap(cfg: &PolicyConfig, snap: &QueueSnapshot) -> usize {
    let overlap =
        (snap.busy + 1 + snap.depth / cfg.max_batch()).clamp(1, cfg.n_exec.max(1));
    cfg.pool_size.div_ceil(overlap).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(batch_sizes: &[usize], n_exec: usize, pool_size: usize) -> PolicyConfig {
        PolicyConfig {
            batch_sizes: batch_sizes.to_vec(),
            n_exec,
            pool_size,
            starvation_limit: Duration::from_millis(100),
            slack_floor: Duration::from_millis(10),
        }
    }

    fn snap(depth: usize, busy: usize) -> QueueSnapshot {
        QueueSnapshot {
            depth,
            busy,
            head_slack: None,
            oldest_background_wait: None,
        }
    }

    /// Satellite: table-driven batch-size policy — deep queue with
    /// slack takes the largest compiled batch, shallow queues and tight
    /// deadlines take the smallest, intermediate depths take the
    /// largest size they can fill. No threads, no clocks.
    #[test]
    fn batch_size_follows_depth_and_slack() {
        let c = cfg(&[1, 2, 4, 8], 2, 8);
        // (depth, head_slack_ms, want)
        let table: &[(usize, Option<u64>, usize)] = &[
            (0, None, 1),       // empty queue → smallest
            (1, None, 1),       // trickle → smallest
            (2, None, 2),       // exactly fills a 2-batch
            (3, None, 2),       // largest size ≤ 3
            (7, None, 4),       // largest size ≤ 7
            (8, None, 8),       // deep → largest
            (100, None, 8),     // very deep → still largest
            (100, Some(500), 8), // deep + generous slack → throughput mode
            (100, Some(0), 1),  // already late → latency mode
            (100, Some(5), 1),  // slack below the floor → latency mode
            (1, Some(5), 1),    // tight + shallow → smallest
            (0, Some(500), 1),  // slack alone cannot grow an empty queue
        ];
        for &(depth, slack_ms, want) in table {
            let s = QueueSnapshot {
                depth,
                busy: 0,
                head_slack: slack_ms.map(Duration::from_millis),
                oldest_background_wait: None,
            };
            assert_eq!(
                choose_batch_size(&c, &s),
                want,
                "depth={depth} slack={slack_ms:?}"
            );
        }
    }

    /// The slack floor is a strict threshold: exactly at the floor is
    /// throughput mode, one nanosecond below is latency mode.
    #[test]
    fn slack_floor_is_exclusive() {
        let c = cfg(&[2, 8], 1, 4);
        let at = QueueSnapshot {
            depth: 50,
            head_slack: Some(c.slack_floor),
            ..Default::default()
        };
        let below = QueueSnapshot {
            depth: 50,
            head_slack: Some(c.slack_floor - Duration::from_nanos(1)),
            ..Default::default()
        };
        assert_eq!(choose_batch_size(&c, &at), 8);
        assert_eq!(choose_batch_size(&c, &below), 2);
        assert!(fill_window(&c, &at));
        assert!(!fill_window(&c, &below));
    }

    /// Satellite: starvation-protection bounds — promotion happens at
    /// the limit (inclusive), never before it, and never without a
    /// queued background request.
    #[test]
    fn starvation_promotion_bounds() {
        let c = cfg(&[1, 4], 2, 4);
        let limit = c.starvation_limit;
        let with_wait = |w: Option<Duration>| QueueSnapshot {
            depth: 3,
            oldest_background_wait: w,
            ..Default::default()
        };
        assert!(!promote_background(&c, &with_wait(None)));
        assert!(!promote_background(&c, &with_wait(Some(Duration::ZERO))));
        assert!(!promote_background(
            &c,
            &with_wait(Some(limit - Duration::from_nanos(1)))
        ));
        assert!(promote_background(&c, &with_wait(Some(limit))));
        assert!(promote_background(&c, &with_wait(Some(limit * 10))));
    }

    /// Table-driven dispatcher-activation policy (moved from the
    /// server): shallow queues keep one drainer, queued work or busy
    /// dispatchers wake more, never more than exist.
    #[test]
    fn desired_active_scales_with_depth_and_busy() {
        let c = cfg(&[1, 2, 4], 3, 8);
        let table: &[(usize, usize, usize)] = &[
            // (busy, depth, want)
            (0, 0, 1),
            (0, 1, 1),
            (1, 1, 2), // a request arriving mid-compute wakes a second
            (0, 5, 2),
            (2, 100, 3),
            (0, 100, 3), // clamped at n_exec
        ];
        for &(busy, depth, want) in table {
            assert_eq!(
                desired_active(&c, &snap(depth, busy)),
                want,
                "busy={busy} depth={depth}"
            );
        }
    }

    /// Table-driven per-run cap policy (moved from the server): idle →
    /// whole pool, overlapping batches slice it, clamps keep it within
    /// [1, pool].
    #[test]
    fn run_cap_slices_pool_by_expected_overlap() {
        let c2 = cfg(&[1, 2, 4], 2, 8);
        let table2: &[(usize, usize, usize)] = &[
            // (busy_others, depth_after, want)
            (0, 0, 8), // idle server → lone batch takes the whole pool
            (0, 4, 4), // a full extra batch queued → half the pool each
            (1, 0, 4), // another dispatcher computing → same split
            (0, 100, 4), // very deep → clamped to dispatcher count
        ];
        for &(busy, depth, want) in table2 {
            assert_eq!(run_cap(&c2, &snap(depth, busy)), want, "busy={busy} depth={depth}");
        }
        // Tiny pool, many dispatchers: cap never drops below one worker.
        let c4 = cfg(&[1, 2, 4], 4, 2);
        assert_eq!(run_cap(&c4, &snap(100, 0)), 1);
    }

    /// Degenerate configs stay safe: a single compiled batch size, one
    /// dispatcher, and zero-depth snapshots never panic or return 0.
    #[test]
    fn degenerate_configs_are_safe() {
        let c = cfg(&[4], 1, 1);
        assert_eq!(choose_batch_size(&c, &snap(0, 0)), 4);
        assert_eq!(choose_batch_size(&c, &snap(100, 0)), 4);
        assert_eq!(desired_active(&c, &snap(0, 0)), 1);
        assert_eq!(run_cap(&c, &snap(0, 0)), 1);
        assert!(fill_window(&c, &snap(0, 0)));
    }

    #[test]
    fn priority_ordering_and_indices() {
        assert!(Priority::Interactive < Priority::Batch);
        assert_eq!(Priority::Interactive.index(), 0);
        assert_eq!(Priority::Batch.index(), 1);
        assert_eq!(Priority::ALL.len(), Priority::COUNT);
    }
}
