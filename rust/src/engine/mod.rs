//! The inference engine (Layer-3 coordinator core): operator
//! implementations, a graph executor with per-layer path/parameter
//! configuration, pure serving policies, and a batching request server
//! with traffic classes and deadlines.

pub mod ops;
pub mod executor;
pub mod policy;
pub mod scratch;
pub mod server;

pub use executor::{ExecConfig, Executor, LayerChoice};
pub use policy::{PolicyConfig, Priority, QueueDiscipline, QueueSnapshot};
pub use scratch::{MemoryPlan, ScratchArena};
pub use server::{ClassStats, Server, ServerConfig, ServerStats};
