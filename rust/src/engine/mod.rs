//! The inference engine (Layer-3 coordinator core): operator
//! implementations, a graph executor with per-layer path/parameter
//! configuration, and a batching request server.

pub mod ops;
pub mod executor;
pub mod server;

pub use executor::{ExecConfig, Executor, LayerChoice};
pub use server::{Server, ServerConfig, ServerStats};
