//! Batching inference server: the request-path coordinator.
//!
//! Clients submit single-image NHWC requests; dispatcher threads group
//! them into batches (up to `max_batch`, waiting at most `batch_window`)
//! and run them on pre-compiled executors — one per supported batch
//! size, mirroring how the AOT artifacts are compiled per batch shape.
//! Per-request latency and aggregate throughput are recorded.
//!
//! # Concurrent batch executors
//!
//! `ServerConfig::executors` starts that many dispatcher threads, all
//! draining one shared request queue and all running batches on the
//! *same* persistent [`ThreadPool`](crate::util::ThreadPool): while one
//! batch computes, another forms and starts. Oversubscription is
//! avoided on two levels — the pool's worker set is fixed (concurrent
//! `parallel_for`s interleave their chunk jobs on the same workers
//! instead of spawning more threads), and when no per-layer tuning says
//! otherwise the server caps each executor's GEMMs at
//! `pool size / executors` participants so concurrent batches slice the
//! pool instead of queueing a full pool's worth of jobs each.

use std::sync::mpsc::{channel, Receiver, Sender, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::models::Graph;
use crate::tensor::Tensor;
use crate::util::stats::Summary;

use super::executor::{ExecConfig, Executor};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Supported batch sizes, ascending (executors prebuilt per size).
    pub batch_sizes: Vec<usize>,
    /// Max time the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Concurrent batch-executor (dispatcher) threads sharing the one
    /// request queue and the one pool. 0 clamps to 1.
    pub executors: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batch_sizes: vec![1, 2, 4],
            batch_window: Duration::from_millis(5),
            executors: 1,
        }
    }
}

struct Request {
    image: Tensor, // [H, W, C]
    enqueued: Instant,
    reply: Sender<Reply>,
}

/// A completed inference.
pub struct Reply {
    pub logits: Vec<f32>,
    /// Queue + batching + compute latency.
    pub latency: Duration,
    /// Batch this request was served in.
    pub batch: usize,
}

#[derive(Default)]
struct StatsInner {
    latencies_ns: Vec<f64>,
    batches: Vec<usize>,
    started: Option<Instant>,
    finished: Option<Instant>,
    served: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub served: usize,
    pub latency: Summary,
    pub throughput_rps: f64,
    pub mean_batch: f64,
}

/// The serving engine.
pub struct Server {
    tx: Option<Sender<Request>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    res: usize,
}

impl Server {
    /// Build executors for every configured batch size and start
    /// `cfg.executors` dispatcher threads. `make_graph(batch)` supplies
    /// the model graph; `exec` is the (shared) execution config; `res`
    /// the input resolution.
    pub fn start<F: Fn(usize) -> Graph>(
        make_graph: F,
        exec: ExecConfig,
        res: usize,
        cfg: ServerConfig,
    ) -> Self {
        assert!(!cfg.batch_sizes.is_empty());
        let mut sizes = cfg.batch_sizes.clone();
        sizes.sort_unstable();
        let n_exec = cfg.executors.max(1);
        let mut exec = exec;
        if n_exec > 1 && exec.default_choice.threads == 0 {
            // Several executors share one pool: slice it so a batch's
            // GEMMs occupy pool/executors workers and concurrent
            // batches run beside each other instead of queueing a full
            // pool's worth of jobs each. Explicit per-layer tuning
            // (per_layer entries / a preset default cap) is respected.
            exec.default_choice.threads = exec.pool.size().div_ceil(n_exec).max(1);
        }
        let executors: Arc<Vec<(usize, Executor)>> = Arc::new(
            sizes
                .iter()
                .map(|&b| (b, Executor::new(make_graph(b), exec.clone())))
                .collect(),
        );
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let window = cfg.batch_window;
        let workers = (0..n_exec)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let executors = Arc::clone(&executors);
                let stats = Arc::clone(&stats);
                std::thread::spawn(move || dispatcher(rx, executors, window, stats, res))
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            stats,
            res,
        }
    }

    /// Submit one image `[H, W, C]`; returns a handle to await the reply.
    pub fn submit(&self, image: Tensor) -> Receiver<Reply> {
        assert_eq!(image.shape, vec![self.res, self.res, 3], "image shape");
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .unwrap()
            .send(Request {
                image,
                enqueued: Instant::now(),
                reply: reply_tx,
            })
            .expect("server stopped");
        reply_rx
    }

    /// Drain and stop the server, returning aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        self.tx.take(); // closes channel; dispatchers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let inner = self.stats.lock().unwrap();
        let wall = match (inner.started, inner.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        ServerStats {
            served: inner.served,
            latency: if inner.latencies_ns.is_empty() {
                Summary::of(&[0.0])
            } else {
                Summary::of(&inner.latencies_ns)
            },
            throughput_rps: if wall > 0.0 {
                inner.served as f64 / wall
            } else {
                0.0
            },
            mean_batch: if inner.batches.is_empty() {
                0.0
            } else {
                inner.batches.iter().sum::<usize>() as f64 / inner.batches.len() as f64
            },
        }
    }
}

/// One batch-executor thread. Several of these may drain the same
/// queue: the receiver sits behind a mutex, and each request is
/// delivered to exactly one dispatcher, so every request is answered
/// exactly once regardless of how many executors run.
fn dispatcher(
    rx: Arc<Mutex<Receiver<Request>>>,
    executors: Arc<Vec<(usize, Executor)>>,
    window: Duration,
    stats: Arc<Mutex<StatsInner>>,
    res: usize,
) {
    let max_batch = executors.last().map(|(b, _)| *b).unwrap_or(1);
    let mut pending: Vec<Request> = Vec::new();
    let mut open = true;
    while open || !pending.is_empty() {
        // Blocking intake of the first request. Holding the queue lock
        // across the blocking recv is fine: there is nothing for the
        // other dispatchers to receive while the queue is empty.
        if open && pending.is_empty() {
            match rx.lock().unwrap().recv() {
                Ok(r) => pending.push(r),
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        // Fill up to max_batch within the window — but only if the
        // intake lock is free. If another dispatcher owns it (parked in
        // its own blocking recv), waiting for the lock could stall this
        // batch until the *next* request arrives; serving the batch we
        // already have keeps trickle-latency bounded by the window.
        if open {
            if let Ok(q) = rx.try_lock() {
                let deadline = Instant::now() + window;
                while pending.len() < max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match q.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        // Largest supported batch ≤ pending.
        let (batch, exec) = executors
            .iter()
            .rev()
            .find(|(b, _)| *b <= pending.len())
            .unwrap_or(&executors[0]);
        let batch = (*batch).min(pending.len());
        let group: Vec<Request> = pending.drain(..batch).collect();
        // Assemble the batched NHWC input.
        let mut input = Tensor::zeros(&[batch, res, res, 3]);
        let per = res * res * 3;
        for (i, r) in group.iter().enumerate() {
            input.data[i * per..(i + 1) * per].copy_from_slice(&r.image.data);
        }
        {
            let mut s = stats.lock().unwrap();
            if s.started.is_none() {
                s.started = Some(Instant::now());
            }
        }
        let logits = exec.run(&input);
        let done = Instant::now();
        let classes = logits.shape[1];
        let mut s = stats.lock().unwrap();
        s.finished = Some(done);
        for (i, r) in group.into_iter().enumerate() {
            let latency = done - r.enqueued;
            s.latencies_ns.push(latency.as_nanos() as f64);
            s.batches.push(batch);
            s.served += 1;
            let _ = r.reply.send(Reply {
                logits: logits.data[i * classes..(i + 1) * classes].to_vec(),
                latency,
                batch,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_model, ModelArch};
    use crate::util::{ThreadPool, XorShiftRng};

    fn image(res: usize, seed: u64) -> Tensor {
        let mut r = XorShiftRng::new(seed);
        Tensor::random(&[res, res, 3], &mut r, 0.0, 1.0)
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 1,
            },
        );
        let replies: Vec<_> = (0..6).map(|i| server.submit(image(res, i))).collect();
        for r in replies {
            let reply = r.recv().expect("reply");
            assert_eq!(reply.logits.len(), 1000);
            assert!(reply.batch >= 1 && reply.batch <= 2);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 6);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency.mean > 0.0);
    }

    #[test]
    fn batches_form_under_load() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2, 4],
                batch_window: Duration::from_millis(50),
                executors: 1,
            },
        );
        // Burst of 8 requests: with a generous window, batches of 4 form.
        let replies: Vec<_> = (0..8).map(|i| server.submit(image(res, i))).collect();
        let mut max_batch = 0;
        for r in replies {
            max_batch = max_batch.max(r.recv().unwrap().batch);
        }
        let stats = server.shutdown();
        assert!(max_batch >= 2, "expected batching, got max batch {max_batch}");
        assert!(stats.mean_batch > 1.0);
    }

    /// Satellite: N client threads submitting through concurrent batch
    /// executors — every request is answered exactly once, the served
    /// count matches, and the summary statistics stay finite and sane.
    #[test]
    fn concurrent_executors_answer_every_request_exactly_once() {
        let res = 32;
        let (clients, per_client) = (4usize, 4usize);
        let server = Arc::new(Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 3,
            },
        ));
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let server = Arc::clone(&server);
                std::thread::spawn(move || {
                    let mut replies = 0usize;
                    for i in 0..per_client {
                        let rx = server.submit(image(res, (c * per_client + i) as u64));
                        let reply = rx.recv().expect("reply");
                        assert_eq!(reply.logits.len(), 1000);
                        assert!(reply.logits.iter().all(|v| v.is_finite()));
                        assert!(reply.batch >= 1 && reply.batch <= 2);
                        // Exactly once: the reply channel yields one
                        // reply and then hangs up.
                        assert!(reply.latency > Duration::ZERO);
                        assert!(rx.try_recv().is_err(), "duplicate reply");
                        replies += 1;
                    }
                    replies
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, clients * per_client);
        let server = Arc::into_inner(server).expect("all clients joined");
        let stats = server.shutdown();
        assert_eq!(stats.served, clients * per_client);
        assert!(stats.latency.mean.is_finite() && stats.latency.mean > 0.0);
        assert!(stats.latency.p95.is_finite());
        assert!(
            stats.mean_batch.is_finite() && stats.mean_batch >= 1.0 && stats.mean_batch <= 2.0,
            "mean batch {} out of range",
            stats.mean_batch
        );
        assert!(stats.throughput_rps > 0.0);
    }

    /// Determinism across executor counts: the same requests produce the
    /// same logits whether one or three executors serve them (caps and
    /// concurrency are scheduling decisions, never numerics).
    #[test]
    fn concurrent_executors_match_single_executor_logits() {
        let res = 32;
        let run = |executors: usize| -> Vec<Vec<f32>> {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::dense_cnhw(ThreadPool::shared(2)),
                res,
                ServerConfig {
                    batch_sizes: vec![1],
                    batch_window: Duration::from_millis(1),
                    executors,
                },
            );
            let rxs: Vec<_> = (0..4).map(|i| server.submit(image(res, i))).collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().logits).collect();
            server.shutdown();
            out
        };
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn shutdown_drains_pending() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(1)),
            res,
            ServerConfig {
                batch_sizes: vec![1],
                batch_window: Duration::from_millis(1),
                executors: 1,
            },
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(image(res, i))).collect();
        let stats = server.shutdown();
        assert_eq!(stats.served, 3);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }
}
