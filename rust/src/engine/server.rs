//! Batching inference server: the request-path coordinator.
//!
//! Clients submit single-image NHWC requests; dispatcher threads group
//! them into batches (up to `max_batch`, waiting at most `batch_window`)
//! and run them on pre-compiled executors — one per supported batch
//! size, mirroring how the AOT artifacts are compiled per batch shape.
//! When fewer requests are pending than the smallest compiled batch
//! (a trickle, or the shutdown drain), the batch is zero-padded up to
//! the smallest executor's size and the padded rows' logits are
//! discarded — a request always gets a reply. Per-request latency and
//! aggregate throughput are recorded.
//!
//! # Concurrent batch executors
//!
//! `ServerConfig::executors` starts that many dispatcher threads, all
//! draining one shared request queue and all running batches on the
//! *same* persistent [`ThreadPool`](crate::util::ThreadPool): while one
//! batch computes, another forms and starts. Oversubscription is
//! avoided on two levels — the pool's worker set is fixed (concurrent
//! `parallel_for`s interleave their chunk jobs on the same workers
//! instead of spawning more threads), and when no per-layer tuning says
//! otherwise the server caps each executor's GEMMs at
//! `pool size / executors` participants so concurrent batches slice the
//! pool instead of queueing a full pool's worth of jobs each.
//!
//! # Load-aware adaptive mode
//!
//! The static `pool/executors` slice is right only when every
//! dispatcher is actually busy. `ServerConfig::adaptive` replaces the
//! startup-time split with two decisions made *per batch* against a
//! queue-depth gauge (an atomic incremented in [`Server::submit`],
//! decremented when requests drain into a batch):
//!
//! 1. **Per-run thread cap** — each batch executes under
//!    [`Executor::run_capped`] with `pool size / expected overlapping
//!    batches` participants: a deep queue slices the pool harder so
//!    more batches run beside each other, an empty queue lets a lone
//!    batch take the whole pool. The per-run cap composes with
//!    per-layer tuned caps as a min, so tuning is never widened.
//! 2. **Active dispatchers** — surplus dispatchers park on a condvar
//!    while the queue is shallow (one stays live) and are woken by
//!    `submit` on bursts, instead of all camping on the intake lock.
//!
//! The chosen caps are observable: `ServerStats::cap_range` reports the
//! min/max cap used, and `NMPRUNE_SERVE_TRACE=1` prints one line per
//! batch. Caps and parking are pure scheduling — logits are bitwise
//! identical between static and adaptive modes.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::models::Graph;
use crate::tensor::Tensor;
use crate::util::stats::Summary;

use super::executor::{ExecConfig, Executor};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Supported batch sizes, ascending (executors prebuilt per size).
    pub batch_sizes: Vec<usize>,
    /// Max time the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Concurrent batch-executor (dispatcher) threads sharing the one
    /// request queue and the one pool. 0 clamps to 1.
    pub executors: usize,
    /// Load-aware mode: derive the per-run thread cap and the number of
    /// actively draining dispatchers from queue depth per batch, instead
    /// of the fixed `pool/executors` slice chosen at startup.
    pub adaptive: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batch_sizes: vec![1, 2, 4],
            batch_window: Duration::from_millis(5),
            executors: 1,
            adaptive: false,
        }
    }
}

struct Request {
    image: Tensor, // [H, W, C]
    enqueued: Instant,
    reply: Sender<Reply>,
}

/// A completed inference.
pub struct Reply {
    pub logits: Vec<f32>,
    /// Queue + batching + compute latency.
    pub latency: Duration,
    /// Batch this request was served in (the compiled batch size — may
    /// exceed the number of real requests when the batch was padded).
    pub batch: usize,
}

#[derive(Default)]
struct StatsInner {
    latencies_ns: Vec<f64>,
    batches: Vec<usize>,
    /// Per-batch chosen per-run thread cap (adaptive mode only).
    caps: Vec<usize>,
    started: Option<Instant>,
    finished: Option<Instant>,
    served: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub served: usize,
    /// Empty (`n == 0`, all zeros) when nothing was served — never a
    /// fabricated 0 ns sample.
    pub latency: Summary,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// Min/max per-run thread cap chosen across batches; `None` in
    /// static mode or when no batch ran. The observable trace of the
    /// adaptive controller (deep burst → small caps, trickle → pool
    /// size).
    pub cap_range: Option<(usize, usize)>,
}

/// Queue-depth gauge plus the parking primitive for surplus
/// dispatchers. `depth` counts requests submitted but not yet drained
/// into a batch (incremented in `submit`, decremented at batch
/// formation); `busy` counts dispatchers currently computing a batch —
/// without it, a request arriving while the only awake dispatcher is
/// mid-compute would leave parked dispatchers asleep for a whole batch
/// time. The condvar wakes parked dispatchers on bursts and at
/// shutdown.
struct LoadGauge {
    depth: AtomicUsize,
    busy: AtomicUsize,
    closing: AtomicBool,
    lock: Mutex<()>,
    cvar: Condvar,
}

impl LoadGauge {
    fn new() -> Self {
        Self {
            depth: AtomicUsize::new(0),
            busy: AtomicUsize::new(0),
            closing: AtomicBool::new(false),
            lock: Mutex::new(()),
            cvar: Condvar::new(),
        }
    }
}

/// How many dispatchers are worth keeping awake: the ones already
/// computing a batch plus one per full `max_batch` of queued work — at
/// least one, at most all of them.
fn desired_active(busy: usize, depth: usize, max_batch: usize, n_exec: usize) -> usize {
    (busy + depth.div_ceil(max_batch.max(1))).clamp(1, n_exec)
}

/// Per-run thread cap for a batch about to execute: slice the pool by
/// the number of batches expected to overlap — the ones other
/// dispatchers are already computing, this one, and what the remaining
/// queue depth can still fill — clamped to the dispatcher count. An
/// idle server yields the whole pool; a deep queue yields
/// `pool/n_exec`.
fn adaptive_cap(
    busy_others: usize,
    depth_after: usize,
    max_batch: usize,
    n_exec: usize,
    pool_size: usize,
) -> usize {
    let overlap = (busy_others + 1 + depth_after / max_batch.max(1)).clamp(1, n_exec.max(1));
    pool_size.div_ceil(overlap).max(1)
}

/// The serving engine.
pub struct Server {
    tx: Option<Sender<Request>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    gauge: Arc<LoadGauge>,
    /// Adaptive mode with >1 dispatcher: only then can anyone be parked
    /// and worth waking from `submit` (a lone dispatcher never parks).
    wake_dispatchers: bool,
    res: usize,
}

/// Everything a dispatcher thread needs, shared across all of them.
struct Dispatch {
    rx: Arc<Mutex<Receiver<Request>>>,
    executors: Arc<Vec<(usize, Executor)>>,
    window: Duration,
    stats: Arc<Mutex<StatsInner>>,
    gauge: Arc<LoadGauge>,
    res: usize,
    adaptive: bool,
    n_exec: usize,
    pool_size: usize,
    trace: bool,
}

impl Server {
    /// Build executors for every configured batch size and start
    /// `cfg.executors` dispatcher threads. `make_graph(batch)` supplies
    /// the model graph; `exec` is the (shared) execution config; `res`
    /// the input resolution.
    pub fn start<F: Fn(usize) -> Graph>(
        make_graph: F,
        exec: ExecConfig,
        res: usize,
        cfg: ServerConfig,
    ) -> Self {
        assert!(!cfg.batch_sizes.is_empty());
        let mut sizes = cfg.batch_sizes.clone();
        sizes.sort_unstable();
        let n_exec = cfg.executors.max(1);
        let pool_size = exec.pool.size();
        let mut exec = exec;
        if !cfg.adaptive && n_exec > 1 && exec.default_choice.threads == 0 {
            // Static mode with several executors on one pool: slice it
            // so a batch's GEMMs occupy pool/executors workers and
            // concurrent batches run beside each other instead of
            // queueing a full pool's worth of jobs each. Explicit
            // per-layer tuning (per_layer entries / a preset default
            // cap) is respected. Adaptive mode skips this: the slice is
            // decided per batch from queue depth instead.
            exec.default_choice.threads = pool_size.div_ceil(n_exec).max(1);
        }
        let executors: Arc<Vec<(usize, Executor)>> = Arc::new(
            sizes
                .iter()
                .map(|&b| (b, Executor::new(make_graph(b), exec.clone())))
                .collect(),
        );
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let gauge = Arc::new(LoadGauge::new());
        let ctx = Arc::new(Dispatch {
            rx,
            executors,
            window: cfg.batch_window,
            stats: Arc::clone(&stats),
            gauge: Arc::clone(&gauge),
            res,
            adaptive: cfg.adaptive,
            n_exec,
            pool_size,
            // `=1` to enable, like NMPRUNE_PIN (so `=0` really is off).
            trace: std::env::var("NMPRUNE_SERVE_TRACE").map(|v| v == "1").unwrap_or(false),
        });
        let workers = (0..n_exec)
            .map(|idx| {
                let ctx = Arc::clone(&ctx);
                std::thread::spawn(move || dispatcher(&ctx, idx))
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            stats,
            gauge,
            wake_dispatchers: cfg.adaptive && n_exec > 1,
            res,
        }
    }

    /// Submit one image `[H, W, C]`; returns a handle to await the reply.
    pub fn submit(&self, image: Tensor) -> Receiver<Reply> {
        assert_eq!(image.shape, vec![self.res, self.res, 3], "image shape");
        let (reply_tx, reply_rx) = channel();
        // Gauge before send: a dispatcher can only drain (and decrement
        // for) this request after `send`, so depth never underflows.
        self.gauge.depth.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .unwrap()
            .send(Request {
                image,
                enqueued: Instant::now(),
                reply: reply_tx,
            })
            .expect("server stopped");
        if self.wake_dispatchers {
            // Wake parked dispatchers so a burst is met with more
            // drains. Taking the lock pairs the notify with the parked
            // side's predicate check (no missed wake-ups); the parked
            // side's wait also has a timeout backstop.
            let _guard = self.gauge.lock.lock().unwrap();
            self.gauge.cvar.notify_all();
        }
        reply_rx
    }

    /// Drain and stop the server, returning aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        self.tx.take(); // closes channel; dispatchers drain then exit
        // Wake parked dispatchers so they observe the close and help
        // drain whatever is still queued.
        self.gauge.closing.store(true, Ordering::Release);
        {
            let _guard = self.gauge.lock.lock().unwrap();
            self.gauge.cvar.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let inner = self.stats.lock().unwrap();
        let wall = match (inner.started, inner.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        ServerStats {
            served: inner.served,
            latency: if inner.latencies_ns.is_empty() {
                // Nothing served: report an explicitly empty summary
                // instead of fabricating a 0 ns request.
                Summary::empty()
            } else {
                Summary::of(&inner.latencies_ns)
            },
            throughput_rps: if wall > 0.0 {
                inner.served as f64 / wall
            } else {
                0.0
            },
            mean_batch: if inner.batches.is_empty() {
                0.0
            } else {
                inner.batches.iter().sum::<usize>() as f64 / inner.batches.len() as f64
            },
            cap_range: inner
                .caps
                .iter()
                .copied()
                .fold(None, |acc: Option<(usize, usize)>, c| match acc {
                    None => Some((c, c)),
                    Some((lo, hi)) => Some((lo.min(c), hi.max(c))),
                }),
        }
    }
}

/// One batch-executor thread. Several of these may drain the same
/// queue: the receiver sits behind a mutex, and each request is
/// delivered to exactly one dispatcher, so every request is answered
/// exactly once regardless of how many executors run.
fn dispatcher(ctx: &Dispatch, idx: usize) {
    let max_batch = ctx.executors.last().map(|(b, _)| *b).unwrap_or(1);
    // Bounded poll interval for parked/polling dispatchers (never 0,
    // or they would spin).
    let poll = ctx.window.max(Duration::from_millis(1));
    let mut pending: Vec<Request> = Vec::new();
    let mut open = true;
    while open || !pending.is_empty() {
        // Adaptive mode: surplus dispatchers park while the queue is
        // shallow enough that fewer drains suffice. Dispatcher 0 never
        // parks (something must accept the first request of a burst);
        // the rest re-check on every submit notify, on a timeout
        // backstop, and at shutdown.
        if ctx.adaptive && idx > 0 && open && pending.is_empty() {
            let mut guard = ctx.gauge.lock.lock().unwrap();
            while !ctx.gauge.closing.load(Ordering::Acquire)
                && desired_active(
                    ctx.gauge.busy.load(Ordering::Acquire),
                    ctx.gauge.depth.load(Ordering::Acquire),
                    max_batch,
                    ctx.n_exec,
                ) <= idx
            {
                let (g, _timed_out) = ctx.gauge.cvar.wait_timeout(guard, poll).unwrap();
                guard = g;
            }
        }
        // Blocking intake of the first request. Holding the queue lock
        // across the blocking recv is fine: there is nothing for the
        // other dispatchers to receive while the queue is empty. Woken
        // adaptive dispatchers poll with a bounded wait instead, so
        // that when the burst is already drained they go back to the
        // parking check rather than camping on the intake lock.
        if open && pending.is_empty() {
            if ctx.adaptive && idx > 0 {
                // try_lock, not lock: Mutex::lock has no timeout, so a
                // blocking acquire would camp behind a dispatcher that
                // idles holding the lock across its recv — exactly the
                // unbounded wait parking is meant to replace. If the
                // lock is taken, the owner is handling intake; back off
                // briefly and re-evaluate parking.
                match ctx.rx.try_lock() {
                    Ok(q) => match q.recv_timeout(poll) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            continue;
                        }
                    },
                    Err(_) => {
                        std::thread::sleep(Duration::from_micros(500));
                        continue;
                    }
                }
            } else {
                match ctx.rx.lock().unwrap().recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => {
                        open = false;
                        continue;
                    }
                }
            }
        }
        // Fill up to max_batch within the window — but only if the
        // intake lock is free. If another dispatcher owns it (parked in
        // its own blocking recv), waiting for the lock could stall this
        // batch until the *next* request arrives; serving the batch we
        // already have keeps trickle-latency bounded by the window.
        if open {
            if let Ok(q) = ctx.rx.try_lock() {
                let deadline = Instant::now() + ctx.window;
                while pending.len() < max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match q.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        // Largest supported batch ≤ pending — or, when even the
        // smallest compiled batch exceeds what is pending (trickle /
        // shutdown drain), the smallest one zero-padded: the executor's
        // compiled input shape is always honoured and every request is
        // answered. (Running `batch.min(pending.len())` real rows
        // against a larger compiled batch used to trip the Input-op
        // shape assert and drop the requests.)
        let (batch, exec) = ctx
            .executors
            .iter()
            .rev()
            .find(|(b, _)| *b <= pending.len())
            .unwrap_or(&ctx.executors[0]);
        let batch = *batch;
        let take = batch.min(pending.len());
        let group: Vec<Request> = pending.drain(..take).collect();
        ctx.gauge.depth.fetch_sub(take, Ordering::AcqRel);
        // Assemble the batched NHWC input; rows [take, batch) stay zero
        // and their logits are computed but discarded.
        let mut input = Tensor::zeros(&[batch, ctx.res, ctx.res, 3]);
        let per = ctx.res * ctx.res * 3;
        for (i, r) in group.iter().enumerate() {
            input.data[i * per..(i + 1) * per].copy_from_slice(&r.image.data);
        }
        // Per-run cap: adaptive mode slices the pool by how many
        // batches can overlap — dispatchers already computing, this
        // batch, and what is still queued; static mode relies on the
        // startup-time default cap (run_cap 0 = defer to per-layer
        // choices). `busy` is read before our own increment below, so
        // it counts the *other* in-flight batches.
        let run_cap = if ctx.adaptive {
            adaptive_cap(
                ctx.gauge.busy.load(Ordering::Acquire),
                ctx.gauge.depth.load(Ordering::Acquire),
                max_batch,
                ctx.n_exec,
                ctx.pool_size,
            )
        } else {
            0
        };
        let t0 = Instant::now();
        {
            let mut s = ctx.stats.lock().unwrap();
            // Keep the earliest start across racing dispatchers.
            s.started = Some(s.started.map_or(t0, |prev| prev.min(t0)));
        }
        ctx.gauge.busy.fetch_add(1, Ordering::AcqRel);
        let logits = exec.run_capped(&input, run_cap);
        ctx.gauge.busy.fetch_sub(1, Ordering::AcqRel);
        let done = Instant::now();
        if ctx.trace {
            eprintln!(
                "[serve] exec={idx} batch={batch} real={take} cap={run_cap} depth={}",
                ctx.gauge.depth.load(Ordering::Relaxed)
            );
        }
        let classes = logits.shape[1];
        let mut s = ctx.stats.lock().unwrap();
        // Keep the latest finish: with concurrent executors a batch that
        // completed *before* us may lock *after* us — blindly storing
        // our timestamp could rewind the measured wall clock and
        // inflate throughput_rps.
        s.finished = Some(s.finished.map_or(done, |prev| prev.max(done)));
        if ctx.adaptive {
            s.caps.push(run_cap);
        }
        for (i, r) in group.into_iter().enumerate() {
            let latency = done - r.enqueued;
            s.latencies_ns.push(latency.as_nanos() as f64);
            // Batching efficiency counts *real* requests per batch: a
            // padded trickle must report mean_batch 1.0, not the
            // compiled size (Reply::batch still carries the latter).
            s.batches.push(take);
            s.served += 1;
            let _ = r.reply.send(Reply {
                logits: logits.data[i * classes..(i + 1) * classes].to_vec(),
                latency,
                batch,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_model, ModelArch};
    use crate::util::{ThreadPool, XorShiftRng};

    fn image(res: usize, seed: u64) -> Tensor {
        let mut r = XorShiftRng::new(seed);
        Tensor::random(&[res, res, 3], &mut r, 0.0, 1.0)
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 1,
                adaptive: false,
            },
        );
        let replies: Vec<_> = (0..6).map(|i| server.submit(image(res, i))).collect();
        for r in replies {
            let reply = r.recv().expect("reply");
            assert_eq!(reply.logits.len(), 1000);
            assert!(reply.batch >= 1 && reply.batch <= 2);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 6);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency.mean > 0.0);
        assert!(stats.cap_range.is_none(), "static mode records no caps");
    }

    #[test]
    fn batches_form_under_load() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2, 4],
                batch_window: Duration::from_millis(50),
                executors: 1,
                adaptive: false,
            },
        );
        // Burst of 8 requests: with a generous window, batches of 4 form.
        let replies: Vec<_> = (0..8).map(|i| server.submit(image(res, i))).collect();
        let mut max_batch = 0;
        for r in replies {
            max_batch = max_batch.max(r.recv().unwrap().batch);
        }
        let stats = server.shutdown();
        assert!(max_batch >= 2, "expected batching, got max batch {max_batch}");
        assert!(stats.mean_batch > 1.0);
    }

    /// Satellite: N client threads submitting through concurrent batch
    /// executors — every request is answered exactly once, the served
    /// count matches, and the summary statistics stay finite and sane.
    #[test]
    fn concurrent_executors_answer_every_request_exactly_once() {
        let res = 32;
        let (clients, per_client) = (4usize, 4usize);
        let server = Arc::new(Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 3,
                adaptive: false,
            },
        ));
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let server = Arc::clone(&server);
                std::thread::spawn(move || {
                    let mut replies = 0usize;
                    for i in 0..per_client {
                        let rx = server.submit(image(res, (c * per_client + i) as u64));
                        let reply = rx.recv().expect("reply");
                        assert_eq!(reply.logits.len(), 1000);
                        assert!(reply.logits.iter().all(|v| v.is_finite()));
                        assert!(reply.batch >= 1 && reply.batch <= 2);
                        // Exactly once: the reply channel yields one
                        // reply and then hangs up.
                        assert!(reply.latency > Duration::ZERO);
                        assert!(rx.try_recv().is_err(), "duplicate reply");
                        replies += 1;
                    }
                    replies
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, clients * per_client);
        let server = Arc::into_inner(server).expect("all clients joined");
        let stats = server.shutdown();
        assert_eq!(stats.served, clients * per_client);
        assert!(stats.latency.mean.is_finite() && stats.latency.mean > 0.0);
        assert!(stats.latency.p95.is_finite());
        assert!(
            stats.mean_batch.is_finite() && stats.mean_batch >= 1.0 && stats.mean_batch <= 2.0,
            "mean batch {} out of range",
            stats.mean_batch
        );
        assert!(stats.throughput_rps > 0.0);
    }

    /// Determinism across executor counts: the same requests produce the
    /// same logits whether one or three executors serve them (caps and
    /// concurrency are scheduling decisions, never numerics).
    #[test]
    fn concurrent_executors_match_single_executor_logits() {
        let res = 32;
        let run = |executors: usize| -> Vec<Vec<f32>> {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::dense_cnhw(ThreadPool::shared(2)),
                res,
                ServerConfig {
                    batch_sizes: vec![1],
                    batch_window: Duration::from_millis(1),
                    executors,
                    adaptive: false,
                },
            );
            let rxs: Vec<_> = (0..4).map(|i| server.submit(image(res, i))).collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().logits).collect();
            server.shutdown();
            out
        };
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn shutdown_drains_pending() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(1)),
            res,
            ServerConfig {
                batch_sizes: vec![1],
                batch_window: Duration::from_millis(1),
                executors: 1,
                adaptive: false,
            },
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(image(res, i))).collect();
        let stats = server.shutdown();
        assert_eq!(stats.served, 3);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    /// Regression (satellite bugfix): when fewer requests are pending
    /// than the smallest compiled batch size, the batch is zero-padded
    /// instead of panicking on the Input-op shape assert — and the real
    /// rows' logits are bitwise what a hand-padded direct run produces.
    #[test]
    fn fewer_requests_than_smallest_batch_are_padded_not_dropped() {
        let res = 32;
        let exec_cfg = ExecConfig::dense_cnhw(ThreadPool::shared(2));
        let direct = Executor::new(build_model(ModelArch::ResNet18, 4, res), exec_cfg.clone());
        for n in 1..=3usize {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                exec_cfg.clone(),
                res,
                ServerConfig {
                    batch_sizes: vec![4],
                    batch_window: Duration::from_millis(2),
                    executors: 1,
                    adaptive: false,
                },
            );
            let images: Vec<Tensor> = (0..n).map(|i| image(res, 100 + i as u64)).collect();
            let rxs: Vec<_> = images.iter().map(|im| server.submit(im.clone())).collect();
            for (im, rx) in images.iter().zip(rxs) {
                let reply = rx.recv().expect("padded batch must still reply");
                assert_eq!(reply.logits.len(), 1000);
                assert_eq!(reply.batch, 4, "served on the padded batch-4 executor");
                assert!(rx.try_recv().is_err(), "exactly one reply");
                // Per-sample independence: a request's logits equal a
                // direct batch-4 run with that image in row 0 and the
                // other rows zero-padded, bitwise.
                let mut padded = Tensor::zeros(&[4, res, res, 3]);
                padded.data[..im.data.len()].copy_from_slice(&im.data);
                let want = direct.run(&padded);
                assert_eq!(reply.logits, want.data[..1000].to_vec(), "n={n}");
            }
            let stats = server.shutdown();
            assert_eq!(stats.served, n, "n={n}");
            // The padded rows are not requests: latency samples count
            // only real ones.
            assert_eq!(stats.latency.n, n, "n={n}");
        }
    }

    /// Regression (satellite bugfix): a server that served nothing
    /// reports an explicitly empty latency summary — not a fabricated
    /// 0 ns sample — and every stat stays finite.
    #[test]
    fn zero_request_shutdown_reports_empty_stats() {
        let res = 32;
        for adaptive in [false, true] {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::dense_cnhw(ThreadPool::shared(2)),
                res,
                ServerConfig {
                    batch_sizes: vec![2, 4],
                    batch_window: Duration::from_millis(1),
                    executors: 2,
                    adaptive,
                },
            );
            let stats = server.shutdown();
            assert_eq!(stats.served, 0);
            assert_eq!(stats.latency.n, 0, "no fabricated samples");
            assert_eq!(stats.latency.mean, 0.0);
            assert_eq!(stats.throughput_rps, 0.0);
            assert_eq!(stats.mean_batch, 0.0);
            assert!(stats.cap_range.is_none());
            for v in [
                stats.latency.stddev,
                stats.latency.min,
                stats.latency.max,
                stats.latency.median,
                stats.latency.p95,
            ] {
                assert!(v == 0.0, "adaptive={adaptive}: NaN/garbage in empty summary");
            }
        }
    }

    /// Tentpole: adaptive mode answers every request exactly once with
    /// logits bitwise identical to static mode, and records the caps it
    /// chose.
    #[test]
    fn adaptive_mode_matches_static_logits_and_records_caps() {
        let res = 32;
        let run = |adaptive: bool| -> (Vec<Vec<f32>>, ServerStats) {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5),
                res,
                ServerConfig {
                    batch_sizes: vec![2, 4],
                    batch_window: Duration::from_millis(2),
                    executors: 2,
                    adaptive,
                },
            );
            let rxs: Vec<_> = (0..12).map(|i| server.submit(image(res, i))).collect();
            let logits: Vec<Vec<f32>> = rxs
                .into_iter()
                .map(|rx| {
                    let reply = rx.recv().expect("reply");
                    assert!(rx.try_recv().is_err(), "duplicate reply");
                    reply.logits
                })
                .collect();
            let stats = server.shutdown();
            assert_eq!(stats.served, 12);
            (logits, stats)
        };
        let (static_logits, static_stats) = run(false);
        let (adaptive_logits, adaptive_stats) = run(true);
        assert_eq!(static_logits, adaptive_logits, "modes must agree bitwise");
        assert!(static_stats.cap_range.is_none());
        let (lo, hi) = adaptive_stats.cap_range.expect("adaptive records caps");
        assert!(lo >= 1 && hi <= 4, "caps within pool bounds: {lo}..{hi}");
    }

    /// The adaptive controller itself: deep queues slice the pool,
    /// shallow queues hand a lone batch the whole pool, and the number
    /// of dispatchers worth waking scales with depth.
    #[test]
    fn adaptive_controller_cap_and_parking_policy() {
        // Idle server, empty queue → lone batch gets the whole pool.
        assert_eq!(adaptive_cap(0, 0, 4, 2, 8), 8);
        // A full extra batch queued → two overlap → half the pool each.
        assert_eq!(adaptive_cap(0, 4, 4, 2, 8), 4);
        // Another dispatcher already computing → same split, even with
        // an empty queue.
        assert_eq!(adaptive_cap(1, 0, 4, 2, 8), 4);
        // Very deep queue → clamped to the dispatcher count, not below
        // one worker.
        assert_eq!(adaptive_cap(0, 100, 4, 2, 8), 4);
        assert_eq!(adaptive_cap(0, 100, 4, 4, 2), 1);
        // Parking: shallow queues keep one drainer; queued work or a
        // busy dispatcher wakes more; never more than exist.
        assert_eq!(desired_active(0, 0, 4, 3), 1);
        assert_eq!(desired_active(0, 1, 4, 3), 1);
        // A request arriving while the lone awake dispatcher computes
        // must wake a second one — busy counts toward desired.
        assert_eq!(desired_active(1, 1, 4, 3), 2);
        assert_eq!(desired_active(0, 5, 4, 3), 2);
        assert_eq!(desired_active(2, 100, 4, 3), 3);
    }

    /// Parked dispatchers must wake for bursts and for shutdown: a
    /// 3-executor adaptive server under a trickle-then-burst load
    /// answers everything and exits cleanly.
    #[test]
    fn adaptive_parked_dispatchers_wake_on_burst_and_shutdown() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(4)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 3,
                adaptive: true,
            },
        );
        // Trickle: one at a time (surplus dispatchers stay parked).
        for i in 0..3 {
            let rx = server.submit(image(res, i));
            assert_eq!(rx.recv().expect("trickle reply").logits.len(), 1000);
        }
        // Burst: all at once (parked dispatchers must wake to help).
        let rxs: Vec<_> = (10..20).map(|i| server.submit(image(res, i))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().expect("burst reply").logits.len(), 1000);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 13);
        let (lo, hi) = stats.cap_range.expect("caps recorded");
        assert!(lo >= 1 && hi <= 4);
    }
}
