//! Batching inference server: the request-path coordinator.
//!
//! Clients submit single-image NHWC requests — with a traffic class and
//! an optional deadline via [`Server::submit_with`] — and dispatcher
//! threads group them into batches and run them on pre-compiled
//! executors, one per supported batch size, mirroring how the AOT
//! artifacts are compiled per batch shape. When fewer requests are
//! pending than the smallest compiled batch (a trickle, or the shutdown
//! drain), the batch is zero-padded up to the smallest executor's size
//! and the padded rows' logits are discarded — a request always gets a
//! reply. Per-request latency (overall and per class), deadline misses,
//! aggregate throughput, and a batch-size histogram are recorded.
//!
//! # Ordered intake queue
//!
//! The dispatcher's source of truth is an ordered intake queue, not a
//! bare channel. Under [`QueueDiscipline::Priority`] requests pop in
//! (priority, deadline, FIFO) order — `Interactive` before `Batch`,
//! earlier deadlines first within the interactive class, submission
//! order as the tie break (the background class is FIFO within itself,
//! which keeps starvation protection exact) — with starvation
//! protection: the *oldest* background request is served ahead of
//! interactive traffic once it has queued longer than
//! `ServerConfig::starvation_limit`. Under [`QueueDiscipline::Fifo`]
//! (the default) classes and
//! deadlines are recorded for stats but ignored for ordering, which is
//! the baseline the priority mode is compared against — scheduling is
//! pure, so logits are bitwise identical between the two disciplines.
//!
//! # Concurrent batch executors
//!
//! `ServerConfig::executors` starts that many dispatcher threads, all
//! draining the one intake queue and all running batches on the *same*
//! persistent [`ThreadPool`](crate::util::ThreadPool): while one batch
//! computes, another forms and starts. Oversubscription is avoided on
//! two levels — the pool's worker set is fixed, and when no per-layer
//! tuning says otherwise the server caps each executor's GEMMs at
//! `pool size / executors` participants so concurrent batches slice the
//! pool instead of queueing a full pool's worth of jobs each.
//!
//! # Zero-alloc steady state (the memory plane)
//!
//! Each dispatcher thread owns one [`ScratchArena`] and one staging
//! input tensor per compiled batch size, allocated once at startup.
//! Batches are staged and executed entirely inside them
//! ([`Executor::run_capped_in`]), so the compute plane performs no
//! heap allocation in steady state. The claim is measured, not
//! assumed: every batch's compute region runs under
//! [`allocwatch::scoped`] and the observed (allocs, bytes) pairs land
//! in `ServerStats::compute_allocs`, which `rust/tests/zero_alloc.rs`
//! checks under a counting global allocator. Reply transport (logit
//! copies, channel sends) allocates and deliberately stays outside
//! the measured region. Weights can come from an AOT-packed artifact
//! via [`Server::start_packed`], making model load a validation pass
//! instead of a re-pack.
//!
//! # Load-aware adaptive mode
//!
//! `ServerConfig::adaptive` makes three decisions *per drain*, all
//! implemented as pure functions in [`super::policy`] over a
//! [`QueueSnapshot`] assembled from the intake queue:
//!
//! 1. **Batch size** ([`policy::choose_batch_size`]) — a shallow queue
//!    or a tight head deadline takes the smallest compiled batch
//!    (latency mode; a tight head also skips the batching window), a
//!    deep queue with slack takes the largest (throughput mode).
//! 2. **Per-run thread cap** ([`policy::run_cap`]) — each batch
//!    executes under [`Executor::run_capped`] with the pool sliced by
//!    the expected number of overlapping batches; composes with
//!    per-layer tuned caps as a min, so tuning is never widened.
//! 3. **Active dispatchers** ([`policy::desired_active`]) — surplus
//!    dispatchers keep waiting on the intake condvar while the queue is
//!    shallow (one always stays live) and wake on submit bursts.
//!
//! The decisions are observable: `ServerStats::cap_range` reports the
//! min/max cap used, `ServerStats::batch_hist` the compiled batch sizes
//! chosen, and `NMPRUNE_SERVE_TRACE=1` prints one line per batch. All
//! of it is pure scheduling — logits are bitwise identical across
//! static/adaptive modes and FIFO/priority disciplines.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::models::Graph;
use crate::runtime::PackedArtifact;
use crate::tensor::Tensor;
use crate::util::stats::Summary;
use crate::util::{allocwatch, ThreadPool};

use super::executor::{ExecConfig, Executor};
use super::policy::{self, PolicyConfig, Priority, QueueDiscipline, QueueSnapshot};
use super::scratch::ScratchArena;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Supported batch sizes, ascending (executors prebuilt per size).
    pub batch_sizes: Vec<usize>,
    /// Max time the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Concurrent batch-executor (dispatcher) threads sharing the one
    /// intake queue and the one pool. 0 clamps to 1.
    pub executors: usize,
    /// Load-aware mode: derive the batch size, the per-run thread cap
    /// and the number of actively draining dispatchers from the queue
    /// gauge per drain, instead of fixed startup-time choices.
    pub adaptive: bool,
    /// Intake ordering: FIFO (default; classes/deadlines stats-only) or
    /// (priority, deadline, FIFO) with starvation protection.
    pub discipline: QueueDiscipline,
    /// Starvation protection: a queued background request older than
    /// this is served ahead of interactive traffic.
    pub starvation_limit: Duration,
    /// Head-of-queue deadline slack below which a drain optimises for
    /// latency (smallest compiled batch, no window fill).
    pub slack_floor: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            batch_sizes: vec![1, 2, 4],
            batch_window: Duration::from_millis(5),
            executors: 1,
            adaptive: false,
            discipline: QueueDiscipline::Fifo,
            starvation_limit: Duration::from_millis(100),
            slack_floor: Duration::from_millis(10),
        }
    }
}

struct Request {
    image: Tensor, // [H, W, C]
    enqueued: Instant,
    /// Absolute deadline (stats + Priority-discipline ordering).
    deadline: Option<Instant>,
    prio: Priority,
    reply: Sender<Reply>,
}

/// A completed inference.
pub struct Reply {
    pub logits: Vec<f32>,
    /// Queue + batching + compute latency.
    pub latency: Duration,
    /// Batch this request was served in (the compiled batch size — may
    /// exceed the number of real requests when the batch was padded).
    pub batch: usize,
    /// Whether the reply came after the request's deadline (always
    /// false for deadline-less requests).
    pub missed_deadline: bool,
}

/// One queued request plus its ordering key. Min-order is
/// (deadline, submission seq) with `None` deadlines after every
/// concrete one; the FIFO discipline stores `key_deadline = None`
/// everywhere, degenerating the order to pure submission seq.
struct Queued {
    key_deadline: Option<Instant>,
    seq: u64,
    req: Request,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        match (self.key_deadline, other.key_deadline) {
            (Some(a), Some(b)) => a.cmp(&b).then(self.seq.cmp(&other.seq)),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => self.seq.cmp(&other.seq),
        }
    }
}

/// The intake queue: two per-class min-heaps behind one mutex, plus
/// the condvar dispatchers wait on. The `interactive` heap orders by
/// (deadline, seq); the `background` heap orders by seq alone — FIFO
/// within the throughput class — so its head *is* the oldest arrival:
/// starvation promotion serves exactly the starved request (a
/// deadline-carrying newcomer can never jump an aged one and latch the
/// promotion into priority inversion), and the age check is an O(1)
/// peek. Under the FIFO discipline every request lands in the
/// `interactive` heap with a `None` ordering deadline — pure
/// submission order, classes recorded for stats only.
struct IntakeState {
    interactive: BinaryHeap<Reverse<Queued>>,
    background: BinaryHeap<Reverse<Queued>>,
    open: bool,
    seq: u64,
}

struct Intake {
    state: Mutex<IntakeState>,
    cvar: Condvar,
}

impl IntakeState {
    fn len(&self) -> usize {
        self.interactive.len() + self.background.len()
    }

    fn is_empty(&self) -> bool {
        self.interactive.is_empty() && self.background.is_empty()
    }

    /// Age of the oldest queued background request — an O(1) peek: the
    /// background heap is seq-ordered, so its head is the oldest
    /// arrival.
    fn oldest_background_wait(&self, now: Instant) -> Option<Duration> {
        self.background
            .peek()
            .map(|Reverse(q)| now.saturating_duration_since(q.req.enqueued))
    }

    /// Assemble the policy inputs under the intake lock. `busy` is the
    /// number of dispatchers currently computing (excluding the
    /// caller); `now` is sampled once by the caller so one snapshot is
    /// internally consistent.
    fn snapshot(&self, busy: usize, now: Instant) -> QueueSnapshot {
        let head = self.interactive.peek().or_else(|| self.background.peek());
        QueueSnapshot {
            depth: self.len(),
            busy,
            head_slack: head
                .and_then(|Reverse(q)| q.req.deadline)
                .map(|d| d.saturating_duration_since(now)),
            oldest_background_wait: self.oldest_background_wait(now),
        }
    }

    /// Pop the next request in policy order: interactive first, unless
    /// starvation protection promotes the background class this pop —
    /// and then the promoted request is exactly the oldest background
    /// arrival (the seq-ordered heap's head), so serving it clears the
    /// promotion instead of latching it into priority inversion. The
    /// age check runs only when both classes are actually queued.
    fn pop_next(&mut self, pcfg: &PolicyConfig, now: Instant) -> Option<Request> {
        let heap = if self.interactive.is_empty() {
            &mut self.background
        } else if self.background.is_empty() {
            &mut self.interactive
        } else {
            let snap = QueueSnapshot {
                oldest_background_wait: self.oldest_background_wait(now),
                ..QueueSnapshot::default()
            };
            if policy::promote_background(pcfg, &snap) {
                &mut self.background
            } else {
                &mut self.interactive
            }
        };
        heap.pop().map(|Reverse(q)| q.req)
    }
}

#[derive(Default)]
struct StatsInner {
    latencies_ns: Vec<f64>,
    /// Per-class latency samples, indexed by `Priority::index()`.
    class_latencies_ns: [Vec<f64>; Priority::COUNT],
    /// Per-class requests that carried a deadline / missed it.
    deadline_total: [usize; Priority::COUNT],
    deadline_missed: [usize; Priority::COUNT],
    batches: Vec<usize>,
    /// Compiled batch size → number of batches executed at that size.
    batch_hist: BTreeMap<usize, usize>,
    /// Per-batch chosen per-run thread cap (adaptive mode only).
    caps: Vec<usize>,
    /// Per-batch compute-plane heap traffic (allocs, bytes), in batch
    /// completion order. All zero unless a counting global allocator
    /// is registered (see `util::allocwatch`).
    compute: Vec<(u64, u64)>,
    started: Option<Instant>,
    finished: Option<Instant>,
    served: usize,
}

/// Per-traffic-class serving statistics.
#[derive(Clone, Debug)]
pub struct ClassStats {
    pub served: usize,
    /// Empty (`n == 0`) when the class served nothing.
    pub latency: Summary,
    /// Requests of this class that carried a deadline.
    pub deadline_total: usize,
    /// …and how many of those were answered after it.
    pub deadline_missed: usize,
}

impl ClassStats {
    /// Fraction of deadline-carrying requests answered late (0.0 when
    /// none carried a deadline).
    pub fn miss_rate(&self) -> f64 {
        if self.deadline_total == 0 {
            0.0
        } else {
            self.deadline_missed as f64 / self.deadline_total as f64
        }
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub served: usize,
    /// Empty (`n == 0`, all zeros) when nothing was served — never a
    /// fabricated 0 ns sample.
    pub latency: Summary,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// Min/max per-run thread cap chosen across batches; `None` in
    /// static mode or when no batch ran.
    pub cap_range: Option<(usize, usize)>,
    /// Per-class latency summaries and deadline-miss counts, indexed by
    /// `Priority::index()`.
    pub per_class: [ClassStats; Priority::COUNT],
    /// (compiled batch size, batches executed at that size), ascending —
    /// the observable trace of the gauge-driven batch-size policy.
    pub batch_hist: Vec<(usize, usize)>,
    /// Per-batch compute-plane heap traffic (allocations, bytes), in
    /// batch completion order — the observable proof of zero-alloc
    /// steady-state serving. Entries are measured only when a counting
    /// global allocator is registered (the zero-alloc integration test
    /// does); they are all zero otherwise.
    pub compute_allocs: Vec<(u64, u64)>,
}

impl ServerStats {
    pub fn class(&self, p: Priority) -> &ClassStats {
        &self.per_class[p.index()]
    }
}

/// The serving engine.
pub struct Server {
    intake: Arc<Intake>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    discipline: QueueDiscipline,
    res: usize,
}

/// Everything a dispatcher thread needs, shared across all of them.
struct Dispatch {
    intake: Arc<Intake>,
    executors: Arc<Vec<(usize, Executor)>>,
    window: Duration,
    stats: Arc<Mutex<StatsInner>>,
    /// Dispatchers currently computing a batch.
    busy: AtomicUsize,
    res: usize,
    adaptive: bool,
    pcfg: PolicyConfig,
    trace: bool,
}

impl Server {
    /// Build executors for every configured batch size and start
    /// `cfg.executors` dispatcher threads. `make_graph(batch)` supplies
    /// the model graph; `exec` is the (shared) execution config; `res`
    /// the input resolution.
    pub fn start<F: Fn(usize) -> Graph>(
        make_graph: F,
        exec: ExecConfig,
        res: usize,
        cfg: ServerConfig,
    ) -> Self {
        assert!(!cfg.batch_sizes.is_empty());
        let mut sizes = cfg.batch_sizes.clone();
        sizes.sort_unstable();
        let n_exec = cfg.executors.max(1);
        let pool_size = exec.pool.size();
        let mut exec = exec;
        if !cfg.adaptive && n_exec > 1 && exec.default_choice.threads == 0 {
            // Static mode with several executors on one pool: slice it
            // so a batch's GEMMs occupy pool/executors workers and
            // concurrent batches run beside each other instead of
            // queueing a full pool's worth of jobs each. Explicit
            // per-layer tuning (per_layer entries / a preset default
            // cap) is respected. Adaptive mode skips this: the slice is
            // decided per batch from the queue gauge instead.
            exec.default_choice.threads = pool_size.div_ceil(n_exec).max(1);
        }
        let executors = sizes
            .iter()
            .map(|&b| (b, Executor::new(make_graph(b), exec.clone())))
            .collect();
        Self::start_with(executors, pool_size, res, cfg)
    }

    /// [`Server::start`] from an AOT-packed weight artifact: executors
    /// are built with [`Executor::from_artifact`] — a validation pass
    /// over frozen weights and tuning choices, not a re-pack — so model
    /// load is fast and any graph/artifact mismatch is a
    /// [`RuntimeError`](crate::runtime::RuntimeError) instead of a
    /// silently different model. One artifact serves every compiled
    /// batch size (weights are batch-independent). The artifact's
    /// per-layer thread caps are tuned state and are never widened, so
    /// the static-mode pool-slicing heuristic of [`Server::start`] does
    /// not apply here.
    pub fn start_packed<F: Fn(usize) -> Graph>(
        make_graph: F,
        pool: Arc<ThreadPool>,
        art: &PackedArtifact,
        cfg: ServerConfig,
    ) -> crate::runtime::Result<Self> {
        assert!(!cfg.batch_sizes.is_empty());
        let mut sizes = cfg.batch_sizes.clone();
        sizes.sort_unstable();
        let mut executors = Vec::new();
        for &b in &sizes {
            let exec = Executor::from_artifact(make_graph(b), Arc::clone(&pool), art)?;
            executors.push((b, exec));
        }
        Ok(Self::start_with(executors, pool.size(), art.res, cfg))
    }

    /// Common tail of the constructors: start the dispatcher threads
    /// over prebuilt per-batch-size executors (ascending sizes).
    fn start_with(
        executors: Vec<(usize, Executor)>,
        pool_size: usize,
        res: usize,
        cfg: ServerConfig,
    ) -> Self {
        let sizes: Vec<usize> = executors.iter().map(|&(b, _)| b).collect();
        let n_exec = cfg.executors.max(1);
        let executors = Arc::new(executors);
        let intake = Arc::new(Intake {
            state: Mutex::new(IntakeState {
                interactive: BinaryHeap::new(),
                background: BinaryHeap::new(),
                open: true,
                seq: 0,
            }),
            cvar: Condvar::new(),
        });
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let ctx = Arc::new(Dispatch {
            intake: Arc::clone(&intake),
            executors,
            window: cfg.batch_window,
            stats: Arc::clone(&stats),
            busy: AtomicUsize::new(0),
            res,
            adaptive: cfg.adaptive,
            pcfg: PolicyConfig {
                batch_sizes: sizes,
                n_exec,
                pool_size,
                starvation_limit: cfg.starvation_limit,
                slack_floor: cfg.slack_floor,
            },
            // Shared flag convention: ""/"0"/"false" are off.
            trace: crate::util::env::flag("NMPRUNE_SERVE_TRACE"),
        });
        let workers = (0..n_exec)
            .map(|idx| {
                let ctx = Arc::clone(&ctx);
                // nmprune-lint: allow(S1) -- one long-lived dispatcher per executor, joined on Drop
                std::thread::spawn(move || dispatcher(&ctx, idx))
            })
            .collect();
        Self {
            intake,
            workers,
            stats,
            discipline: cfg.discipline,
            res,
        }
    }

    /// Submit one image `[H, W, C]` as interactive, deadline-less
    /// traffic; returns a handle to await the reply.
    pub fn submit(&self, image: Tensor) -> Receiver<Reply> {
        self.submit_with(image, Priority::Interactive, None)
    }

    /// Submit one image `[H, W, C]` with a traffic class and an
    /// optional deadline (relative to now). Under the Priority
    /// discipline the deadline orders the interactive class (the
    /// background class stays FIFO so starvation protection is exact);
    /// deadlines are tracked in the miss stats under both disciplines.
    pub fn submit_with(
        &self,
        image: Tensor,
        prio: Priority,
        deadline: Option<Duration>,
    ) -> Receiver<Reply> {
        assert_eq!(image.shape, vec![self.res, self.res, 3], "image shape");
        let (reply_tx, reply_rx) = channel();
        let now = Instant::now();
        let req = Request {
            image,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            prio,
            reply: reply_tx,
        };
        {
            let mut st = self.intake.state.lock().unwrap();
            assert!(st.open, "server stopped");
            let seq = st.seq;
            st.seq += 1;
            // Ordering key: deadlines order the *interactive* class
            // under the Priority discipline. The background class is
            // FIFO (seq-only) so starvation protection stays exact —
            // see the `IntakeState` doc; the FIFO discipline ignores
            // deadlines for ordering entirely. Deadlines always count
            // toward miss stats regardless.
            let key_deadline = match (self.discipline, prio) {
                (QueueDiscipline::Priority, Priority::Interactive) => req.deadline,
                _ => None,
            };
            let queued = Queued {
                key_deadline,
                seq,
                req,
            };
            match (self.discipline, prio) {
                // FIFO: one seq-ordered queue regardless of class.
                (QueueDiscipline::Fifo, _) | (_, Priority::Interactive) => {
                    st.interactive.push(Reverse(queued))
                }
                (QueueDiscipline::Priority, Priority::Batch) => {
                    st.background.push(Reverse(queued))
                }
            }
        }
        // Wake dispatchers (parked ones included) outside the lock;
        // waiters re-check their predicates, so notify_all is safe.
        self.intake.cvar.notify_all();
        reply_rx
    }

    /// Drain and stop the server, returning aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        {
            let mut st = self.intake.state.lock().unwrap();
            st.open = false; // dispatchers drain then exit
        }
        self.intake.cvar.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let inner = self.stats.lock().unwrap();
        let wall = match (inner.started, inner.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        let summarise = |samples: &[f64]| {
            if samples.is_empty() {
                // Nothing served: report an explicitly empty summary
                // instead of fabricating a 0 ns request.
                Summary::empty()
            } else {
                Summary::of(samples)
            }
        };
        ServerStats {
            served: inner.served,
            latency: summarise(&inner.latencies_ns),
            throughput_rps: if wall > 0.0 {
                inner.served as f64 / wall
            } else {
                0.0
            },
            mean_batch: if inner.batches.is_empty() {
                0.0
            } else {
                inner.batches.iter().sum::<usize>() as f64 / inner.batches.len() as f64
            },
            cap_range: inner
                .caps
                .iter()
                .copied()
                .fold(None, |acc: Option<(usize, usize)>, c| match acc {
                    None => Some((c, c)),
                    Some((lo, hi)) => Some((lo.min(c), hi.max(c))),
                }),
            per_class: Priority::ALL.map(|p| {
                let i = p.index();
                ClassStats {
                    served: inner.class_latencies_ns[i].len(),
                    latency: summarise(&inner.class_latencies_ns[i]),
                    deadline_total: inner.deadline_total[i],
                    deadline_missed: inner.deadline_missed[i],
                }
            }),
            batch_hist: inner.batch_hist.iter().map(|(&b, &n)| (b, n)).collect(),
            compute_allocs: inner.compute.clone(),
        }
    }
}

/// One batch-executor thread. Several of these may drain the same
/// intake queue: pops happen under the intake mutex, so each request is
/// delivered to exactly one dispatcher and every request is answered
/// exactly once regardless of how many executors run.
fn dispatcher(ctx: &Dispatch, idx: usize) {
    // Bounded re-check interval for waiting dispatchers (never 0, or a
    // missed predicate change could strand them).
    let poll = ctx.window.max(Duration::from_millis(1));
    // The memory plane: one scratch arena and one staging input tensor
    // per compiled batch size, owned by this dispatcher thread for its
    // lifetime. Steady-state batches are staged and executed entirely
    // inside them — the compute plane never touches the heap.
    let mut arenas: Vec<ScratchArena> = ctx.executors.iter().map(|(_, e)| e.scratch()).collect();
    let mut staged: Vec<Tensor> = ctx
        .executors
        .iter()
        .map(|&(b, _)| Tensor::zeros(&[b, ctx.res, ctx.res, 3]))
        .collect();
    // Requests drained in an earlier iteration beyond what that
    // iteration's executor could take (a group size strictly between
    // two compiled batch sizes). They are served first next iteration —
    // they were popped in policy order and have waited longest.
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Phase 1 — wait for work (skipped while carried requests are
        // in hand). Parked surplus dispatchers (adaptive mode, idx
        // beyond the policy's desired_active) keep waiting even while
        // work is queued; dispatcher 0 never parks, and shutdown
        // (open = false) overrides parking so everyone helps drain.
        let mut st = ctx.intake.state.lock().unwrap();
        while pending.is_empty() {
            if st.is_empty() {
                if !st.open {
                    return;
                }
            } else if !(ctx.adaptive && idx > 0 && st.open) {
                break;
            } else {
                let snap = st.snapshot(ctx.busy.load(Ordering::Acquire), Instant::now());
                if policy::desired_active(&ctx.pcfg, &snap) > idx {
                    break;
                }
            }
            st = ctx.intake.cvar.wait_timeout(st, poll).unwrap().0;
        }
        // Phase 2 — per-drain policy decisions from one snapshot.
        // Carried requests count too: they sit ahead of the queue head,
        // so the effective depth includes them and the effective head
        // slack is the tightest deadline among them and the queue head
        // — a carried tight-deadline request must still trigger latency
        // mode instead of idling out a fresh batching window.
        let now = Instant::now();
        let mut snap = st.snapshot(ctx.busy.load(Ordering::Acquire), now);
        snap.depth += pending.len();
        if let Some(d) = pending.iter().filter_map(|r| r.deadline).min() {
            let carried_slack = d.saturating_duration_since(now);
            snap.head_slack = Some(match snap.head_slack {
                Some(s) => s.min(carried_slack),
                None => carried_slack,
            });
        }
        let (target, wait_fill) = if ctx.adaptive {
            (
                policy::choose_batch_size(&ctx.pcfg, &snap),
                policy::fill_window(&ctx.pcfg, &snap),
            )
        } else {
            (ctx.pcfg.max_batch(), true)
        };
        // Phase 3 — carried requests first, then drain up to `target`
        // in policy order; if underfull and allowed, wait out the
        // batching window for more arrivals (the condvar wait drops the
        // lock, so submits and the other dispatchers proceed
        // meanwhile).
        let fill_deadline = now + ctx.window;
        let mut group: Vec<Request> = std::mem::take(&mut pending);
        loop {
            while group.len() < target {
                match st.pop_next(&ctx.pcfg, Instant::now()) {
                    Some(r) => group.push(r),
                    None => break,
                }
            }
            if group.len() >= target || !st.open || !wait_fill {
                break;
            }
            let rem = fill_deadline.saturating_duration_since(Instant::now());
            if rem.is_zero() {
                break;
            }
            st = ctx.intake.cvar.wait_timeout(st, rem).unwrap().0;
        }
        // Phase 4 — per-run cap from the post-drain queue state: the
        // remaining depth plus the *other* dispatchers' in-flight
        // batches predict the overlap this batch will see.
        let run_cap = if ctx.adaptive {
            policy::run_cap(
                &ctx.pcfg,
                &st.snapshot(ctx.busy.load(Ordering::Acquire), Instant::now()),
            )
        } else {
            0
        };
        drop(st);
        if group.is_empty() {
            continue;
        }
        // Largest supported batch ≤ group — or, when even the smallest
        // compiled batch exceeds what was drained (trickle / shutdown
        // drain / latency mode), the smallest one zero-padded: the
        // executor's compiled input shape is always honoured and every
        // request is answered. A group size strictly *between* two
        // compiled sizes (window expiry or shutdown drain with e.g. 3
        // pending against sizes [2, 4]) serves the largest fitting
        // batch and carries the surplus to the next iteration — never
        // overrunning the compiled shape, never dropping a request.
        let ei = ctx
            .executors
            .iter()
            .rposition(|(b, _)| *b <= group.len())
            .unwrap_or(0);
        let (batch, exec) = &ctx.executors[ei];
        let batch = *batch;
        let take = group.len().min(batch);
        pending = group.split_off(take);
        let per = ctx.res * ctx.res * 3;
        let t0 = Instant::now();
        {
            let mut s = ctx.stats.lock().unwrap();
            // Keep the earliest start across racing dispatchers.
            s.started = Some(s.started.map_or(t0, |prev| prev.min(t0)));
        }
        ctx.busy.fetch_add(1, Ordering::AcqRel);
        // The compute plane: stage the batch into this dispatcher's
        // preallocated input tensor and run inside its arena. The
        // scoped region measures heap traffic (all zero in steady
        // state when a counting allocator is registered); the reply
        // transport below — logit copies, channel sends — allocates
        // and deliberately sits outside it.
        let arena = &mut arenas[ei];
        let input = &mut staged[ei];
        let (logits, mem) = allocwatch::scoped(|| {
            for (i, r) in group.iter().enumerate() {
                input.data[i * per..(i + 1) * per].copy_from_slice(&r.image.data);
            }
            // Rows [take, batch) are padding: clear any residue from
            // the previous batch staged in this tensor so the padded
            // rows' (discarded) logits stay deterministic.
            input.data[take * per..].fill(0.0);
            exec.run_capped_in(input, run_cap, arena)
        });
        ctx.busy.fetch_sub(1, Ordering::AcqRel);
        let done = Instant::now();
        if ctx.trace {
            eprintln!(
                "[serve] exec={idx} batch={batch} real={take} target={target} cap={run_cap}"
            );
        }
        let classes = logits.shape[1];
        let mut s = ctx.stats.lock().unwrap();
        // Keep the latest finish: with concurrent executors a batch that
        // completed *before* us may lock *after* us — blindly storing
        // our timestamp could rewind the measured wall clock and
        // inflate throughput_rps.
        s.finished = Some(s.finished.map_or(done, |prev| prev.max(done)));
        if ctx.adaptive {
            s.caps.push(run_cap);
        }
        *s.batch_hist.entry(batch).or_insert(0) += 1;
        s.compute.push((mem.allocs, mem.bytes));
        for (i, r) in group.into_iter().enumerate() {
            let latency = done - r.enqueued;
            let missed = r.deadline.is_some_and(|d| done > d);
            let ci = r.prio.index();
            s.latencies_ns.push(latency.as_nanos() as f64);
            s.class_latencies_ns[ci].push(latency.as_nanos() as f64);
            if r.deadline.is_some() {
                s.deadline_total[ci] += 1;
                if missed {
                    s.deadline_missed[ci] += 1;
                }
            }
            // Batching efficiency counts *real* requests per batch: a
            // padded trickle must report mean_batch 1.0, not the
            // compiled size (Reply::batch still carries the latter).
            s.batches.push(take);
            s.served += 1;
            let _ = r.reply.send(Reply {
                logits: logits.data[i * classes..(i + 1) * classes].to_vec(),
                latency,
                batch,
                missed_deadline: missed,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_model, ModelArch};
    use crate::util::{ThreadPool, XorShiftRng};

    fn image(res: usize, seed: u64) -> Tensor {
        let mut r = XorShiftRng::new(seed);
        Tensor::random(&[res, res, 3], &mut r, 0.0, 1.0)
    }

    #[test]
    fn serves_requests_and_reports_stats() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                ..ServerConfig::default()
            },
        );
        let replies: Vec<_> = (0..6).map(|i| server.submit(image(res, i))).collect();
        for r in replies {
            let reply = r.recv().expect("reply");
            assert_eq!(reply.logits.len(), 1000);
            assert!(reply.batch >= 1 && reply.batch <= 2);
            assert!(!reply.missed_deadline, "deadline-less requests never miss");
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 6);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency.mean > 0.0);
        assert!(stats.cap_range.is_none(), "static mode records no caps");
        // Default submissions are interactive and deadline-less.
        assert_eq!(stats.class(Priority::Interactive).served, 6);
        assert_eq!(stats.class(Priority::Batch).served, 0);
        assert_eq!(stats.class(Priority::Interactive).deadline_total, 0);
        assert_eq!(stats.class(Priority::Interactive).miss_rate(), 0.0);
        // The histogram accounts for every served request.
        let hist_total: usize = stats.batch_hist.iter().map(|&(b, n)| b * n).sum();
        assert!(hist_total >= 6, "histogram covers all batches (padding included)");
        // One compute-plane sample per executed batch; without a
        // registered counting allocator they all read zero (the
        // instrumentation is inert in this binary).
        let batches: usize = stats.batch_hist.iter().map(|&(_, n)| n).sum();
        assert_eq!(stats.compute_allocs.len(), batches);
        assert!(stats.compute_allocs.iter().all(|&s| s == (0, 0)));
    }

    #[test]
    fn batches_form_under_load() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2, 4],
                batch_window: Duration::from_millis(50),
                ..ServerConfig::default()
            },
        );
        // Burst of 8 requests: with a generous window, batches of 4 form.
        let replies: Vec<_> = (0..8).map(|i| server.submit(image(res, i))).collect();
        let mut max_batch = 0;
        for r in replies {
            max_batch = max_batch.max(r.recv().unwrap().batch);
        }
        let stats = server.shutdown();
        assert!(max_batch >= 2, "expected batching, got max batch {max_batch}");
        assert!(stats.mean_batch > 1.0);
        assert!(
            stats.batch_hist.iter().any(|&(b, _)| b >= 2),
            "histogram records the formed batches: {:?}",
            stats.batch_hist
        );
    }

    /// N client threads submitting through concurrent batch executors —
    /// every request is answered exactly once, the served count
    /// matches, and the summary statistics stay finite and sane.
    #[test]
    fn concurrent_executors_answer_every_request_exactly_once() {
        let res = 32;
        let (clients, per_client) = (4usize, 4usize);
        let server = Arc::new(Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 3,
                ..ServerConfig::default()
            },
        ));
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let server = Arc::clone(&server);
                // nmprune-lint: allow(S1) -- test-only load generator, joined below
                std::thread::spawn(move || {
                    let mut replies = 0usize;
                    for i in 0..per_client {
                        let rx = server.submit(image(res, (c * per_client + i) as u64));
                        let reply = rx.recv().expect("reply");
                        assert_eq!(reply.logits.len(), 1000);
                        assert!(reply.logits.iter().all(|v| v.is_finite()));
                        assert!(reply.batch >= 1 && reply.batch <= 2);
                        // Exactly once: the reply channel yields one
                        // reply and then hangs up.
                        assert!(reply.latency > Duration::ZERO);
                        assert!(rx.try_recv().is_err(), "duplicate reply");
                        replies += 1;
                    }
                    replies
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, clients * per_client);
        let server = Arc::into_inner(server).expect("all clients joined");
        let stats = server.shutdown();
        assert_eq!(stats.served, clients * per_client);
        assert!(stats.latency.mean.is_finite() && stats.latency.mean > 0.0);
        assert!(stats.latency.p95.is_finite());
        assert!(
            stats.mean_batch.is_finite() && stats.mean_batch >= 1.0 && stats.mean_batch <= 2.0,
            "mean batch {} out of range",
            stats.mean_batch
        );
        assert!(stats.throughput_rps > 0.0);
    }

    /// Determinism across executor counts: the same requests produce the
    /// same logits whether one or three executors serve them (caps and
    /// concurrency are scheduling decisions, never numerics).
    #[test]
    fn concurrent_executors_match_single_executor_logits() {
        let res = 32;
        let run = |executors: usize| -> Vec<Vec<f32>> {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::dense_cnhw(ThreadPool::shared(2)),
                res,
                ServerConfig {
                    batch_sizes: vec![1],
                    batch_window: Duration::from_millis(1),
                    executors,
                    ..ServerConfig::default()
                },
            );
            let rxs: Vec<_> = (0..4).map(|i| server.submit(image(res, i))).collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().logits).collect();
            server.shutdown();
            out
        };
        assert_eq!(run(1), run(3));
    }

    #[test]
    fn shutdown_drains_pending() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(1)),
            res,
            ServerConfig {
                batch_sizes: vec![1],
                batch_window: Duration::from_millis(1),
                ..ServerConfig::default()
            },
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(image(res, i))).collect();
        let stats = server.shutdown();
        assert_eq!(stats.served, 3);
        for rx in rxs {
            assert!(rx.try_recv().is_ok());
        }
    }

    /// Regression: when fewer requests are pending than the smallest
    /// compiled batch size, the batch is zero-padded instead of
    /// panicking on the Input-op shape assert — and the real rows'
    /// logits are bitwise what a hand-padded direct run produces.
    #[test]
    fn fewer_requests_than_smallest_batch_are_padded_not_dropped() {
        let res = 32;
        let exec_cfg = ExecConfig::dense_cnhw(ThreadPool::shared(2));
        let direct = Executor::new(build_model(ModelArch::ResNet18, 4, res), exec_cfg.clone());
        for n in 1..=3usize {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                exec_cfg.clone(),
                res,
                ServerConfig {
                    batch_sizes: vec![4],
                    batch_window: Duration::from_millis(2),
                    ..ServerConfig::default()
                },
            );
            let images: Vec<Tensor> = (0..n).map(|i| image(res, 100 + i as u64)).collect();
            let rxs: Vec<_> = images.iter().map(|im| server.submit(im.clone())).collect();
            for (im, rx) in images.iter().zip(rxs) {
                let reply = rx.recv().expect("padded batch must still reply");
                assert_eq!(reply.logits.len(), 1000);
                assert_eq!(reply.batch, 4, "served on the padded batch-4 executor");
                assert!(rx.try_recv().is_err(), "exactly one reply");
                // Per-sample independence: a request's logits equal a
                // direct batch-4 run with that image in row 0 and the
                // other rows zero-padded, bitwise.
                let mut padded = Tensor::zeros(&[4, res, res, 3]);
                padded.data[..im.data.len()].copy_from_slice(&im.data);
                let want = direct.run(&padded);
                assert_eq!(reply.logits, want.data[..1000].to_vec(), "n={n}");
            }
            let stats = server.shutdown();
            assert_eq!(stats.served, n, "n={n}");
            // The padded rows are not requests: latency samples count
            // only real ones.
            assert_eq!(stats.latency.n, n, "n={n}");
        }
    }

    /// Regression (review finding): a drained group whose size falls
    /// strictly *between* two compiled batch sizes — 3 requests against
    /// sizes [2, 4] at the shutdown drain — must serve the largest
    /// fitting batch and carry the surplus to the next drain, not
    /// overrun the compiled input shape (which panicked the dispatcher
    /// and dropped all three replies).
    #[test]
    fn group_between_compiled_sizes_is_split_not_overrun() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![2, 4],
                // Long window: the drain is still filling when shutdown
                // closes the intake with 3 pending.
                batch_window: Duration::from_millis(200),
                ..ServerConfig::default()
            },
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(image(res, 200 + i))).collect();
        let stats = server.shutdown();
        assert_eq!(stats.served, 3);
        for rx in rxs {
            let reply = rx.try_recv().expect("split drain must answer everyone");
            assert_eq!(reply.logits.len(), 1000);
            assert_eq!(reply.batch, 2, "both drains run on the batch-2 executor");
        }
        assert_eq!(
            stats.batch_hist,
            vec![(2, 2)],
            "3 requests split as 2 + 1(padded) on the batch-2 executor"
        );
    }

    /// Regression: a server that served nothing reports an explicitly
    /// empty latency summary — not a fabricated 0 ns sample — and every
    /// stat stays finite, per class included.
    #[test]
    fn zero_request_shutdown_reports_empty_stats() {
        let res = 32;
        for adaptive in [false, true] {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::dense_cnhw(ThreadPool::shared(2)),
                res,
                ServerConfig {
                    batch_sizes: vec![2, 4],
                    batch_window: Duration::from_millis(1),
                    executors: 2,
                    adaptive,
                    ..ServerConfig::default()
                },
            );
            let stats = server.shutdown();
            assert_eq!(stats.served, 0);
            assert_eq!(stats.latency.n, 0, "no fabricated samples");
            assert_eq!(stats.latency.mean, 0.0);
            assert_eq!(stats.throughput_rps, 0.0);
            assert_eq!(stats.mean_batch, 0.0);
            assert!(stats.cap_range.is_none());
            assert!(stats.batch_hist.is_empty());
            assert!(stats.compute_allocs.is_empty());
            for p in Priority::ALL {
                assert_eq!(stats.class(p).served, 0);
                assert_eq!(stats.class(p).latency.n, 0);
                assert_eq!(stats.class(p).miss_rate(), 0.0);
            }
            for v in [
                stats.latency.stddev,
                stats.latency.min,
                stats.latency.max,
                stats.latency.median,
                stats.latency.p95,
            ] {
                assert!(v == 0.0, "adaptive={adaptive}: NaN/garbage in empty summary");
            }
        }
    }

    /// Adaptive mode answers every request exactly once with logits
    /// bitwise identical to static mode, and records the caps and batch
    /// sizes it chose.
    #[test]
    fn adaptive_mode_matches_static_logits_and_records_caps() {
        let res = 32;
        let run = |adaptive: bool| -> (Vec<Vec<f32>>, ServerStats) {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5),
                res,
                ServerConfig {
                    batch_sizes: vec![2, 4],
                    batch_window: Duration::from_millis(2),
                    executors: 2,
                    adaptive,
                    ..ServerConfig::default()
                },
            );
            let rxs: Vec<_> = (0..12).map(|i| server.submit(image(res, i))).collect();
            let logits: Vec<Vec<f32>> = rxs
                .into_iter()
                .map(|rx| {
                    let reply = rx.recv().expect("reply");
                    assert!(rx.try_recv().is_err(), "duplicate reply");
                    reply.logits
                })
                .collect();
            let stats = server.shutdown();
            assert_eq!(stats.served, 12);
            (logits, stats)
        };
        let (static_logits, static_stats) = run(false);
        let (adaptive_logits, adaptive_stats) = run(true);
        assert_eq!(static_logits, adaptive_logits, "modes must agree bitwise");
        assert!(static_stats.cap_range.is_none());
        let (lo, hi) = adaptive_stats.cap_range.expect("adaptive records caps");
        assert!(lo >= 1 && hi <= 4, "caps within pool bounds: {lo}..{hi}");
        // Every batch size in the histogram is a compiled size.
        for &(b, _) in &adaptive_stats.batch_hist {
            assert!(b == 2 || b == 4, "unknown batch size {b} in histogram");
        }
    }

    /// Tentpole: a server loading its weights from an AOT-packed
    /// artifact answers with logits bitwise identical to the server
    /// that generates and packs them online — including at batch sizes
    /// the artifact was not packed at (batch-generic loading) — and a
    /// mismatched artifact is a load-time error, not a silently
    /// different model.
    #[test]
    fn packed_server_matches_online_logits() {
        let res = 32;
        let make = |b: usize| build_model(ModelArch::ResNet18, b, res);
        // Pack at batch 4; serve at sizes [1, 2].
        let art = Executor::new(make(4), ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5))
            .to_artifact();
        let scfg = || ServerConfig {
            batch_sizes: vec![1, 2],
            batch_window: Duration::from_millis(2),
            ..ServerConfig::default()
        };
        let collect = |server: Server| -> Vec<Vec<f32>> {
            let rxs: Vec<_> = (0..6).map(|i| server.submit(image(res, i))).collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().logits).collect();
            server.shutdown();
            out
        };
        let online = collect(Server::start(
            make,
            ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
            res,
            scfg(),
        ));
        let packed = collect(
            Server::start_packed(make, ThreadPool::shared(2), &art, scfg())
                .expect("artifact matches the serving graphs"),
        );
        assert_eq!(online, packed, "AOT-packed weights changed numerics");
        let err = Server::start_packed(
            |b| build_model(ModelArch::MobileNetV2, b, res),
            ThreadPool::shared(2),
            &art,
            scfg(),
        );
        assert!(err.is_err(), "mismatched artifact must fail at load");
    }

    /// Tentpole: mixed-priority traffic under the Priority discipline
    /// produces logits bitwise identical to the FIFO discipline, and the
    /// per-class stats attribute every request to its class.
    #[test]
    fn priority_discipline_matches_fifo_logits_with_per_class_stats() {
        let res = 32;
        let run = |discipline: QueueDiscipline| -> (Vec<Vec<f32>>, ServerStats) {
            let server = Server::start(
                |b| build_model(ModelArch::ResNet18, b, res),
                ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
                res,
                ServerConfig {
                    batch_sizes: vec![2, 4],
                    batch_window: Duration::from_millis(2),
                    executors: 2,
                    adaptive: true,
                    discipline,
                    ..ServerConfig::default()
                },
            );
            let rxs: Vec<_> = (0..10)
                .map(|i| {
                    let (prio, ddl) = if i % 2 == 0 {
                        (Priority::Interactive, Some(Duration::from_secs(30)))
                    } else {
                        (Priority::Batch, None)
                    };
                    server.submit_with(image(res, i), prio, ddl)
                })
                .collect();
            let logits = rxs
                .into_iter()
                .map(|rx| {
                    let reply = rx.recv().expect("reply");
                    assert!(rx.try_recv().is_err(), "exactly one reply");
                    reply.logits
                })
                .collect();
            (logits, server.shutdown())
        };
        let (fifo_logits, fifo_stats) = run(QueueDiscipline::Fifo);
        let (prio_logits, prio_stats) = run(QueueDiscipline::Priority);
        assert_eq!(fifo_logits, prio_logits, "discipline changed numerics");
        for stats in [&fifo_stats, &prio_stats] {
            assert_eq!(stats.served, 10);
            assert_eq!(stats.class(Priority::Interactive).served, 5);
            assert_eq!(stats.class(Priority::Batch).served, 5);
            // Generous 30 s deadlines: tracked, not missed.
            assert_eq!(stats.class(Priority::Interactive).deadline_total, 5);
            assert_eq!(stats.class(Priority::Interactive).deadline_missed, 0);
            assert_eq!(stats.class(Priority::Batch).deadline_total, 0);
        }
    }

    /// Deadline misses are counted: a deadline that already passed at
    /// submit time must be reported as missed in the reply and in the
    /// per-class stats, without affecting the logits.
    #[test]
    fn expired_deadlines_are_counted_as_missed() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(1),
                discipline: QueueDiscipline::Priority,
                ..ServerConfig::default()
            },
        );
        let rx_late =
            server.submit_with(image(res, 1), Priority::Interactive, Some(Duration::ZERO));
        let rx_ok =
            server.submit_with(image(res, 2), Priority::Interactive, Some(Duration::from_secs(30)));
        assert!(rx_late.recv().expect("reply").missed_deadline);
        assert!(!rx_ok.recv().expect("reply").missed_deadline);
        let stats = server.shutdown();
        let cls = stats.class(Priority::Interactive);
        assert_eq!(cls.deadline_total, 2);
        assert_eq!(cls.deadline_missed, 1);
        assert!((cls.miss_rate() - 0.5).abs() < 1e-12);
    }

    /// Regression (review finding): starvation promotion must serve the
    /// *oldest* background request and then clear — a deadline-carrying
    /// background newcomer must neither jump the aged request nor latch
    /// the promotion into serving background ahead of interactive
    /// forever. Pure pop-order test on the intake state: constructed
    /// timestamps, no threads, no sleeps.
    #[test]
    fn starvation_promotion_serves_oldest_background_then_clears() {
        let pcfg = PolicyConfig {
            batch_sizes: vec![1, 4],
            n_exec: 1,
            pool_size: 1,
            starvation_limit: Duration::from_millis(100),
            slack_floor: Duration::from_millis(10),
        };
        let now = Instant::now();
        // Tag requests by image length so pops are identifiable.
        let mk = |tag: usize, prio: Priority, enqueued: Instant, deadline: Option<Instant>| {
            let (tx, _rx) = channel();
            Request {
                image: Tensor::zeros(&[tag]),
                enqueued,
                deadline,
                prio,
                reply: tx,
            }
        };
        let mut st = IntakeState {
            interactive: BinaryHeap::new(),
            background: BinaryHeap::new(),
            open: true,
            seq: 3,
        };
        // Aged, deadline-less background request (past the limit).
        st.background.push(Reverse(Queued {
            key_deadline: None,
            seq: 0,
            req: mk(1, Priority::Batch, now - Duration::from_millis(200), None),
        }));
        // Fresh interactive request.
        st.interactive.push(Reverse(Queued {
            key_deadline: None,
            seq: 1,
            req: mk(2, Priority::Interactive, now, None),
        }));
        // Fresh background request *with* a deadline: background is
        // seq-ordered, so it must not jump the aged one.
        st.background.push(Reverse(Queued {
            key_deadline: None,
            seq: 2,
            req: mk(3, Priority::Batch, now, Some(now + Duration::from_millis(5))),
        }));
        let order: Vec<usize> = (0..3)
            .map(|_| st.pop_next(&pcfg, now).expect("queued").image.shape[0])
            .collect();
        assert_eq!(
            order,
            vec![1, 2, 3],
            "aged background (promoted), then interactive (promotion cleared), then fresh background"
        );
        assert!(st.pop_next(&pcfg, now).is_none());
    }

    /// Starvation protection end to end: with interactive traffic
    /// continuously queued, an old background request is still served
    /// (the promotion path), and the background class drains.
    #[test]
    fn background_class_is_not_starved() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(1),
                discipline: QueueDiscipline::Priority,
                // Tiny limit so the test promotes quickly.
                starvation_limit: Duration::from_millis(5),
                ..ServerConfig::default()
            },
        );
        let bg = server.submit_with(image(res, 0), Priority::Batch, None);
        // Keep interactive traffic flowing while the background request
        // ages past the starvation limit.
        let mut fg = Vec::new();
        for i in 0..12u64 {
            fg.push(server.submit_with(image(res, 1 + i), Priority::Interactive, None));
            std::thread::sleep(Duration::from_millis(1));
        }
        let bg_reply = bg.recv().expect("background request must not starve");
        assert_eq!(bg_reply.logits.len(), 1000);
        for rx in fg {
            assert_eq!(rx.recv().expect("interactive reply").logits.len(), 1000);
        }
        let stats = server.shutdown();
        assert_eq!(stats.class(Priority::Batch).served, 1);
        assert_eq!(stats.class(Priority::Interactive).served, 12);
    }

    /// Parked dispatchers must wake for bursts and for shutdown: a
    /// 3-executor adaptive server under a trickle-then-burst load
    /// answers everything and exits cleanly.
    #[test]
    fn adaptive_parked_dispatchers_wake_on_burst_and_shutdown() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(4)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 3,
                adaptive: true,
                ..ServerConfig::default()
            },
        );
        // Trickle: one at a time (surplus dispatchers stay parked).
        for i in 0..3 {
            let rx = server.submit(image(res, i));
            assert_eq!(rx.recv().expect("trickle reply").logits.len(), 1000);
        }
        // Burst: all at once (parked dispatchers must wake to help).
        let rxs: Vec<_> = (10..20).map(|i| server.submit(image(res, i))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().expect("burst reply").logits.len(), 1000);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 13);
        let (lo, hi) = stats.cap_range.expect("caps recorded");
        assert!(lo >= 1 && hi <= 4);
    }

    /// A tight head deadline flips the drain into latency mode: the
    /// smallest compiled batch is chosen even though the queue is deep
    /// (observable through the batch histogram).
    #[test]
    fn tight_deadlines_choose_small_batches() {
        let res = 32;
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::dense_cnhw(ThreadPool::shared(2)),
            res,
            ServerConfig {
                batch_sizes: vec![1, 8],
                // A long window would otherwise merge the whole burst.
                batch_window: Duration::from_millis(100),
                adaptive: true,
                discipline: QueueDiscipline::Priority,
                slack_floor: Duration::from_millis(50),
                ..ServerConfig::default()
            },
        );
        // Every request's slack (20 ms) is under the 50 ms floor, so
        // each drain takes the smallest batch and skips the window.
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                server.submit_with(
                    image(res, i),
                    Priority::Interactive,
                    Some(Duration::from_millis(20)),
                )
            })
            .collect();
        for rx in rxs {
            assert_eq!(rx.recv().expect("reply").logits.len(), 1000);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 6);
        assert_eq!(
            stats.batch_hist,
            vec![(1, 6)],
            "latency mode must have served every request on the batch-1 executor"
        );
    }
}
