//! # nmprune — Column-wise N:M pruning for vector CPUs
//!
//! Reproduction of *"Efficient Column-Wise N:M Pruning on RISC-V CPU"*
//! (Chu, Hong, Wu — Academia Sinica, 2025) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate is the Layer-3 coordinator: it owns pruning, layout
//! transforms, the native tiled GEMM/convolution hot path, an RVV
//! (RISC-V Vector) simulator used to reproduce the paper's L1-cache-load
//! and cycle metrics, an AITemplate-style auto-tuner, a model zoo of the
//! paper's CNN architectures, and a batching inference engine.  AOT
//! compiled JAX/Pallas artifacts (HLO text) are loaded and executed via
//! PJRT in [`runtime`].
//!
//! Repo-wide invariants (SAFETY-commented `unsafe`, pool-only threads,
//! clock-free policies, zero-alloc `_into` paths, …) are machine-checked
//! by [`analysis`] (`nmprune lint`); see `docs/SAFETY.md`.

// Every `unsafe` operation inside an `unsafe fn` must sit in an explicit
// `unsafe {}` block with its own `// SAFETY:` comment (checked by the U1
// lint rule) — the fn-level `unsafe` only states the caller's contract.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod util;
pub mod tensor;
pub mod pruning;
pub mod im2col;
pub mod gemm;
pub mod conv;
pub mod rvv;
pub mod models;
pub mod tuner;
pub mod engine;
pub mod runtime;
pub mod benchlib;
