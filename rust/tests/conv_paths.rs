//! Cross-path equivalence: every execution path the paper compares must
//! compute the same convolution. Property tests over random geometry
//! (kernel/stride/pad/batch/V/T), plus the edge cases the fused
//! im2col+pack kernel's tail handling exists for.

use nmprune::conv::{Conv2dDenseCnhw, Conv2dDenseNhwc, Conv2dSparseCnhw, ConvShape};
use nmprune::gemm::{gemm_dense, matmul_ref, spmm_colwise, spmm_inner_rownm, spmm_outer_rownm};
use nmprune::im2col::naive::conv2d_direct_cnhw;
use nmprune::im2col::{fused_im2col_pack_cnhw, im2col_cnhw, pack_data_matrix};
use nmprune::pruning::{prune_colwise_adaptive, prune_rownm};
use nmprune::rvv::kernels::sim_spmm_colwise;
use nmprune::rvv::RvvMachine;
use nmprune::tensor::layout::{cnhw_to_nhwc, nhwc_to_cnhw, oihw_to_filter_matrix};
use nmprune::tensor::Tensor;
use nmprune::util::{allclose, prop, ThreadPool, XorShiftRng};

/// Draw a random-but-valid conv shape. `size` scales the channel count.
fn random_shape(r: &mut XorShiftRng, size: usize) -> ConvShape {
    let k = [1, 3, 5, 7][r.below(4)];
    let stride = 1 + r.below(2);
    let pad = r.below(k / 2 + 2).min(k); // sometimes > k/2, sometimes 0
    let hw = (k + stride + r.below(12)).max(4);
    ConvShape {
        n: 1 + r.below(3),
        c_in: 1 + r.below(size.max(2)),
        h_in: hw,
        w_in: (k + r.below(17)).max(3), // non-square, often not %V
        c_out: 1 + r.below(size.max(2)),
        kh: k,
        kw: k,
        stride,
        pad,
    }
}

#[test]
fn prop_dense_cnhw_equals_direct_conv() {
    let pool = ThreadPool::shared(1);
    prop::check_seeded(
        0xA110,
        |r, size| {
            let s = random_shape(r, size);
            let v = [4, 8, 16, 32][r.below(4)];
            let tile = 1 + r.below(10);
            (s, v, tile, r.next_u64())
        },
        |&(s, v, tile, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
            let got = Conv2dDenseCnhw::new(s, &w, v, tile).run(&x, &pool);
            let want = conv2d_direct_cnhw(&x, &w, &s);
            allclose(&got.data, &want.data, 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_dense_nhwc_agrees_with_cnhw_path() {
    let pool = ThreadPool::shared(1);
    prop::check_seeded(
        0xA111,
        |r, size| {
            let s = random_shape(r, size);
            (s, r.next_u64())
        },
        |&(s, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let x_nhwc = Tensor::random(&[s.n, s.h_in, s.w_in, s.c_in], &mut rng, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
            let y_nhwc = Conv2dDenseNhwc::new(s, &w).run(&x_nhwc, &pool);
            let x_cnhw = nhwc_to_cnhw(&x_nhwc);
            let y_cnhw = Conv2dDenseCnhw::new(s, &w, 16, 4).run(&x_cnhw, &pool);
            allclose(&y_nhwc.data, &cnhw_to_nhwc(&y_cnhw).data, 1e-4, 1e-5)
        },
    );
}

#[test]
fn prop_sparse_equals_masked_dense_reference() {
    prop::check_seeded(
        0xA112,
        |r, size| {
            let s = random_shape(r, size);
            let v = [8, 16, 32][r.below(3)];
            let tile = 1 + r.below(8);
            let sparsity = [0.25, 0.5, 0.75][r.below(3)];
            (s, v, tile, sparsity, r.next_u64())
        },
        |&(s, v, tile, sparsity, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
            let op = Conv2dSparseCnhw::new_adaptive(s, &w, v, tile, sparsity);
            let got = op.run(&x, &ThreadPool::shared(1));
            // Reference: masked filter matrix × im2col data matrix.
            let masked = op.weights.decompress();
            let a = im2col_cnhw(&x, &s);
            let want = matmul_ref(&masked, &a, s.c_out, s.k(), s.gemm_cols());
            allclose(&got.data, &want, 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_fused_pack_equals_separate_passes() {
    prop::check_seeded(
        0xA113,
        |r, size| {
            let s = random_shape(r, size);
            let v = [4, 8, 16, 32, 64][r.below(5)];
            (s, v, r.next_u64())
        },
        |&(s, v, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
            let fused = fused_im2col_pack_cnhw(&x, &s, v);
            let separate = pack_data_matrix(&im2col_cnhw(&x, &s), s.k(), s.gemm_cols(), v);
            fused.data == separate.data
                && fused.k == separate.k
                && fused.cols == separate.cols
        },
    );
}

#[test]
fn prop_threading_is_result_invariant() {
    prop::check_seeded(
        0xA114,
        |r, size| {
            let s = random_shape(r, size);
            let threads = 2 + r.below(5);
            (s, threads, r.next_u64())
        },
        |&(s, threads, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
            let sp = Conv2dSparseCnhw::new_adaptive(s, &w, 16, 4, 0.5);
            let single = sp.run(&x, &ThreadPool::shared(1));
            let multi = sp.run(&x, &ThreadPool::shared(threads));
            // Bitwise: identical per-tile arithmetic, only dispatch differs.
            single.data == multi.data
        },
    );
}

#[test]
fn prop_rvv_sim_matches_native_across_lmul_and_tails() {
    prop::check_seeded(
        0xA115,
        |r, size| {
            let rows = 1 + r.below(12);
            let k = 1 + r.below(size.max(4));
            let lmul = [1usize, 2, 4, 8][r.below(4)];
            // Deliberately non-multiple-of-V cols to exercise tails.
            let cols = 1 + r.below(70);
            let tile = 1 + r.below((32 / lmul - 1).min(8));
            (rows, k, cols, lmul, tile, r.next_u64())
        },
        |&(rows, k, cols, lmul, tile, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let w = rng.normal_vec(rows * k, 1.0);
            let a = rng.normal_vec(k * cols, 1.0);
            let mut m = RvvMachine::k1();
            let v = m.vlmax(lmul);
            let p = pack_data_matrix(&a, k, cols, v);
            let cp = prune_colwise_adaptive(&w, rows, k, tile, 0.5);
            let native = spmm_colwise(&cp, &p);
            let (sim, rep) = sim_spmm_colwise(&mut m, &cp, &p, lmul);
            allclose(&sim, &native, 1e-5, 1e-6) && rep.instructions > 0
        },
    );
}

#[test]
fn prop_row_nm_kernels_agree_on_shared_mask() {
    prop::check_seeded(
        0xA116,
        |r, size| {
            let rows = 1 + r.below(16);
            let m = [4usize, 8][r.below(2)];
            let groups = 1 + r.below(size.max(2));
            let n = 1 + r.below(m);
            let cols = 1 + r.below(50);
            (rows, m, groups, n, cols, r.next_u64())
        },
        |&(rows, m, groups, n, cols, seed)| {
            let k = m * groups;
            let mut rng = XorShiftRng::new(seed);
            let w = rng.normal_vec(rows * k, 1.0);
            let a = rng.normal_vec(k * cols, 1.0);
            let rp = prune_rownm(&w, rows, k, n, m);
            let p = pack_data_matrix(&a, k, cols, 16);
            let inner = spmm_inner_rownm(&rp, &p);
            let outer = spmm_outer_rownm(&rp, &p);
            let want = matmul_ref(&rp.decompress(), &a, rows, k, cols);
            allclose(&inner, &want, 1e-4, 1e-5) && allclose(&outer, &want, 1e-4, 1e-5)
        },
    );
}

// ---------------------------------------------------------------------
// Edge cases the random generator hits only occasionally — pinned.

fn run_both(s: ConvShape) {
    let mut rng = XorShiftRng::new(1);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut rng, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
    let got = Conv2dDenseCnhw::new(s, &w, 32, 8).run(&x, &ThreadPool::shared(1));
    let want = conv2d_direct_cnhw(&x, &w, &s);
    assert!(
        allclose(&got.data, &want.data, 1e-3, 1e-3),
        "mismatch for {s}"
    );
}

#[test]
fn edge_input_narrower_than_strip() {
    // W_out (3) ≪ V (32): a single ragged tail strip.
    run_both(ConvShape::square(1, 4, 5, 3, 3, 1, 1));
}

#[test]
fn edge_1x1_kernel_stride_2() {
    run_both(ConvShape::square(2, 8, 9, 4, 1, 2, 0));
}

#[test]
fn edge_7x7_stride_2_pad_3_stem() {
    run_both(ConvShape::square(1, 3, 21, 8, 7, 2, 3));
}

#[test]
fn edge_single_output_pixel() {
    // H_out = W_out = 1.
    run_both(ConvShape::square(1, 6, 3, 5, 3, 1, 0));
}

#[test]
fn edge_pad_wider_than_kernel_half() {
    run_both(ConvShape::square(1, 2, 6, 3, 3, 1, 2));
}

#[test]
fn edge_batch_spans_strip_boundary() {
    // cols = n·h_out·w_out = 3·4·4 = 48, V = 32: strip 1 crosses batches.
    run_both(ConvShape::square(3, 4, 4, 4, 3, 1, 1));
}

#[test]
fn edge_dense_gemm_tile_larger_than_rows() {
    let mut rng = XorShiftRng::new(2);
    let (rows, k, cols) = (3usize, 8usize, 20usize);
    let w = rng.normal_vec(rows * k, 1.0);
    let a = rng.normal_vec(k * cols, 1.0);
    let p = pack_data_matrix(&a, k, cols, 16);
    let got = gemm_dense(&w, rows, &p, 8); // tile 8 > rows 3
    assert!(allclose(&got, &matmul_ref(&w, &a, rows, k, cols), 1e-4, 1e-5));
}

#[test]
fn prop_dense_nchw_agrees_with_nhwc_path() {
    use nmprune::conv::Conv2dDenseNchw;
    use nmprune::tensor::layout::{nchw_to_nhwc, nhwc_to_nchw};
    let pool = ThreadPool::shared(1);
    prop::check_seeded(
        0xA117,
        |r, size| {
            let s = random_shape(r, size);
            (s, r.next_u64())
        },
        |&(s, seed)| {
            let mut rng = XorShiftRng::new(seed);
            let x_nhwc = Tensor::random(&[s.n, s.h_in, s.w_in, s.c_in], &mut rng, -1.0, 1.0);
            let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut rng, -0.5, 0.5);
            let y_nhwc = Conv2dDenseNhwc::new(s, &w).run(&x_nhwc, &pool);
            let y_nchw =
                Conv2dDenseNchw::new(s, &w, 16, 4).run(&nhwc_to_nchw(&x_nhwc), &pool);
            allclose(&y_nhwc.data, &nchw_to_nhwc(&y_nchw).data, 1e-3, 1e-3)
        },
    );
}

#[test]
fn edge_filter_matrix_roundtrip_orientation() {
    // The OIHW→filter-matrix permutation must match the im2col row
    // order: a conv with a delta filter extracts the right channel.
    let s = ConvShape::square(1, 3, 4, 1, 1, 1, 0);
    let mut w = Tensor::zeros(&[1, 3, 1, 1]);
    w.data[2] = 1.0; // select input channel 2
    let mut rng = XorShiftRng::new(3);
    let x = Tensor::random(&[3, 1, 4, 4], &mut rng, -1.0, 1.0);
    let y = Conv2dDenseCnhw::new(s, &w, 8, 2).run(&x, &ThreadPool::shared(1));
    let want = &x.data[2 * 16..3 * 16];
    assert!(allclose(&y.data, want, 1e-6, 1e-7));
    // And the flattened matrix has the 1.0 at column 2 (k-major, ch inner).
    let f = oihw_to_filter_matrix(&w);
    assert_eq!(f.data[2], 1.0);
}
