//! Integration proof of the memory-plane refactor: with a counting
//! global allocator installed for this whole test binary, a warmed
//! executor and a warmed server perform ZERO heap allocations per
//! request in the compute plane, and AOT-packed artifacts round-trip
//! through disk bitwise — across pool sizes and serving modes.
//!
//! Counting is per-thread (see `util::allocwatch`), so the concurrent
//! test threads `cargo test` runs don't pollute each other's scopes;
//! the server aggregates its dispatcher-thread measurements into
//! `ServerStats::compute_allocs` where this test reads them.
//!
//! The strict zero assertions run on single-worker pools: the pool's
//! serial fast path executes jobs inline on the calling thread, while
//! the parallel path boxes jobs per strip (measured, but a scheduling
//! cost — not part of the per-request compute-plane guarantee).

use std::time::Duration;

use nmprune::engine::{ExecConfig, Executor, Server, ServerConfig};
use nmprune::models::{build_model, ModelArch};
use nmprune::runtime::PackedArtifact;
use nmprune::tensor::Tensor;
use nmprune::util::allocwatch::{self, CountingAlloc, ScopeStats};
use nmprune::util::{ThreadPool, XorShiftRng};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn image(batch: usize, res: usize, seed: u64) -> Tensor {
    let mut r = XorShiftRng::new(seed);
    Tensor::random(&[batch, res, res, 3], &mut r, 0.0, 1.0)
}

/// A warmed executor running inside its scratch arena performs no heap
/// allocation at all — the tentpole guarantee, measured for both CNHW
/// paths (the paper's sparse path and the dense baseline).
#[test]
fn warmed_arena_execution_is_allocation_free() {
    let res = 32;
    let configs = [
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
        ExecConfig::dense_cnhw(ThreadPool::shared(1)),
    ];
    for cfg in configs {
        let label = cfg.path;
        let exec = Executor::new(build_model(ModelArch::ResNet18, 1, res), cfg);
        let mut arena = exec.scratch();
        // Warm once. (The arena is fully preallocated and pre-faulted,
        // so even this first run should be clean — but the guarantee
        // under test is the steady state.)
        let x = image(1, res, 1);
        exec.run_in(&x, &mut arena);
        for round in 0..3u64 {
            let x = image(1, res, 2 + round);
            let (_, stats) = allocwatch::scoped(|| {
                exec.run_in(&x, &mut arena);
            });
            assert_eq!(
                stats,
                ScopeStats::default(),
                "{label:?} round {round} allocated on the compute plane"
            );
        }
    }
}

/// End-to-end serving: every batch a single-worker server executes —
/// the first included, because arenas and staging tensors are
/// preallocated at dispatcher startup — runs its compute plane without
/// touching the heap. Reply transport is outside the measured region
/// by design.
#[test]
fn server_compute_plane_is_allocation_free() {
    let res = 32;
    let server = Server::start(
        |b| build_model(ModelArch::ResNet18, b, res),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
        res,
        ServerConfig {
            batch_sizes: vec![1, 2],
            batch_window: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    );
    for i in 0..8u64 {
        let mut r = XorShiftRng::new(i);
        let img = Tensor::random(&[res, res, 3], &mut r, 0.0, 1.0);
        let reply = server.submit(img).recv().expect("reply");
        assert_eq!(reply.logits.len(), 1000);
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 8);
    assert!(!stats.compute_allocs.is_empty(), "batches must be measured");
    for (i, &(allocs, bytes)) in stats.compute_allocs.iter().enumerate() {
        assert_eq!(
            (allocs, bytes),
            (0, 0),
            "batch {i} allocated on the compute plane"
        );
    }
}

/// AOT artifact round-trip through disk: save → load → execute is
/// bitwise identical to the executor that produced the artifact —
/// across pool sizes {1, 2, 8}, in and out of the arena path, and when
/// served by static and adaptive servers built from the same file.
#[test]
fn artifact_disk_roundtrip_is_bitwise_across_pools_and_modes() {
    let res = 32;
    let dir = std::env::temp_dir().join("nmprune_zero_alloc_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resnet18_s50.nmpk");
    let art = Executor::new(
        build_model(ModelArch::ResNet18, 1, res),
        ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
    )
    .to_artifact();
    art.save(&path).expect("save artifact");
    let loaded = PackedArtifact::load(&path).expect("load artifact");

    // Online-packed reference on a serial pool; pool size and caps are
    // scheduling decisions and never change numerics.
    let x = image(1, res, 9);
    let want = Executor::new(
        build_model(ModelArch::ResNet18, 1, res),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
    )
    .run(&x);
    for pool in [1usize, 2, 8] {
        let exec = Executor::from_artifact(
            build_model(ModelArch::ResNet18, 1, res),
            ThreadPool::shared(pool),
            &loaded,
        )
        .expect("artifact matches graph");
        assert_eq!(exec.run(&x).data, want.data, "pool {pool}");
        let mut arena = exec.scratch();
        let got = exec.run_in(&x, &mut arena);
        assert_eq!(got.data, want.data, "pool {pool} (arena)");
    }

    // Served from the same file, static and adaptive mode agree
    // bitwise (scheduling is pure), and the first reply matches the
    // direct run on its image.
    let collect = |adaptive: bool| -> Vec<Vec<f32>> {
        let server = Server::start_packed(
            |b| build_model(ModelArch::ResNet18, b, res),
            ThreadPool::shared(2),
            &loaded,
            ServerConfig {
                batch_sizes: vec![1, 2],
                batch_window: Duration::from_millis(2),
                executors: 2,
                adaptive,
                ..ServerConfig::default()
            },
        )
        .expect("start from artifact");
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let mut r = XorShiftRng::new(9 + i);
                server.submit(Tensor::random(&[res, res, 3], &mut r, 0.0, 1.0))
            })
            .collect();
        let out = rxs.into_iter().map(|rx| rx.recv().unwrap().logits).collect();
        server.shutdown();
        out
    };
    let fixed = collect(false);
    assert_eq!(fixed[0], want.data, "served logits match the direct run");
    assert_eq!(fixed, collect(true), "serving mode changed numerics");
    std::fs::remove_dir_all(&dir).ok();
}
