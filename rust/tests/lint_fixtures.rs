//! Fixture coverage for `nmprune lint`: every rule has true-positive
//! and true-negative fixtures, the suppression grammar round-trips,
//! strings/comments stay invisible to rules, the CLI obeys the
//! bench-diff exit-code contract (0 clean / 1 findings / 2 usage) —
//! and, the gate that matters, the repository's own tree lints clean.

use std::path::Path;
use std::process::{Command, Output};

use nmprune::analysis::{lint_source, lint_tree, render_text, Rule};
use nmprune::util::json::Json;

#[test]
fn u1_unsafe_requires_safety_comment() {
    let f = lint_source("src/a.rs", "unsafe fn f() {}\n");
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::U1);
    assert_eq!(f[0].line, 1);
    assert_eq!(f[0].file, "src/a.rs");
    assert!(f[0].snippet.contains("fn f"));

    let above = "// SAFETY: fixture, pointer is valid\nlet x = unsafe { g() };\n";
    assert!(lint_source("src/a.rs", above).is_empty());
    let trailing = "let x = unsafe { g() }; // SAFETY: fine\n";
    assert!(lint_source("src/a.rs", trailing).is_empty());
    let doc_section = "/// # Safety\n/// caller checks bounds\nunsafe fn f() {}\n";
    assert!(lint_source("src/a.rs", doc_section).is_empty());

    // A blank line breaks "immediately preceding".
    let gap = "// SAFETY: stale, too far away\n\nlet x = unsafe { g() };\n";
    let f = lint_source("src/a.rs", gap);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::U1, 3));

    // Multi-line statement: the comment above the statement head counts.
    let split = concat!(
        "// SAFETY: lifetime erasure only, pool blocks until jobs drain\n",
        "let f: &'static F =\n",
        "    unsafe { transmute(r) };\n",
    );
    assert!(lint_source("src/a.rs", split).is_empty());
}

#[test]
fn s1_spawn_only_in_threadpool() {
    let src = "fn f() { std::thread::spawn(|| {}); }\n";
    let f = lint_source("rust/src/engine/server.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::S1, 1));
    // The pool's own implementation file is the one exempt location.
    assert!(lint_source("rust/src/util/threadpool.rs", src).is_empty());
}

#[test]
fn p1_policy_module_is_clock_free() {
    for src in [
        "fn f() { let t = std::time::Instant::now(); }\n",
        "fn f() { let t = std::time::SystemTime::now(); }\n",
        "fn f(t: std::time::Instant) -> u128 { t.elapsed().as_micros() }\n",
    ] {
        let f = lint_source("rust/src/engine/policy.rs", src);
        assert_eq!(f.len(), 1, "{src}");
        assert_eq!(f[0].rule, Rule::P1, "{src}");
        // The same code is fine outside the policy module.
        assert!(lint_source("rust/src/engine/server.rs", src).is_empty(), "{src}");
    }
}

#[test]
fn a1_no_debug_assert_in_artifact_loader() {
    let src = "fn f(x: u32) { debug_assert!(x > 0); }\n";
    let f = lint_source("rust/src/runtime/artifact.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::A1, 1));
    // The `_eq!` / `_ne!` variants share the identifier prefix.
    let eq = "fn f(x: u32) { debug_assert_eq!(x, 1); }\n";
    assert_eq!(lint_source("rust/src/runtime/artifact.rs", eq)[0].rule, Rule::A1);
    // A doc-comment mention is prose, not code — the old CI grep
    // false-positived exactly here.
    let doc = "/// Unlike debug_assert, this check survives release.\nfn f() {}\n";
    assert!(lint_source("rust/src/runtime/artifact.rs", doc).is_empty());
    // Other files may keep their debug_asserts.
    assert!(lint_source("rust/src/gemm/dense.rs", src).is_empty());
}

#[test]
fn n1_partial_cmp_unwrap_even_across_lines() {
    let one = "let o = a.partial_cmp(&b).unwrap();\n";
    let f = lint_source("src/a.rs", one);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::N1, 1));
    assert!(f[0].message.contains("total_cmp"));

    // rustfmt splits long chains — the scan runs on the joined view.
    let multi = "let o = a\n    .partial_cmp(&b)\n    .unwrap();\n";
    let f = lint_source("src/a.rs", multi);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::N1, 2));

    let expect = "let o = a.partial_cmp(&b).expect(\"cmp\");\n";
    assert_eq!(lint_source("src/a.rs", expect)[0].rule, Rule::N1);

    // total_cmp and NaN-tolerant unwrap_or are the approved forms.
    assert!(lint_source("src/a.rs", "let o = a.total_cmp(&b);\n").is_empty());
    let tolerant = "xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));\n";
    assert!(lint_source("src/a.rs", tolerant).is_empty());
}

#[test]
fn z1_alloc_calls_inside_marked_region() {
    let src = concat!(
        "// nmprune: zero-alloc\n",
        "fn hot(out: &mut [f32]) {\n",
        "    let v = Vec::new();\n",
        "    let w = xs.iter().collect();\n",
        "}\n",
    );
    let f = lint_source("src/a.rs", src);
    assert_eq!(f.len(), 2);
    assert_eq!((f[0].rule, f[0].line), (Rule::Z1, 3));
    assert_eq!((f[1].rule, f[1].line), (Rule::Z1, 4));
    assert!(f[0].message.contains("fn hot"));

    // The same body without the marker is not Z1's business.
    let unmarked = "fn cold() {\n    let v = Vec::new();\n}\n";
    assert!(lint_source("src/a.rs", unmarked).is_empty());

    // The check is lexical: allocations in callees are out of scope,
    // and an alloc after the fn's closing brace is outside the region.
    let clean = concat!(
        "// nmprune: zero-alloc\n",
        "fn hot(out: &mut [f32]) {\n",
        "    helper(out);\n",
        "}\n",
        "fn later() {\n",
        "    let v = Vec::new();\n",
        "}\n",
    );
    assert!(lint_source("src/a.rs", clean).is_empty());

    // A dangling marker is itself a finding.
    let dangling = "// nmprune: zero-alloc\n";
    let f = lint_source("src/a.rs", dangling);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::Z1, 1));
}

#[test]
fn suppression_round_trip_and_hygiene() {
    // A justified allow on the line above silences the finding.
    let ok = concat!(
        "// nmprune-lint: allow(S1) -- fixture spawn, joined below\n",
        "std::thread::spawn(|| {});\n",
    );
    assert!(lint_source("src/a.rs", ok).is_empty());

    // Trailing form covers its own line too.
    let trailing = "std::thread::spawn(|| {}); // nmprune-lint: allow(S1) -- fixture\n";
    assert!(lint_source("src/a.rs", trailing).is_empty());

    // The directive reaches exactly one line: two lines away it lapses.
    let far = concat!(
        "// nmprune-lint: allow(S1) -- too far away\n",
        "\n",
        "std::thread::spawn(|| {});\n",
    );
    let f = lint_source("src/a.rs", far);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::S1, 3));

    // Empty justification: L1, and the suppression does not take effect.
    let empty = concat!(
        "// nmprune-lint: allow(S1) --\n",
        "std::thread::spawn(|| {});\n",
    );
    let f = lint_source("src/a.rs", empty);
    assert_eq!(f.len(), 2);
    assert_eq!((f[0].rule, f[0].line), (Rule::L1, 1));
    assert_eq!((f[1].rule, f[1].line), (Rule::S1, 2));

    // Missing `--`, unknown rule id, and allow(L1) are all L1 findings.
    let missing = "// nmprune-lint: allow(N1) because reasons\n";
    assert_eq!(lint_source("src/a.rs", missing)[0].rule, Rule::L1);
    let unknown = "// nmprune-lint: allow(Q9) -- no such rule\n";
    assert_eq!(lint_source("src/a.rs", unknown)[0].rule, Rule::L1);
    let meta = "// nmprune-lint: allow(L1) -- nice try\n";
    let f = lint_source("src/a.rs", meta);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::L1);
    assert!(f[0].message.contains("cannot be suppressed"));
}

#[test]
fn strings_and_comments_are_invisible_to_rules() {
    let in_str = "let s = \"unsafe thread::spawn debug_assert\";\n";
    assert!(lint_source("rust/src/runtime/artifact.rs", in_str).is_empty());

    let in_raw = "let s = r#\"unsafe { thread::spawn }\"#;\n";
    assert!(lint_source("src/a.rs", in_raw).is_empty());

    let in_comment = "// unsafe is discussed here, thread::spawn too\nfn f() {}\n";
    assert!(lint_source("src/a.rs", in_comment).is_empty());

    let in_block = "/* spanning\n   unsafe thread::spawn\n */\nfn f() {}\n";
    assert!(lint_source("src/a.rs", in_block).is_empty());

    // And the converse: code after a comment on the same line still fires.
    let mixed = "let x = unsafe { g() }; // not a safety comment\n";
    assert_eq!(lint_source("src/a.rs", mixed)[0].rule, Rule::U1);
}

fn run_lint(args: &[&str]) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_nmprune"));
    cmd.arg("lint").args(args);
    cmd.output().expect("spawn nmprune lint")
}

#[test]
fn lint_cli_exit_codes_and_json() {
    let dir = std::env::temp_dir().join(format!("nmprune_lint_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("engine")).unwrap();
    let dirty = "fn f() { let x = 1; }\nunsafe fn g() {}\n";
    std::fs::write(dir.join("engine/bad.rs"), dirty).unwrap();

    // Findings: exit 1, text report anchored to file:line.
    let out = run_lint(&[dir.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("engine/bad.rs:2: [U1]"), "{text}");
    assert!(text.contains("lint: 1 finding(s)"), "{text}");

    // Same findings in machine-readable form under --json.
    let out = run_lint(&["--json", dir.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1));
    let doc = String::from_utf8_lossy(&out.stdout).into_owned();
    let doc = Json::parse(&doc).expect("lint --json output must parse");
    assert_eq!(doc.get("count").and_then(Json::as_f64), Some(1.0));
    let arr = doc.get("findings").and_then(Json::as_arr).expect("findings");
    assert_eq!(arr[0].get("rule").and_then(Json::as_str), Some("U1"));
    assert_eq!(arr[0].get("file").and_then(Json::as_str), Some("engine/bad.rs"));
    assert_eq!(arr[0].get("line").and_then(Json::as_f64), Some(2.0));

    // Fixed tree: exit 0.
    std::fs::write(dir.join("engine/bad.rs"), "fn f() {}\n").unwrap();
    let out = run_lint(&[dir.to_str().unwrap()]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("lint: clean"));

    // Nonexistent path: usage/IO error, exit 2.
    let out = run_lint(&["/nonexistent/nmprune_lint_fixture"]);
    assert_eq!(out.status.code(), Some(2));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn repo_tree_is_lint_clean() {
    // The CI gate in miniature: the crate's own repository — sources,
    // tests, benches, examples — must carry zero findings.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
    let findings = lint_tree(root).expect("lint walks the repo tree");
    assert!(findings.is_empty(), "repo must self-lint clean:\n{}", render_text(&findings));
}
